//! NN integration: functional forward pass consistency with the python
//! conventions, model-table sanity, and end-to-end cost coherence.

use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::{all_models, cifar_resnet14, imagenet_resnet};
use tcbnn::nn::{model_cost, ModelDef, ResidualMode, Scheme};
use tcbnn::sim::{RTX2080, RTX2080TI};
use tcbnn::util::Rng;

fn small_cifar_net() -> ModelDef {
    ModelDef {
        name: "cifar-lite",
        dataset: "synthetic",
        input: Dims { hw: 16, feat: 3 },
        classes: 10,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 32, o: 64, k: 3, stride: 1, pad: 1, pool: true, residual: false,
            },
            LayerSpec::BinConv {
                c: 64, o: 64, k: 3, stride: 1, pad: 1, pool: true, residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 64, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

#[test]
fn cifar_lite_full_pipeline() {
    let m = small_cifar_net();
    let mut rng = Rng::new(42);
    let w = random_weights(&m, &mut rng);
    let batch = 8;
    let x: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.next_f32() - 0.5).collect();
    let logits = forward(&m, &w, &x, batch);
    assert_eq!(logits.len(), batch * 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    // logits are bounded by +/- d_in * gamma
    for v in &logits {
        assert!(v.abs() <= 128.0 * 0.05 + 1.0);
    }
}

#[test]
fn perturbing_one_image_only_changes_its_logits() {
    let m = small_cifar_net();
    let mut rng = Rng::new(43);
    let w = random_weights(&m, &mut rng);
    let batch = 8;
    let elems = 16 * 16 * 3;
    let x: Vec<f32> = (0..batch * elems).map(|_| rng.next_f32()).collect();
    let base = forward(&m, &w, &x, batch);
    let mut x2 = x.clone();
    for v in &mut x2[3 * elems..4 * elems] {
        *v = 1.0 - *v;
    }
    let pert = forward(&m, &w, &x2, batch);
    for i in 0..batch {
        let same = base[i * 10..(i + 1) * 10] == pert[i * 10..(i + 1) * 10];
        if i == 3 {
            assert!(!same, "perturbed image must change");
        } else {
            assert!(same, "image {i} must be unaffected");
        }
    }
}

#[test]
fn table5_models_have_sane_sizes() {
    for m in all_models() {
        let mbits = m.weight_bits();
        // binarized models are between 0.1 MB and 64 MB of weights
        let mbytes = mbits as f64 / 8.0 / 1e6;
        assert!(
            mbytes > 0.1 && mbytes < 120.0,
            "{}: {mbytes} MB of packed weights",
            m.name
        );
    }
}

#[test]
fn tables_6_7_full_grid_is_computable() {
    // every (model, scheme, gpu) cell of Tables 6-7 must produce a
    // finite, positive latency and throughput
    for gpu in [&RTX2080, &RTX2080TI] {
        for m in all_models() {
            for s in Scheme::all() {
                let lat = model_cost(&m, 8, gpu, s, ResidualMode::Full, true);
                assert!(lat.total_secs > 0.0 && lat.total_secs.is_finite());
                let tput_batch = if m.dataset == "ImageNet" { 512 } else { 1024 };
                let tp = model_cost(&m, tput_batch, gpu, s, ResidualMode::Full, true);
                assert!(tp.throughput_fps() > 0.0);
                // throughput batch must beat latency batch in fps
                assert!(
                    tp.throughput_fps() > lat.throughput_fps() * 0.8,
                    "{} {} on {}",
                    m.name,
                    s.name(),
                    gpu.name
                );
            }
        }
    }
}

#[test]
fn headline_speedup_in_band() {
    // paper: BTC-FMT vs SBNN-64-Fine averages ~2.3x latency across the
    // six models; our model must land in a 1.2x-6x band per model and
    // >= 1.5x on average
    let mut ratios = Vec::new();
    for m in all_models() {
        let sbnn =
            model_cost(&m, 8, &RTX2080TI, Scheme::Sbnn64Fine, ResidualMode::Full, true)
                .total_secs;
        let btc =
            model_cost(&m, 8, &RTX2080TI, Scheme::BtcFmt, ResidualMode::Full, true)
                .total_secs;
        let r = sbnn / btc;
        assert!(r > 1.0 && r < 8.0, "{}: ratio {r}", m.name);
        ratios.push(r);
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(avg > 1.5, "average speedup {avg}");
}

#[test]
fn resnet14_residual_blocks_participate() {
    let m = cifar_resnet14();
    let with = model_cost(&m, 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
    let without = model_cost(&m, 8, &RTX2080, Scheme::BtcFmt, ResidualMode::None, true);
    assert!(with.total_secs > without.total_secs);
}

#[test]
fn deep_resnets_cost_table11_shape() {
    let t = |d| {
        model_cost(
            &imagenet_resnet(d),
            8,
            &RTX2080,
            Scheme::BtcFmt,
            ResidualMode::Full,
            true,
        )
        .total_secs
    };
    // paper Table 11: 1.44ms / 4.17 / 8.52 / 13.3 — ratios ~1 : 2.9 : 5.9 : 9.3
    let (a, b, c, d) = (t(18), t(50), t(101), t(152));
    assert!(b / a > 1.5 && b / a < 6.0, "50/18 = {}", b / a);
    assert!(c / a > 2.5 && c / a < 12.0, "101/18 = {}", c / a);
    assert!(d / a > 3.0 && d / a < 20.0, "152/18 = {}", d / a);
}
