//! End-to-end tests for the `serve::Fleet` layer: a million-request
//! bursty traffic replay over a 2-model/3-shard fleet (zero lost
//! waiters, bounded memory, sheds under overload, work stealing),
//! bit-identical outputs against a directly-driven `EngineModel`,
//! SLO-restricted batch sizing vs the fixed-bucket path, and the typed
//! error surface.
//!
//! Everything runs on host backends (MockModel / Fastpath+SIMD engine
//! models) — no GPU, no network.

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use tcbnn::coordinator::server::{BatchModel, MockModel, Response};
use tcbnn::coordinator::{Metrics, RouteError};
use tcbnn::engine::{EngineModel, Planner};
use tcbnn::nn::forward::random_weights;
use tcbnn::nn::model::mnist_mlp;
use tcbnn::serve::{
    AdmissionConfig, Fleet, FleetError, FleetModelConfig, SloConfig,
};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::Rng;

fn mock_factory(
    delay: Duration,
) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static
{
    move || {
        Ok(Box::new(MockModel { row_elems: 4, out_elems: 3, delay })
            as Box<dyn BatchModel>)
    }
}

/// The replay: one million open-loop requests in bursts of 8192,
/// every 8th burst aimed at a deliberately slow, depth-capped model.
///
/// Asserts the satellite's full contract:
/// * accounting closes: accepted + shed == 1_000_000, and the fleet's
///   own shed counters agree with the errors the callers saw;
/// * zero lost waiters: every accepted receiver yields a response (a
///   shed request returns `Err` synchronously and was never enqueued);
/// * the overloaded model sheds (queue-depth cap under 8192-bursts
///   that far exceed its ~160k req/s service rate);
/// * work stealing engaged at least once across the fleet;
/// * responses are correct (MockModel computes logit0 = sum(input));
/// * memory stays bounded: latency storage is the same fixed-footprint
///   histogram as a fresh `Metrics`, regardless of request count;
/// * p99 of accepted requests is finite and sane.
#[test]
fn million_request_replay_sheds_steals_and_loses_no_waiter() {
    const TOTAL: u64 = 1_000_000;
    const BURST: u64 = 8192;
    const PENDING_CAP: usize = 65_536;

    let mut fleet = Fleet::new();
    fleet.register(
        "fast",
        FleetModelConfig {
            shards: 3,
            max_wait: Duration::from_millis(1),
            admission: AdmissionConfig {
                rate: None,
                burst: 64.0,
                max_queue_depth: 1 << 20, // never the shedding model
            },
            ..Default::default()
        },
        mock_factory(Duration::ZERO),
    );
    fleet.register(
        "slow",
        FleetModelConfig {
            shards: 3,
            max_wait: Duration::from_millis(1),
            admission: AdmissionConfig {
                rate: None,
                burst: 64.0,
                max_queue_depth: 4096,
            },
            ..Default::default()
        },
        mock_factory(Duration::from_micros(200)),
    );

    let mut pending: VecDeque<(f32, Receiver<Response>)> = VecDeque::new();
    let mut accepted = 0u64;
    let mut shed_seen = 0u64;
    let mut answered = 0u64;
    let mut drain = |pending: &mut VecDeque<(f32, Receiver<Response>)>,
                     upto: usize,
                     answered: &mut u64| {
        while pending.len() > upto {
            let (want, rx) = pending.pop_front().unwrap();
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("accepted request must be answered (no lost waiter)");
            assert_eq!(r.logits[0], want, "request answered with its own result");
            *answered += 1;
        }
    };

    for i in 0..TOTAL {
        // bursty open loop: blocks of 8192, every 8th block goes to the
        // slow model (4x+ beyond its service rate -> guaranteed sheds)
        let model = if (i / BURST) % 8 == 7 { "slow" } else { "fast" };
        let tag = (i % 997) as f32;
        // MockModel: logits[0] = sum(input) = tag + 3
        match fleet.submit(model, vec![tag, 1.0, 1.0, 1.0]) {
            Ok(rx) => {
                accepted += 1;
                pending.push_back((tag + 3.0, rx));
            }
            Err(FleetError::Overloaded(_)) => shed_seen += 1,
            Err(e) => panic!("only overload may reject here, got {e}"),
        }
        // bound client-side memory without closing the loop per request
        if pending.len() > PENDING_CAP {
            drain(&mut pending, PENDING_CAP / 2, &mut answered);
        }
    }
    drain(&mut pending, 0, &mut answered);

    // accounting closes exactly
    assert_eq!(accepted + shed_seen, TOTAL);
    assert_eq!(answered, accepted, "every accepted waiter was answered");
    let fleet_sheds =
        fleet.sheds("fast").unwrap() + fleet.sheds("slow").unwrap();
    assert_eq!(fleet_sheds, shed_seen, "fleet counters match caller errors");
    assert!(
        fleet.sheds("slow").unwrap() > 0,
        "depth-capped model must shed under 8192-bursts"
    );
    assert_eq!(fleet.sheds("fast").unwrap(), 0, "uncapped model never sheds");

    // the fleet completed exactly the accepted requests
    let fast = fleet.metrics("fast").unwrap();
    let slow = fleet.metrics("slow").unwrap();
    assert_eq!(fast.completed() + slow.completed(), accepted);

    // work stealing engaged somewhere across 1M bursty requests
    let steals = fleet.steals("fast").unwrap() + fleet.steals("slow").unwrap();
    assert!(steals >= 1, "expected at least one steal, got {steals}");

    // bounded memory: latency storage is a fixed-footprint histogram —
    // identical to a Metrics that served nothing
    let fresh = Metrics::new().hist_footprint_bytes();
    assert_eq!(fast.hist_footprint_bytes(), fresh);
    assert_eq!(slow.hist_footprint_bytes(), fresh);

    // p99 of accepted requests is finite and sane
    for m in [&fast, &slow] {
        let s = m.latency_summary();
        assert!(s.p99.is_finite() && s.p99 > 0.0, "p99 {}", s.p99);
        assert!(s.p99 < 60.0, "p99 {} runaway", s.p99);
    }

    // per-shard attribution: 3 shards each, every counter consistent
    for name in ["fast", "slow"] {
        let snap = fleet.snapshot(name).unwrap();
        assert_eq!(snap.shards.len(), 3);
        let shard_reqs: u64 = snap.shards.iter().map(|s| s.requests).sum();
        assert_eq!(shard_reqs, snap.requests, "{name}: shard attribution sums");
        assert_eq!(
            snap.steals,
            snap.shards.iter().map(|s| s.steals).sum::<u64>()
        );
    }
    fleet.shutdown();
}

/// A 2-shard fleet over the real engine (mnist_mlp on host backends)
/// answers every request with logits bit-identical to a single
/// `EngineModel` driven directly — sharding, stealing, and batch
/// regrouping must not change a single bit.
#[test]
fn fleet_outputs_bit_identical_to_direct_engine_model() {
    const N: usize = 96;
    let model = mnist_mlp();
    let weights = random_weights(&model, &mut Rng::new(42));
    let planner = Planner::new(&RTX2080TI);
    let row = model.input.flat();

    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<f32>> =
        (0..N).map(|_| (0..row).map(|_| rng.next_f32() - 0.5).collect()).collect();

    // reference: one engine model, fixed batch-8 chunks
    let mut reference = EngineModel::builder(&planner, &model, &weights)
        .buckets(vec![8, 32])
        .build()
        .expect("reference engine model");
    let out_elems = reference.out_elems();
    let mut want: Vec<Vec<f32>> = Vec::with_capacity(N);
    for chunk in inputs.chunks(8) {
        let data: Vec<f32> = chunk.concat();
        let out = reference.run_batch(&data, chunk.len()).unwrap();
        for r in 0..chunk.len() {
            want.push(out[r * out_elems..(r + 1) * out_elems].to_vec());
        }
    }

    // fleet: 2 shards built from one factory (shared planner costs)
    let mut fleet = Fleet::new();
    let factory = {
        let (planner, model, weights) =
            (planner.clone(), model.clone(), weights.clone());
        move || {
            let em = EngineModel::builder(&planner, &model, &weights)
                .buckets(vec![8, 32])
                .build()?;
            Ok(Box::new(em) as Box<dyn BatchModel>)
        }
    };
    fleet.register(
        "mnist",
        FleetModelConfig { shards: 2, ..Default::default() },
        factory,
    );
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| fleet.submit("mnist", x.clone()).expect("admitted"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("answered");
        assert_eq!(r.id, i as u64, "fleet ids follow submission order");
        assert_eq!(
            r.logits, want[i],
            "request {i}: fleet logits must be bit-identical to direct"
        );
    }
    fleet.shutdown();
}

/// SLO-aware sizing: with a 10ms deadline and a predictor that prices
/// a 32-row batch at 32ms, the fleet must never form a 32-row batch —
/// while the fixed-bucket model under the same load happily does.
/// (The sizer's maximality property itself is covered by the unit
/// property test in `serve::slo`.)
#[test]
fn slo_sizing_restricts_buckets_and_fixed_path_does_not() {
    const N: usize = 300;
    let mut fleet = Fleet::new();
    // synthetic monotone cost curve: 1ms per row -> t(8)=8ms <= 10ms,
    // t(32)=32ms > 10ms, so only the 8-bucket is admissible
    fleet.register(
        "slo",
        FleetModelConfig {
            shards: 2,
            slo: Some(SloConfig { p99_deadline: Duration::from_millis(10) }),
            predictor: Some(Arc::new(|b| Some(b as f64 * 1e-3))),
            ..Default::default()
        },
        mock_factory(Duration::ZERO),
    );
    // same buckets, no SLO, slow enough that queues reach 32
    fleet.register(
        "fixed",
        FleetModelConfig { shards: 2, ..Default::default() },
        mock_factory(Duration::from_millis(1)),
    );

    let rxs: Vec<_> = (0..N)
        .flat_map(|i| {
            ["slo", "fixed"].map(|m| {
                fleet.submit(m, vec![i as f32; 4]).expect("admitted")
            })
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("answered");
    }

    assert_eq!(fleet.slo_restricted("slo"), Some(true));
    assert_eq!(fleet.slo_restricted("fixed"), Some(false));
    let slo_snap = fleet.snapshot("slo").unwrap();
    let fixed_snap = fleet.snapshot("fixed").unwrap();
    assert_eq!(
        slo_snap.max_batch_rows, 8,
        "SLO model must never exceed the admissible 8-bucket"
    );
    assert_eq!(
        fixed_snap.max_batch_rows, 32,
        "fixed model forms full 32-buckets under the same load"
    );
    // every accepted request was judged against the deadline
    assert_eq!(slo_snap.slo_hits + slo_snap.slo_misses, N as u64);
    // no SLO configured -> no judgments, hit-rate degrades to 1.0
    assert_eq!(fixed_snap.slo_hits + fixed_snap.slo_misses, 0);
    assert_eq!(fixed_snap.slo_hit_rate(), 1.0);
    fleet.shutdown();
}

/// The typed error surface: unknown model and shutdown reuse the
/// coordinator's `RouteError`, overload is its own variant, and all of
/// it converts into `anyhow::Result` via `?`.
#[test]
fn typed_errors_for_unknown_model_and_shutdown() {
    let mut fleet = Fleet::new();
    fleet.register(
        "real",
        FleetModelConfig { shards: 1, ..Default::default() },
        mock_factory(Duration::ZERO),
    );
    match fleet.submit("nope", vec![0.0; 4]) {
        Err(FleetError::Route(RouteError::UnknownModel { requested, registered })) => {
            assert_eq!(requested, "nope");
            assert_eq!(registered, vec!["real".to_string()]);
        }
        other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
    }

    // anyhow interop: the typed error flows through `?`
    fn try_submit_anyhow(fleet: &Fleet, model: &str) -> anyhow::Result<()> {
        let _rx = fleet.submit(model, vec![0.0; 4])?;
        Ok(())
    }
    let err = try_submit_anyhow(&fleet, "nope").unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");

    fleet.begin_shutdown();
    match fleet.submit("real", vec![0.0; 4]) {
        Err(FleetError::Route(RouteError::Shutdown { model })) => {
            assert_eq!(model, "real");
        }
        other => panic!("expected Shutdown, got {:?}", other.map(|_| ())),
    }
    fleet.shutdown();
}
