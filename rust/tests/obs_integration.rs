//! End-to-end observability tests (PR 6 acceptance):
//!
//! * serving metrics hold constant memory under 1M recorded latencies,
//! * concurrent histogram recording keeps exact totals,
//! * the trace ring drops oldest-first and counts every eviction,
//! * an engine-backed served request leaves a queue -> assemble ->
//!   per-layer trace whose Layer-span count matches the plan and whose
//!   Layer seconds sum (within tolerance) to the engine's busy time,
//! * the shutdown obs dump round-trips through `engine::json` carrying
//!   per-layer drift and per-edge repack attribution,
//! * the human report, JSON, and Prometheus renderings are three views
//!   of one `Snapshot` (field parity over `Snapshot::scalars`).

use std::sync::Arc;

use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::coordinator::Metrics;
use tcbnn::engine::json::Value;
use tcbnn::engine::{EngineModel, Planner};
use tcbnn::nn::forward::random_weights;
use tcbnn::nn::model::mnist_mlp;
use tcbnn::obs::{
    BatchTrace, LayerAttr, LogHistogram, RepackEdge, Snapshot, Span, SpanKind, TraceRing,
};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::Rng;

#[test]
fn metrics_memory_is_bounded_for_a_million_latencies() {
    let m = Metrics::new();
    let before = m.hist_footprint_bytes();
    // 8 distinct latencies per batch, 125k batches = 1M samples
    let lats = [8e-4f64, 9e-4, 1.0e-3, 1.1e-3, 1.2e-3, 1.3e-3, 1.6e-3, 3.1e-3];
    for _ in 0..125_000 {
        m.record_batch(8, 8, &lats);
    }
    assert_eq!(m.completed(), 1_000_000);
    assert_eq!(
        m.hist_footprint_bytes(),
        before,
        "latency store must not grow with request count"
    );
    assert!(before < 8192, "bounded store: {before} bytes");
    let s = m.latency_summary();
    assert_eq!(s.n, 1_000_000);
    // n/mean/min/max are exact; percentiles are bucket-resolution
    assert!((s.min - 8e-4).abs() < 1e-12, "min {}", s.min);
    assert!((s.max - 3.1e-3).abs() < 1e-12, "max {}", s.max);
    let true_mean = lats.iter().sum::<f64>() / 8.0;
    assert!((s.mean - true_mean).abs() < 1e-9, "mean {}", s.mean);
    // the true median sits between 1.1ms and 1.2ms; allow ~9% bucket
    // resolution on either side
    assert!(s.p50 >= 1.0e-3 && s.p50 <= 1.35e-3, "p50 {}", s.p50);
    assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
}

#[test]
fn concurrent_histogram_recording_keeps_exact_totals() {
    let h = LogHistogram::new();
    let threads = 8u64;
    let per = 10_000u64;
    std::thread::scope(|s| {
        for t in 0..threads {
            let h = &h;
            s.spawn(move || {
                // thread t records (t+1) milliseconds — whole-ns values,
                // so the integer sum is exact under any interleaving
                let secs = 1e-3 * (t + 1) as f64;
                for _ in 0..per {
                    h.record(secs);
                }
            });
        }
    });
    assert_eq!(h.count(), threads * per, "no increment lost");
    let want = per as f64 * 36.0 * 1e-3; // per * (1+..+8) ms
    assert!(
        (h.sum_secs() - want).abs() < 1e-9,
        "sum {} vs {want}",
        h.sum_secs()
    );
    let bucketed: u64 = h.nonzero_buckets().iter().map(|(_, _, c)| c).sum();
    assert_eq!(bucketed, threads * per, "every sample bucketed");
    assert_eq!(h.summary().n as u64, threads * per);
}

#[test]
fn trace_ring_overflow_drops_oldest_and_counts() {
    let ring = TraceRing::new(4);
    for seq in 0..10u64 {
        ring.push(BatchTrace {
            seq,
            ids: vec![seq],
            spans: vec![Span::queue(1e-6)],
        });
    }
    assert_eq!(ring.pushed(), 10);
    assert_eq!(ring.dropped(), 6, "every eviction counted");
    assert_eq!(ring.len(), 4, "never over capacity");
    let kept: Vec<u64> = ring.snapshot().iter().map(|t| t.seq).collect();
    assert_eq!(kept, vec![6, 7, 8, 9], "oldest evicted first");
    assert!(ring.find_request(0).is_none(), "evicted trace unfindable");
    assert!(ring.find_request(9).is_some());
}

#[test]
fn served_engine_requests_trace_queue_assembly_and_every_plan_layer() {
    let model = mnist_mlp();
    let n_layers = model.layers.len();
    let planner = Planner::new(&RTX2080TI);
    let mut rng = Rng::new(2024);
    let weights = random_weights(&model, &mut rng);
    let em = EngineModel::builder(&planner, &model, &weights)
        .buckets(vec![8])
        .build()
        .unwrap();
    let engine_metrics = em.metrics_handle();
    let stem = std::env::temp_dir()
        .join(format!("tcbnn-obs-e2e-{}", std::process::id()));
    let mut slot = Some(em);
    let srv = InferenceServer::start(
        ServerConfig { obs_dump: Some(stem.clone()), ..Default::default() },
        move || Ok(Box::new(slot.take().unwrap()) as Box<dyn BatchModel>),
    );
    let server_metrics = Arc::clone(&srv.metrics);
    let inputs: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let resps = srv.submit_all(inputs);
    assert_eq!(resps.len(), 16);

    // the batch trace: queue wait, assembly, then one span per layer
    let trace = server_metrics
        .traces()
        .find_request(0)
        .expect("request 0 traced");
    assert_eq!(trace.spans[0].kind, SpanKind::Queue);
    assert_eq!(trace.spans[1].kind, SpanKind::Assemble);
    assert!(trace.spans[1].bytes > 0, "assembly bytes recorded");
    let layer_spans: Vec<&Span> = trace
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Layer)
        .collect();
    assert_eq!(layer_spans.len(), n_layers, "one Layer span per plan layer");
    assert!(layer_spans.iter().all(|s| s.secs >= 0.0 && s.bytes > 0));
    assert!(
        layer_spans[0].label.contains("L0/"),
        "layer labels locate the plan: {}",
        layer_spans[0].label
    );

    // layer spans across all batches sum (within tolerance) to the
    // engine's busy time: the pass IS the busy time minus dispatch
    // overhead around the arena forward
    let total_layer_s: f64 = server_metrics
        .traces()
        .snapshot()
        .iter()
        .map(|t| t.layer_secs())
        .sum();
    let busy = engine_metrics.snapshot().engine_busy_s;
    assert!(busy > 0.0);
    assert!(total_layer_s > 0.0);
    assert!(
        total_layer_s <= busy * 1.05,
        "layer spans ({total_layer_s}s) cannot exceed busy time ({busy}s)"
    );
    assert!(
        total_layer_s >= busy * 0.1,
        "layer spans ({total_layer_s}s) must account for the bulk of \
         busy time ({busy}s)"
    );

    // shutdown writes the obs dump; it must round-trip with the
    // engine-side attribution grafted in
    srv.shutdown();
    let json_path = format!("{}.json", stem.display());
    let prom_path = format!("{}.prom", stem.display());
    let text = std::fs::read_to_string(&json_path).expect("obs dump written");
    let parsed = Value::parse(&text).expect("valid engine::json");
    let snap = Snapshot::from_json(&parsed).expect("snapshot shape");
    assert_eq!(snap.to_json(), parsed, "dump round-trips exactly");
    assert_eq!(snap.requests, 16);
    assert_eq!(snap.layers.len(), n_layers, "per-layer attribution grafted");
    assert!(
        snap.layers.iter().all(|l| l.calls == snap.batches),
        "every batch ran every layer: {:?} vs {} batches",
        snap.layers.iter().map(|l| l.calls).collect::<Vec<_>>(),
        snap.batches
    );
    assert!(snap.layers.iter().all(|l| l.drift() > 0.0));
    assert_eq!(snap.traces_pushed, snap.batches);
    let prom = std::fs::read_to_string(&prom_path).expect("prom written");
    assert!(prom.contains("tcbnn_requests_total 16"), "{prom}");
    assert!(prom.contains("tcbnn_layer_seconds_total{layer=\"0\""), "{prom}");
    let _ = std::fs::remove_file(&json_path);
    let _ = std::fs::remove_file(&prom_path);
}

#[test]
fn report_json_and_prometheus_are_three_renderings_of_one_snapshot() {
    let m = Metrics::new();
    m.record_batch(8, 8, &[1e-3; 8]);
    m.record_batch(3, 8, &[2e-3; 3]);
    m.record_engine_batch(16, 0.004);
    m.record_plan_cache(1, 2);
    m.record_replan();
    m.set_cost_drift(vec![("FASTPATH".to_string(), 1.5, 3)]);
    m.set_repacks(vec![("FASTPATH".to_string(), 2, 4096)]);
    m.set_layer_attribution(vec![LayerAttr {
        index: 0,
        tag: "1024FC".to_string(),
        scheme: "FASTPATH".to_string(),
        calls: 2,
        secs: 0.003,
        predicted_s: 0.001,
    }]);
    m.set_repack_edges(vec![RepackEdge {
        layer: 1,
        src: "Row32".to_string(),
        dst: "Blocked64".to_string(),
        ops: 2,
        bytes: 4096,
        secs: 2e-6,
    }]);
    m.traces().push(BatchTrace {
        seq: 1,
        ids: vec![0],
        spans: vec![Span::queue(1e-5)],
    });
    let snap = m.snapshot();

    // rendering 1: the human report is exactly the snapshot's rendering
    assert_eq!(m.report(), snap.render_report());

    // rendering 2: JSON carries every field (struct-level round trip)
    let back = Snapshot::from_json(&snap.to_json()).expect("parses back");
    assert_eq!(back, snap, "JSON loses no field");

    // rendering 3: Prometheus carries every scalar family with the
    // same value the snapshot holds
    let prom = snap.to_prometheus();
    for (name, value) in snap.scalars() {
        let line = format!("tcbnn_{name} {value}");
        assert!(prom.contains(&line), "prometheus missing {line:?}\n{prom}");
    }
    // ...and the labeled attribution families
    assert!(prom.contains(
        "tcbnn_layer_drift_ratio{layer=\"0\",tag=\"1024FC\",scheme=\"FASTPATH\"} 3"
    ));
    assert!(prom.contains(
        "tcbnn_repack_edge_bytes_total{layer=\"1\",src=\"Row32\",dst=\"Blocked64\"} 4096"
    ));
    assert!(prom.contains("tcbnn_cost_drift_ratio{scheme=\"FASTPATH\"} 1.5"));
}
