//! Property tests (via `util::proptest`) for the packing primitives and
//! the FSB format at awkward widths — especially non-multiple-of-32
//! widths, where pad-bit handling is easiest to get wrong.

use tcbnn::bitops::{
    pack, pack64, BitMatrix, BitMatrix64, FsbMatrix, Layout, SparseBitMatrix,
};
use tcbnn::layout::repack::{convert, BitImage};
use tcbnn::layout::LayoutKind;
use tcbnn::util::proptest::run_cases;

/// A width that is deliberately NOT a multiple of 32.
fn odd_width(rng: &mut tcbnn::util::Rng, max: usize) -> usize {
    loop {
        let n = 1 + rng.gen_range(max);
        if n % 32 != 0 {
            return n;
        }
    }
}

#[test]
fn pack_unpack_roundtrip_at_odd_widths() {
    run_cases(201, 200, |rng| {
        let n = odd_width(rng, 500);
        let xs = rng.pm1_vec(n);
        let packed = pack::pack_row(&xs);
        assert_eq!(packed.len(), n.div_ceil(32));
        assert_eq!(pack::unpack_row(&packed, n), xs);
        // pad bits of the tail word must be zero (-1 encoding)
        let rem = n % 32;
        assert_eq!(packed[n / 32] >> rem, 0, "tail pad bits set at n={n}");
    });
}

#[test]
fn pack_row_thresh_matches_scalar_rule() {
    run_cases(202, 100, |rng| {
        let n = odd_width(rng, 300);
        let xs: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let th: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let packed = pack::pack_row_thresh(&xs, &th);
        for i in 0..n {
            assert_eq!(
                pack::get_bit(&packed, i),
                xs[i] >= th[i],
                "bit {i} of {n}"
            );
        }
    });
}

#[test]
fn eq2_dot_correct_at_odd_widths() {
    // pm1_dot must agree with the float dot even when the last word is
    // partially filled (pad bits are 0 in BOTH operands and cancel)
    run_cases(203, 100, |rng| {
        let n = odd_width(rng, 400);
        let a = rng.pm1_vec(n);
        let b = rng.pm1_vec(n);
        let fdot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let pa = pack::pack_row(&a);
        let pb = pack::pack_row(&b);
        assert_eq!(pack::pm1_dot(&pa, &pb, n), fdot as i32);
    });
}

#[test]
fn set_get_bit_roundtrip_with_neighbours_intact() {
    run_cases(204, 100, |rng| {
        let n = odd_width(rng, 200);
        let mut words = vec![0u32; n.div_ceil(32)];
        let i = rng.gen_range(n);
        pack::set_bit(&mut words, i, true);
        assert!(pack::get_bit(&words, i));
        let total: u32 = words.iter().map(|w| w.count_ones()).sum();
        assert_eq!(total, 1, "exactly one bit set");
        pack::set_bit(&mut words, i, false);
        assert!(words.iter().all(|&w| w == 0));
    });
}

#[test]
fn pack64_roundtrip_at_odd_widths() {
    // u32 -> u64 -> u32 repacking must preserve every word, including
    // lines with an odd u32 word count (lone low half in the last u64)
    run_cases(209, 200, |rng| {
        let n = odd_width(rng, 600);
        let xs = rng.pm1_vec(n);
        let w32 = pack::pack_row(&xs);
        let mut w64 = vec![0u64; pack64::words64(w32.len())];
        pack64::repack64_into(&w32, &mut w64);
        let mut back = vec![0u32; w32.len()];
        pack64::unpack64_into(&w64, &mut back);
        assert_eq!(back, w32, "u32 word round-trip at n={n}");
        // and the u64 image sees the same logical bits
        for i in 0..n {
            assert_eq!(
                (w64[i / 64] >> (i % 64)) & 1 == 1,
                pack::get_bit(&w32, i),
                "bit {i} of {n}"
            );
        }
    });
}

#[test]
fn pack64_matrix_roundtrip_and_dot_agreement() {
    run_cases(210, 100, |rng| {
        let rows = 1 + rng.gen_range(30);
        let cols = odd_width(rng, 400);
        for layout in [Layout::RowMajor, Layout::ColMajor] {
            let m = BitMatrix::random(rows, cols, layout, rng);
            let m64 = BitMatrix64::from_bitmatrix(&m);
            assert_eq!(m64.to_bitmatrix(), m, "{rows}x{cols} {layout:?}");
        }
        // Eq 2 agrees across word sizes on odd widths
        let a = BitMatrix::random(2, cols, Layout::RowMajor, rng);
        let a64 = BitMatrix64::from_bitmatrix(&a);
        assert_eq!(
            pack64::pm1_dot64(a64.line(0), a64.line(1), cols),
            pack::pm1_dot(a.line(0), a.line(1), cols),
        );
    });
}

#[test]
fn pack64_fsb_normalizes_to_line_order() {
    run_cases(211, 60, |rng| {
        let rows = 1 + rng.gen_range(40);
        let cols = odd_width(rng, 300);
        let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        assert_eq!(BitMatrix64::from_fsb(&f), BitMatrix64::from_bitmatrix(&m));
    });
}

/// Wrap a random BitMatrix as a Row32 layout image.
fn random_image(rng: &mut tcbnn::util::Rng, lines: usize, bits: usize) -> BitImage {
    let m = BitMatrix::random(lines, bits, Layout::RowMajor, rng);
    BitImage::from_rows32(lines, bits, m.data)
}

#[test]
fn cross_layout_roundtrips_at_odd_widths() {
    // Row32 <-> Blocked64 <-> Fsb (and back) must reproduce every bit,
    // especially at non-multiple-of-32/64 widths where pad handling in
    // tail words / tail tiles is easiest to get wrong
    run_cases(212, 120, |rng| {
        let lines = 1 + rng.gen_range(40);
        let bits = odd_width(rng, 300);
        let img = random_image(rng, lines, bits);
        // single hops there and back
        for k in [LayoutKind::Blocked64, LayoutKind::Fsb, LayoutKind::Im2rowStaged] {
            let back = convert(&convert(&img, k), LayoutKind::Row32);
            assert_eq!(back, img, "{lines}x{bits} via {k}");
        }
        // the full chain Row32 -> Blocked64 -> Fsb -> Blocked64 -> Row32
        let chained = convert(
            &convert(
                &convert(&convert(&img, LayoutKind::Blocked64), LayoutKind::Fsb),
                LayoutKind::Blocked64,
            ),
            LayoutKind::Row32,
        );
        assert_eq!(chained, img, "{lines}x{bits} chained");
    });
}

#[test]
fn cross_layout_roundtrips_at_degenerate_shapes() {
    // 1xN and Nx1 images: a single line, and a single bit per line
    run_cases(213, 80, |rng| {
        let n = odd_width(rng, 400);
        for (lines, bits) in [(1, n), (n, 1)] {
            let img = random_image(rng, lines, bits);
            for (src, dst) in tcbnn::layout::repack::all_pairs() {
                let staged = convert(&convert(&img, src), dst);
                assert_eq!(staged.desc.kind, dst);
                assert_eq!(
                    convert(&staged, LayoutKind::Row32),
                    img,
                    "{lines}x{bits} via {src}->{dst}"
                );
            }
        }
    });
}

#[test]
fn cross_layout_conversion_is_invisible_to_eq2() {
    // converting operands through any layout chain never changes a dot
    // product — pad bits stay 0 in every representation
    run_cases(214, 60, |rng| {
        let k = odd_width(rng, 300);
        let a = BitMatrix::random(2, k, Layout::RowMajor, rng);
        let img = BitImage::from_rows32(2, k, a.data.clone());
        for kind in [LayoutKind::Blocked64, LayoutKind::Fsb, LayoutKind::Im2rowStaged] {
            let back = convert(&convert(&img, kind), LayoutKind::Row32);
            let words = match &back.words {
                tcbnn::layout::Words::W32(v) => v.clone(),
                _ => unreachable!("Row32 is u32-worded"),
            };
            let wpl = k.div_ceil(32);
            assert_eq!(
                pack::pm1_dot(&words[..wpl], &words[wpl..2 * wpl], k),
                pack::pm1_dot(a.line(0), a.line(1), k),
                "k={k} via {kind}"
            );
        }
    });
}

#[test]
fn sparse_csr_roundtrip_at_odd_widths() {
    // CSR-of-bit-lines <-> dense must be exact at widths that leave a
    // partially-filled tail block, via both the u32 and u64 routes
    run_cases(215, 120, |rng| {
        let rows = 1 + rng.gen_range(40);
        let cols = odd_width(rng, 400);
        let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
        let s = SparseBitMatrix::from_bitmatrix(&m);
        assert_eq!(s.to_bitmatrix(), m, "{rows}x{cols}");
        let m64 = BitMatrix64::from_bitmatrix(&m);
        assert_eq!(SparseBitMatrix::from_bitmatrix64(&m64), s, "{rows}x{cols} u64");
        assert_eq!(s.to_bitmatrix64(), m64);
        // representation canon: no stored zero blocks, sorted block
        // columns, and the round-tripped CSR is bit-for-bit identical
        assert!(s.bits.iter().all(|&b| b != 0), "zero block stored");
        for r in 0..rows {
            let (bc, _) = s.row_blocks(r);
            assert!(bc.windows(2).all(|w| w[0] < w[1]), "row {r} unsorted");
        }
        assert_eq!(SparseBitMatrix::from_bitmatrix(&s.to_bitmatrix()), s);
    });
}

#[test]
fn sparse_csr_handles_empty_and_full_rows() {
    // the degenerate row shapes: an all-zero row stores no blocks, an
    // all-ones row stores every block with a masked tail
    run_cases(216, 80, |rng| {
        let rows = 3 + rng.gen_range(20);
        let cols = odd_width(rng, 300);
        let mut m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
        let empty_r = rng.gen_range(rows);
        let full_r = (empty_r + 1) % rows;
        for c in 0..cols {
            m.set(empty_r, c, false);
            m.set(full_r, c, true);
        }
        let s = SparseBitMatrix::from_bitmatrix(&m);
        let (bc, _) = s.row_blocks(empty_r);
        assert!(bc.is_empty(), "empty row stored blocks at {rows}x{cols}");
        assert_eq!(s.row_degree(empty_r), 0);
        let (bc, bits) = s.row_blocks(full_r);
        assert_eq!(bc.len(), s.blocks_per_row(), "full row missing blocks");
        assert_eq!(s.row_degree(full_r) as usize, cols);
        // tail block: pad bits above `cols` must be zero
        let rem = cols % 64;
        if rem != 0 {
            let tail = *bits.last().unwrap();
            assert_eq!(tail >> rem, 0, "tail pad bits set at cols={cols}");
        }
        assert_eq!(s.to_bitmatrix(), m, "{rows}x{cols}");
    });
}

#[test]
fn sparse_csr_edges_and_density_are_consistent() {
    // edge-list construction agrees with dense conversion, and the
    // density/degree accounting matches a dense recount
    run_cases(217, 60, |rng| {
        let rows = 1 + rng.gen_range(30);
        let cols = odd_width(rng, 300);
        let n_edges = rng.gen_range(4 * rows + 1);
        let edges: Vec<(usize, usize)> = (0..n_edges)
            .map(|_| (rng.gen_range(rows), rng.gen_range(cols)))
            .collect();
        let s = SparseBitMatrix::from_edges(rows, cols, edges.iter().copied());
        let mut m = BitMatrix::zeros(rows, cols, Layout::RowMajor);
        for &(r, c) in &edges {
            m.set(r, c, true);
        }
        assert_eq!(s, SparseBitMatrix::from_bitmatrix(&m), "{rows}x{cols}");
        let total: usize = (0..rows).map(|r| s.row_degree(r) as usize).sum();
        assert_eq!(s.nnz_bits(), total);
        assert!(s.block_density() <= 1.0);
        assert!(s.nnz_blocks() <= rows * s.blocks_per_row());
    });
}

#[test]
fn fsb_roundtrip_at_odd_dims_row_major() {
    run_cases(205, 150, |rng| {
        let rows = odd_width(rng, 50);
        let cols = odd_width(rng, 300);
        let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        assert_eq!(f.to_bitmatrix(), m, "{rows}x{cols} row-major");
    });
}

#[test]
fn fsb_roundtrip_at_odd_dims_col_major() {
    run_cases(206, 150, |rng| {
        let rows = odd_width(rng, 300);
        let cols = odd_width(rng, 50);
        let m = BitMatrix::random(rows, cols, Layout::ColMajor, rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        assert_eq!(f.to_bitmatrix(), m, "{rows}x{cols} col-major");
    });
}

#[test]
fn fsb_preserves_every_logical_bit() {
    // spot-check individual logical entries through the tile reorder
    run_cases(207, 60, |rng| {
        let rows = 1 + rng.gen_range(40);
        let cols = odd_width(rng, 200);
        let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        let back = f.to_bitmatrix();
        for _ in 0..20 {
            let r = rng.gen_range(rows);
            let c = rng.gen_range(cols);
            assert_eq!(m.get(r, c), back.get(r, c), "({r},{c}) of {rows}x{cols}");
        }
    });
}

#[test]
fn fsb_padding_is_invisible_to_eq2() {
    // an FSB round-trip must never change a BMM result, including at
    // K widths that leave a partially-filled tail word
    run_cases(208, 40, |rng| {
        let m = 8 * (1 + rng.gen_range(3));
        let k = odd_width(rng, 300);
        let a = BitMatrix::random(m, k, Layout::RowMajor, rng);
        let b = BitMatrix::random(k, m, Layout::ColMajor, rng);
        let a2 = FsbMatrix::from_bitmatrix(&a).to_bitmatrix();
        let b2 = FsbMatrix::from_bitmatrix(&b).to_bitmatrix();
        for r in 0..m {
            for c in 0..m {
                assert_eq!(
                    pack::pm1_dot(a.line(r), b.line(c), k),
                    pack::pm1_dot(a2.line(r), b2.line(c), k),
                    "entry ({r},{c}) at k={k}"
                );
            }
        }
    });
}
