//! Cross-module property tests: every BMM/BConv scheme *compute* is
//! bit-exact against the float semantics, through random shapes and
//! the FSB format conversion.  (Backend-level equivalence — every
//! registered `KernelBackend` against the naive Eq-2/exclude-amended
//! references at odd shapes — lives in `backend_equivalence.rs`.)

use tcbnn::bitops::{BitMatrix, BitTensor4, FsbMatrix, Layout, TensorLayout};
use tcbnn::kernels::bconv::{self, BconvProblem};
use tcbnn::kernels::bmm::{self, BmmProblem, BmmScheme};
use tcbnn::kernels::IoMode;
use tcbnn::util::proptest::run_cases;
use tcbnn::util::Rng;

/// Float oracle: +/-1 matmul computed in f64.
fn float_bmm(a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
    let af = a.to_f32();
    let bf = b.to_f32();
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let mut out = vec![0i32; m * n];
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0.0f64;
            for i in 0..k {
                acc += (af[r * k + i] * bf[i * n + c]) as f64;
            }
            out[r * n + c] = acc as i32;
        }
    }
    out
}

#[test]
fn all_bmm_schemes_equal_float_semantics() {
    run_cases(101, 10, |rng| {
        let m = 8 * (1 + rng.gen_range(4));
        let n = 128 * (1 + rng.gen_range(2));
        let k = 128 * (1 + rng.gen_range(3));
        let a = BitMatrix::random(m, k, Layout::RowMajor, rng);
        let b = BitMatrix::random(k, n, Layout::ColMajor, rng);
        let want = float_bmm(&a, &b);
        let p = BmmProblem { m, n, k };
        for s in bmm::all_schemes() {
            if s.supports(p, IoMode::General) {
                assert_eq!(s.compute(&a, &b), want, "scheme {}", s.name());
            }
        }
    });
}

#[test]
fn fsb_conversion_never_changes_bmm_result() {
    run_cases(103, 20, |rng| {
        let m = 8 * (1 + rng.gen_range(3));
        let k = 32 * (1 + rng.gen_range(12)); // arbitrary word-aligned K
        let a = BitMatrix::random(m, k, Layout::RowMajor, rng);
        let b = BitMatrix::random(k, m, Layout::ColMajor, rng);
        // round-trip both operands through FSB, then multiply
        let a2 = FsbMatrix::from_bitmatrix(&a).to_bitmatrix();
        let b2 = FsbMatrix::from_bitmatrix(&b).to_bitmatrix();
        assert_eq!(bmm::naive_ref(&a, &b), bmm::naive_ref(&a2, &b2));
    });
}

#[test]
fn bconv_schemes_equal_exclude_semantics() {
    run_cases(105, 6, |rng| {
        let hw = 4 + rng.gen_range(4);
        let stride = 1 + rng.gen_range(2);
        let pad = rng.gen_range(2);
        let p = BconvProblem { hw, n: 8, c: 128, o: 8, k: 3, stride, pad };
        if hw + 2 * pad < 3 {
            return;
        }
        let input = BitTensor4::random([hw, hw, 8, 128], TensorLayout::Hwnc, rng);
        let filter = BitTensor4::random([3, 3, 8, 128], TensorLayout::Kkoc, rng);
        let want = bconv::naive_ref(&input, &filter, p);
        for s in bconv::all_schemes() {
            if s.supports(p, IoMode::General) {
                assert_eq!(s.compute(&input, &filter, p), want, "scheme {}", s.name());
            }
        }
    });
}

#[test]
fn binarized_output_roundtrip() {
    // compute_bin == threshold(compute) for the FSB design
    run_cases(107, 10, |rng| {
        let p = BmmProblem { m: 16, n: 128, k: 256 };
        let a = BitMatrix::random(p.m, p.k, Layout::RowMajor, rng);
        let b = BitMatrix::random(p.k, p.n, Layout::ColMajor, rng);
        let thresh: Vec<f32> =
            (0..p.n).map(|_| rng.next_normal() as f32 * 8.0).collect();
        let d3 = bmm::btc::Design3;
        let packed = d3.compute_bin(&a, &b, &thresh);
        let ints = d3.compute(&a, &b);
        for r in 0..p.m {
            for c in 0..p.n {
                assert_eq!(packed.get(r, c), (ints[r * p.n + c] as f32) >= thresh[c]);
            }
        }
    });
}

#[test]
fn simulated_time_is_positive_and_finite_everywhere() {
    use tcbnn::sim::{Engine, RTX2080, RTX2080TI};
    let mut rng = Rng::new(9);
    for gpu in [&RTX2080, &RTX2080TI] {
        let e = Engine::new(gpu);
        for _ in 0..8 {
            let n = 128 << rng.gen_range(6);
            let p = BmmProblem::square(n);
            for s in bmm::all_schemes() {
                for mode in [IoMode::General, IoMode::BnnSpecific] {
                    if s.supports(p, mode) {
                        let t = bmm::simulate(&e, s.as_ref(), p, mode);
                        assert!(
                            t.is_finite() && t > 0.0,
                            "{} {:?} n={n}: {t}",
                            s.name(),
                            mode
                        );
                    }
                }
            }
        }
    }
}
