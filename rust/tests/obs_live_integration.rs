//! Live observability-plane tests: a running `serve::Fleet` scraped
//! over real HTTP while it serves traffic.
//!
//! Covers the tentpole end to end — `/metrics` returns a
//! strictly-well-formed Prometheus exposition with windowed *and*
//! cumulative families, `/healthz` answers 200 while every shard is
//! healthy and flips to 503 within one watchdog period of a shard
//! stalling (and back once it recovers), `/snapshot.json` round-trips
//! through `Snapshot::from_json`, and the sampled JSONL trace log
//! decomposes every request's latency into parseable lines.
//!
//! The Prometheus validator below is deliberately strict (text-format
//! grammar, label escaping, cumulative `le` buckets ending at `+Inf`,
//! counter naming) so a renderer regression fails here before any
//! external scraper sees it.  Everything runs on MockModel — no GPU,
//! no network beyond loopback, no external crates.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use tcbnn::coordinator::server::{BatchModel, MockModel};
use tcbnn::engine::json::Value;
use tcbnn::obs::{
    http_get, render_prometheus_fleet, LayerAttr, ScrapeServer, ScrapeSource,
    Snapshot, TraceWriter, OBS_SCHEMA,
};
use tcbnn::serve::{Fleet, FleetModelConfig, WatchdogConfig};

fn mock_factory(
    delay: Duration,
) -> impl Fn() -> anyhow::Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static
{
    move || {
        Ok(Box::new(MockModel { row_elems: 4, out_elems: 3, delay })
            as Box<dyn BatchModel>)
    }
}

// ---------------------------------------------------------------------------
// A strict Prometheus text-format (0.0.4) validator.
// ---------------------------------------------------------------------------

fn is_name_char(c: char, first: bool) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':' || (!first && c.is_ascii_digit())
}

/// Parse one sample line `name[{k="v",...}] value` into its parts,
/// undoing label-value escapes (`\\`, `\"`, `\n`).  Rejects anything
/// off-grammar: bad names, bad escapes, unterminated label sets,
/// trailing tokens (timestamps), non-numeric values.
fn parse_sample(line: &str) -> Result<(String, Vec<(String, String)>, f64), String> {
    let mut chars = line.chars().peekable();
    let mut name = String::new();
    while let Some(&c) = chars.peek() {
        if is_name_char(c, name.is_empty()) {
            name.push(c);
            chars.next();
        } else {
            break;
        }
    }
    if name.is_empty() {
        return Err(format!("no metric name in {line:?}"));
    }
    let mut labels = Vec::new();
    if chars.peek() == Some(&'{') {
        chars.next();
        loop {
            let mut key = String::new();
            while let Some(&c) = chars.peek() {
                if is_name_char(c, key.is_empty()) {
                    key.push(c);
                    chars.next();
                } else {
                    break;
                }
            }
            if key.is_empty() {
                return Err(format!("empty label key in {line:?}"));
            }
            if chars.next() != Some('=') || chars.next() != Some('"') {
                return Err(format!("label {key:?} not followed by =\" in {line:?}"));
            }
            let mut val = String::new();
            loop {
                match chars.next() {
                    Some('\\') => match chars.next() {
                        Some('\\') => val.push('\\'),
                        Some('"') => val.push('"'),
                        Some('n') => val.push('\n'),
                        other => {
                            return Err(format!("bad escape {other:?} in {line:?}"))
                        }
                    },
                    Some('"') => break,
                    Some(c) => val.push(c),
                    None => {
                        return Err(format!("unterminated label value in {line:?}"))
                    }
                }
            }
            labels.push((key, val));
            match chars.next() {
                Some(',') => continue,
                Some('}') => break,
                other => {
                    return Err(format!(
                        "expected ',' or '}}' after label, got {other:?} in {line:?}"
                    ))
                }
            }
        }
    }
    if chars.next() != Some(' ') {
        return Err(format!("expected single space before value in {line:?}"));
    }
    let value: String = chars.collect();
    if value.is_empty() || value.contains(' ') {
        return Err(format!("expected exactly one value token in {line:?}"));
    }
    let v: f64 = value
        .parse()
        .map_err(|e| format!("non-numeric value {value:?} in {line:?}: {e}"))?;
    Ok((name, labels, v))
}

/// Serialize a label set minus `le` — the histogram series key.
fn series_key(labels: &[(String, String)]) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .filter(|(k, _)| k != "le")
        .map(|(k, v)| format!("{k}={v:?}"))
        .collect();
    parts.sort();
    parts.join(",")
}

/// Per-histogram-series accounting while its family block is open.
#[derive(Default)]
struct HistSeries {
    buckets: Vec<(String, f64)>,
    sum: bool,
    count: Option<f64>,
}

/// Close out a histogram family: every series needs cumulative
/// non-decreasing buckets ending at `le="+Inf"`, a `_sum`, and a
/// `_count` equal to the `+Inf` bucket.
fn finish_histogram(family: &str, series: &[(String, HistSeries)]) {
    assert!(!series.is_empty(), "{family}: histogram family with no samples");
    for (key, s) in series {
        assert!(
            !s.buckets.is_empty(),
            "{family}{{{key}}}: histogram series without buckets"
        );
        let mut prev = f64::NEG_INFINITY;
        for (le, cum) in &s.buckets {
            assert!(
                le.parse::<f64>().is_ok(),
                "{family}{{{key}}}: unparseable le={le:?}"
            );
            assert!(
                *cum >= prev,
                "{family}{{{key}}}: bucket counts not cumulative at le={le}"
            );
            prev = *cum;
        }
        let (last_le, last_cum) = s.buckets.last().unwrap();
        assert_eq!(last_le, "+Inf", "{family}{{{key}}}: buckets must end at +Inf");
        assert!(s.sum, "{family}{{{key}}}: missing _sum");
        assert_eq!(
            s.count,
            Some(*last_cum),
            "{family}{{{key}}}: _count must equal the +Inf bucket"
        );
    }
}

/// Assert `body` is a strictly-well-formed exposition: every line is
/// `# HELP`, `# TYPE`, or a sample; `# TYPE` immediately follows its
/// `# HELP` and names each family exactly once; every sample belongs
/// to the family block it appears under; counter families end in
/// `_total` with non-negative values; histogram families satisfy
/// [`finish_histogram`].  Returns the number of sample lines.
fn validate_prometheus(body: &str) -> usize {
    let mut seen_families: Vec<String> = Vec::new();
    let mut cur: Option<(String, String)> = None;
    let mut pending_help: Option<String> = None;
    let mut hist: Vec<(String, HistSeries)> = Vec::new();
    let mut samples = 0usize;

    let close_family = |cur: &Option<(String, String)>,
                            hist: &mut Vec<(String, HistSeries)>| {
        if let Some((fam, kind)) = cur {
            if kind == "histogram" {
                finish_histogram(fam, hist);
                hist.clear();
            }
        }
    };

    for line in body.lines() {
        assert!(!line.is_empty(), "blank line in exposition");
        if let Some(rest) = line.strip_prefix("# HELP ") {
            assert!(pending_help.is_none(), "two HELP lines in a row at {line:?}");
            let (name, text) =
                rest.split_once(' ').unwrap_or_else(|| panic!("bare HELP {line:?}"));
            assert!(!text.trim().is_empty(), "empty HELP text for {name}");
            pending_help = Some(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) =
                rest.split_once(' ').unwrap_or_else(|| panic!("bare TYPE {line:?}"));
            assert_eq!(
                pending_help.take().as_deref(),
                Some(name),
                "TYPE for {name} must directly follow its HELP"
            );
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind),
                "unknown TYPE {kind:?} for {name}"
            );
            assert!(
                name.starts_with("tcbnn_"),
                "family {name} outside the tcbnn namespace"
            );
            assert!(
                !seen_families.iter().any(|f| f == name),
                "family {name} declared twice"
            );
            if kind == "counter" {
                assert!(
                    name.ends_with("_total"),
                    "counter family {name} must end in _total"
                );
            }
            close_family(&cur, &mut hist);
            seen_families.push(name.to_string());
            cur = Some((name.to_string(), kind.to_string()));
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment line {line:?}");
        assert!(pending_help.is_none(), "sample between HELP and TYPE: {line:?}");
        let (name, labels, value) = parse_sample(line).unwrap();
        samples += 1;
        let (fam, kind) = cur
            .as_ref()
            .unwrap_or_else(|| panic!("sample {name} before any TYPE header"));
        match kind.as_str() {
            "histogram" => {
                let key = series_key(&labels);
                let idx = hist
                    .iter()
                    .position(|(k, _)| *k == key)
                    .unwrap_or_else(|| {
                        hist.push((key.clone(), HistSeries::default()));
                        hist.len() - 1
                    });
                let s = &mut hist[idx].1;
                if name == format!("{fam}_bucket") {
                    let le = labels
                        .iter()
                        .find(|(k, _)| k == "le")
                        .unwrap_or_else(|| panic!("bucket without le: {line:?}"));
                    s.buckets.push((le.1.clone(), value));
                } else if name == format!("{fam}_sum") {
                    s.sum = true;
                    assert!(value.is_finite(), "non-finite _sum: {line:?}");
                } else if name == format!("{fam}_count") {
                    s.count = Some(value);
                } else {
                    panic!("sample {name} inside histogram family {fam}");
                }
            }
            _ => {
                assert_eq!(&name, fam, "sample {name} under family {fam}");
                assert!(value.is_finite(), "non-finite value: {line:?}");
                if kind == "counter" {
                    assert!(value >= 0.0, "negative counter: {line:?}");
                }
            }
        }
        // `le="+Inf"` aside, buckets are finite; +Inf only ever appears
        // as a label value, never as a sample value in our renderer
        assert!(value.is_finite() || kind == "histogram", "bad value {line:?}");
    }
    assert!(pending_help.is_none(), "trailing HELP without TYPE");
    close_family(&cur, &mut hist);
    assert!(!seen_families.is_empty(), "empty exposition");
    samples
}

/// The value of the sample whose `name{labels}` prefix matches exactly.
fn sample_value(body: &str, name_and_labels: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        let rest = l.strip_prefix(name_and_labels)?;
        rest.strip_prefix(' ')?.parse().ok()
    })
}

// ---------------------------------------------------------------------------
// Tentpole: live fleet, real HTTP scrape, windowed + cumulative + health.
// ---------------------------------------------------------------------------

/// Serve two models, then scrape the running fleet over loopback HTTP:
/// `/metrics` must pass the strict validator and carry cumulative
/// counters, rolling-window gauges (10s and 60s), and watchdog health
/// for every shard; `/healthz` answers 200 with a healthy body; and
/// `/snapshot.json` is schema-v3 with per-model snapshots that
/// round-trip through `Snapshot::from_json`.
#[test]
fn live_fleet_scrape_serves_valid_prometheus_and_snapshots() {
    const N: usize = 200;
    let mut fleet = Fleet::new();
    for name in ["cifar", "mnist"] {
        fleet.register(
            name,
            FleetModelConfig {
                shards: 2,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            mock_factory(Duration::ZERO),
        );
    }
    let fleet = Arc::new(fleet);
    fleet.start_watchdog(WatchdogConfig::default());
    let scrape =
        ScrapeServer::start("127.0.0.1:0", Arc::clone(&fleet) as Arc<dyn ScrapeSource>)
            .expect("bind scrape server");
    let addr = scrape.local_addr();

    let rxs: Vec<_> = (0..N)
        .flat_map(|i| {
            ["cifar", "mnist"].map(|m| {
                fleet.submit(m, vec![i as f32, 1.0, 1.0, 1.0]).expect("admitted")
            })
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("answered");
    }
    // the watchdog probes immediately on spawn, but don't race it:
    // scrape only after its first report covers both models
    let deadline = Instant::now() + Duration::from_secs(10);
    while fleet.health_report().map_or(true, |r| r.models.len() < 2) {
        assert!(Instant::now() < deadline, "watchdog never published a report");
        thread::sleep(Duration::from_millis(5));
    }

    // /metrics: strict grammar over the whole live exposition
    let (code, metrics) = http_get(addr, "/metrics").expect("GET /metrics");
    assert_eq!(code, 200);
    let samples = validate_prometheus(&metrics);
    assert!(samples > 50, "suspiciously small exposition: {samples} samples");

    // cumulative counters per model
    for m in ["cifar", "mnist"] {
        assert_eq!(
            sample_value(&metrics, &format!("tcbnn_requests_total{{model=\"{m}\"}}")),
            Some(N as f64),
            "cumulative requests for {m}"
        );
    }
    // windowed gauges alongside them, both report windows, rate > 0
    let rps_10s = sample_value(
        &metrics,
        "tcbnn_window_requests_per_second{model=\"mnist\",window=\"10s\"}",
    )
    .expect("10s windowed rate sample");
    assert!(rps_10s > 0.0, "windowed rate must be live, got {rps_10s}");
    assert!(
        sample_value(
            &metrics,
            "tcbnn_window_requests_per_second{model=\"mnist\",window=\"60s\"}",
        )
        .is_some(),
        "60s window missing"
    );
    assert!(
        sample_value(
            &metrics,
            "tcbnn_window_requests{model=\"cifar\",window=\"10s\"}",
        )
        .unwrap_or(0.0)
            > 0.0,
        "windowed request count must be live"
    );
    // watchdog health grafted into the same exposition: every shard up
    for m in ["cifar", "mnist"] {
        for s in 0..2 {
            assert_eq!(
                sample_value(
                    &metrics,
                    &format!("tcbnn_shard_up{{model=\"{m}\",shard=\"{s}\"}}")
                ),
                Some(1.0),
                "{m} shard {s} should be up"
            );
        }
    }

    // /healthz: all healthy -> 200 with a machine-readable body
    let (code, health) = http_get(addr, "/healthz").expect("GET /healthz");
    assert_eq!(code, 200, "healthy fleet must answer 200: {health}");
    assert!(health.contains("\"healthy\":true"), "{health}");

    // /snapshot.json: schema v3, name-sorted models, full round-trip
    let (code, body) = http_get(addr, "/snapshot.json").expect("GET /snapshot.json");
    assert_eq!(code, 200);
    let doc = Value::parse(&body).expect("snapshot.json parses");
    assert_eq!(
        doc.get("schema").and_then(Value::as_usize),
        Some(OBS_SCHEMA as usize)
    );
    let models = doc.get("models").and_then(Value::as_arr).expect("models array");
    assert_eq!(models.len(), 2);
    assert_eq!(
        models[0].get("name").and_then(Value::as_str),
        Some("cifar"),
        "scrape output is name-sorted"
    );
    for entry in models {
        let snap = Snapshot::from_json(entry.get("snapshot").expect("snapshot"))
            .expect("per-model snapshot round-trips through from_json");
        assert_eq!(snap.requests, N as u64);
        assert_eq!(snap.windows.len(), 2, "both report windows serialized");
        assert_eq!(snap.health.len(), 2, "watchdog health serialized per shard");
        assert!(snap.health.iter().all(|h| h.is_up()));
    }

    scrape.shutdown();
    fleet.begin_shutdown();
}

// ---------------------------------------------------------------------------
// Watchdog: a stalled shard flips /healthz to 503 and recovers.
// ---------------------------------------------------------------------------

/// A MockModel whose `run_batch` spins while `gate` is set — a wedged
/// forward call, exactly what the heartbeat watchdog must catch.
struct StallableMock {
    inner: MockModel,
    gate: Arc<AtomicBool>,
}

impl BatchModel for StallableMock {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> anyhow::Result<Vec<f32>> {
        while self.gate.load(Ordering::Acquire) {
            thread::sleep(Duration::from_millis(1));
        }
        self.inner.run_batch(data, padded)
    }
    fn row_elems(&self) -> usize {
        self.inner.row_elems()
    }
    fn out_elems(&self) -> usize {
        self.inner.out_elems()
    }
    fn buckets(&self) -> Vec<usize> {
        self.inner.buckets()
    }
}

/// Poll `/healthz` until it answers `want` (or panic at the deadline).
fn await_healthz(addr: std::net::SocketAddr, want: u16, why: &str) -> String {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (code, body) = http_get(addr, "/healthz").expect("GET /healthz");
        if code == want {
            return body;
        }
        assert!(
            Instant::now() < deadline,
            "healthz never reached {want} within 20s ({why}); last: {code} {body}"
        );
        thread::sleep(Duration::from_millis(20));
    }
}

/// Wedge exactly one of two replicas mid-batch: `/healthz` must flip
/// to 503 naming a stalled shard within the watchdog's reaction time,
/// `/metrics` must stay scrapeable (with that shard's `shard_up` at 0
/// and a stall reason in `shard_health_state`), and clearing the wedge
/// must bring `/healthz` back to 200 with every request answered.
#[test]
fn stalled_shard_flips_healthz_to_503_and_recovers() {
    let gate = Arc::new(AtomicBool::new(false));
    let built = Arc::new(AtomicUsize::new(0));
    let factory = {
        let (gate, built) = (Arc::clone(&gate), Arc::clone(&built));
        move || {
            // only the first-built replica is gated; the sibling stays live
            let mine = if built.fetch_add(1, Ordering::SeqCst) == 0 {
                Arc::clone(&gate)
            } else {
                Arc::new(AtomicBool::new(false))
            };
            Ok(Box::new(StallableMock {
                inner: MockModel {
                    row_elems: 4,
                    out_elems: 3,
                    delay: Duration::ZERO,
                },
                gate: mine,
            }) as Box<dyn BatchModel>)
        }
    };
    let mut fleet = Fleet::new();
    fleet.register(
        "stall",
        FleetModelConfig {
            shards: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        factory,
    );
    let fleet = Arc::new(fleet);
    fleet.start_watchdog(WatchdogConfig {
        period: Duration::from_millis(25),
        stall_after: Duration::from_millis(200),
        // queue age and SLO must not trip: this test isolates heartbeats
        max_queue_age: Duration::from_secs(3600),
        max_slo_miss_rate: 2.0,
    });
    let scrape =
        ScrapeServer::start("127.0.0.1:0", Arc::clone(&fleet) as Arc<dyn ScrapeSource>)
            .expect("bind scrape server");
    let addr = scrape.local_addr();

    // warmup: both replicas built and serving -> healthy
    let warm: Vec<_> = (0..64)
        .map(|i| fleet.submit("stall", vec![i as f32; 4]).expect("admitted"))
        .collect();
    for rx in warm {
        rx.recv_timeout(Duration::from_secs(60)).expect("answered");
    }
    let body = await_healthz(addr, 200, "after warmup");
    assert!(body.contains("\"healthy\":true"), "{body}");

    // wedge the gated replica inside run_batch, then keep feeding work
    // until a batch lands on it (the live sibling may steal early
    // rounds — submission is round-robin, so it cannot starve forever)
    gate.store(true, Ordering::Release);
    let mut held = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    let body = loop {
        for i in 0..8 {
            held.push(fleet.submit("stall", vec![i as f32; 4]).expect("admitted"));
        }
        let (code, body) = http_get(addr, "/healthz").expect("GET /healthz");
        if code == 503 {
            break body;
        }
        assert!(
            Instant::now() < deadline,
            "healthz never flipped to 503 within 20s of the stall; last: {code} {body}"
        );
        thread::sleep(Duration::from_millis(20));
    };
    assert!(body.contains("\"healthy\":false"), "{body}");
    assert!(body.contains("stalled"), "503 body names the state: {body}");

    // metrics stay scrapeable during the stall, and name the dead shard
    let (code, metrics) = http_get(addr, "/metrics").expect("GET /metrics");
    assert_eq!(code, 200, "metrics must stay scrapeable during a stall");
    validate_prometheus(&metrics);
    let downs = metrics
        .lines()
        .filter(|l| l.starts_with("tcbnn_shard_up{model=\"stall\"") && l.ends_with(" 0"))
        .count();
    assert_eq!(downs, 1, "exactly the gated shard is down");
    assert!(
        metrics
            .lines()
            .any(|l| l.starts_with("tcbnn_shard_health_state{model=\"stall\"")
                && l.contains("state=\"stalled\"")
                && l.contains("no heartbeat")),
        "stall reason must be exported"
    );
    let report = fleet.health_report().expect("watchdog running");
    assert!(!report.all_up());

    // recovery: clear the wedge -> healthz returns to 200, no lost waiter
    gate.store(false, Ordering::Release);
    let body = await_healthz(addr, 200, "after clearing the stall");
    assert!(body.contains("\"healthy\":true"), "{body}");
    for rx in held {
        rx.recv_timeout(Duration::from_secs(60))
            .expect("held request answered after recovery");
    }

    scrape.shutdown();
    fleet.begin_shutdown();
}

// ---------------------------------------------------------------------------
// Request-scoped tracing: the sampled JSONL log.
// ---------------------------------------------------------------------------

/// With `sample_every = 1`, every request lands in the trace log as
/// one parseable JSON line carrying the full timing decomposition
/// (queue / steal / assemble / execute / e2e) plus batch context —
/// and every request id appears exactly once.
#[test]
fn sampled_trace_log_writes_parseable_jsonl() {
    const N: usize = 40;
    let path = std::env::temp_dir()
        .join(format!("tcbnn-obs-live-trace-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let trace = Arc::new(TraceWriter::create(&path, 1).expect("create trace log"));

    let mut fleet = Fleet::new();
    fleet.register(
        "traced",
        FleetModelConfig {
            shards: 1,
            max_wait: Duration::from_millis(1),
            trace: Some(Arc::clone(&trace)),
            ..Default::default()
        },
        mock_factory(Duration::ZERO),
    );
    let rxs: Vec<_> = (0..N)
        .map(|i| fleet.submit("traced", vec![i as f32; 4]).expect("admitted"))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("answered");
    }
    fleet.shutdown();
    trace.flush();

    assert_eq!(trace.seen(), N as u64);
    assert_eq!(trace.written(), N as u64, "sample_every=1 keeps every request");
    let text = std::fs::read_to_string(&path).expect("read trace log");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), N);
    let mut req_ids = Vec::new();
    for line in lines {
        let v = Value::parse(line).expect("JSONL line parses");
        assert_eq!(v.get("model").and_then(Value::as_str), Some("traced"));
        for key in ["req", "shard", "batch_seq", "rows", "padded", "steals"] {
            let x = v.get(key).and_then(Value::as_f64).unwrap_or_else(|| {
                panic!("missing integer field {key:?} in {line}")
            });
            assert!(x >= 0.0 && x.fract() == 0.0, "{key}={x} in {line}");
        }
        for key in ["queue_s", "assemble_s", "execute_s", "e2e_s"] {
            let x = v.get(key).and_then(Value::as_f64).unwrap_or_else(|| {
                panic!("missing timing field {key:?} in {line}")
            });
            assert!(x.is_finite() && x >= 0.0, "{key}={x} in {line}");
        }
        let rows = v.get("rows").and_then(Value::as_usize).unwrap();
        let padded = v.get("padded").and_then(Value::as_usize).unwrap();
        assert!(rows >= 1 && padded >= rows, "rows {rows} padded {padded}");
        assert!(v.get("batch_seq").and_then(Value::as_usize).unwrap() >= 1);
        req_ids.push(v.get("req").and_then(Value::as_usize).unwrap());
    }
    req_ids.sort_unstable();
    assert_eq!(
        req_ids,
        (0..N).collect::<Vec<_>>(),
        "every request traced exactly once"
    );
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Renderer escaping under the strict parser.
// ---------------------------------------------------------------------------

/// Label values containing backslash, double quote, and newline must
/// be escaped on the wire and recovered verbatim by the grammar's
/// unescape — the exposition as a whole still validating strictly.
#[test]
fn renderer_escapes_labels_and_survives_the_strict_parser() {
    let snap = Snapshot {
        requests: 1,
        layers: vec![LayerAttr {
            index: 0,
            tag: "we\"ird\\tag\nline".to_string(),
            scheme: "FASTPATH".to_string(),
            calls: 2,
            secs: 0.5,
            predicted_s: 0.25,
        }],
        ..Default::default()
    };
    let body = render_prometheus_fleet(&[("mo\"del\\one".to_string(), snap)]);
    validate_prometheus(&body);
    assert!(
        body.contains(r#"model="mo\"del\\one""#),
        "model label must be escaped on the wire:\n{body}"
    );
    assert!(
        body.contains(r#"tag="we\"ird\\tag\nline""#),
        "tag label must escape backslash, quote, and newline:\n{body}"
    );
    let line = body
        .lines()
        .find(|l| l.starts_with("tcbnn_layer_calls_total{"))
        .expect("layer sample rendered");
    let (name, labels, value) = parse_sample(line).expect("strict parse");
    assert_eq!(name, "tcbnn_layer_calls_total");
    assert_eq!(value, 2.0);
    assert!(
        labels.contains(&("model".to_string(), "mo\"del\\one".to_string())),
        "unescape recovers the raw model name"
    );
    assert!(
        labels.contains(&("tag".to_string(), "we\"ird\\tag\nline".to_string())),
        "unescape recovers the raw tag"
    );
}
