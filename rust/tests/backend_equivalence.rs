//! Backend-generic acceptance tests for the `KernelBackend` registry.
//!
//! 1. **Equivalence harness** — for EVERY registered backend,
//!    property-test bit-exact agreement with the naive Eq-2 BMM and
//!    the exclude-amended BConv reference on random odd shapes
//!    (non-multiple-of-32/64 widths, 1xN, Nx1).  This replaces the
//!    per-scheme test copies that used to live in
//!    `kernels_equivalence.rs` / `fastpath_equivalence.rs`: a new
//!    backend is covered the moment it registers.
//! 2. **SIMD engine sweep** — the registered `Scheme::Simd` backend
//!    runs whatever engine detection picked, so the registry pass
//!    alone can't prove the *other* dispatch paths; the sweep pins a
//!    `SimdBackend` to every `PopcountEngine::available()` and reruns
//!    the odd/1xN/Nx1 + bconv shapes per engine.
//! 3. **Registry extension proof** — a toy backend defined HERE, in a
//!    test crate, is registered over the builtin set and served end to
//!    end (planner -> executor -> coordinator) without touching any
//!    `match` on `Scheme` in `nn::forward`, `nn::cost`, or
//!    `engine::executor`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use tcbnn::bitops::{pack, BitMatrix, BitTensor4, Layout, SparseBitMatrix, TensorLayout};
use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::engine::{EngineExecutor, EngineModel, PlanPolicy, Planner};
use tcbnn::kernels::backend::{
    BackendRegistry, ExecCtx, KernelBackend, PreparedConv, PreparedFc,
};
use tcbnn::kernels::backends::scalar::{ScalarConv, ScalarFc};
use tcbnn::kernels::backends::simd::SimdBackend;
use tcbnn::kernels::backends::sparse::SparseBackend;
use tcbnn::kernels::bconv::{self, BconvProblem};
use tcbnn::kernels::simd::PopcountEngine;
use tcbnn::nn::forward::{forward, forward_with, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::mnist_mlp;
use tcbnn::nn::{ModelDef, ResidualMode, Scheme};
use tcbnn::sim::{Engine, KernelTrace, RTX2080TI};
use tcbnn::sparse::gcn_dense_reference;
use tcbnn::util::proptest::run_cases;
use tcbnn::util::Rng;

/// A width that is deliberately NOT a multiple of 64 (and usually not
/// of 32 either).
fn off64(rng: &mut Rng, max: usize) -> usize {
    loop {
        let n = 1 + rng.gen_range(max);
        if n % 64 != 0 {
            return n;
        }
    }
}

/// Naive Eq-2 FC reference: pm1 dot of every (input row, weight row).
fn naive_fc(a: &BitMatrix, w: &BitMatrix) -> Vec<i32> {
    let (batch, d_in, d_out) = (a.rows, a.cols, w.rows);
    assert_eq!(w.cols, d_in);
    let mut out = vec![0i32; batch * d_out];
    for bi in 0..batch {
        for j in 0..d_out {
            out[bi * d_out + j] = pack::pm1_dot(a.line(bi), w.line(j), d_in);
        }
    }
    out
}

fn run_fc_backend(b: &dyn KernelBackend, a: &BitMatrix, w: &BitMatrix) -> Vec<i32> {
    let batch = a.rows;
    let d_out = w.rows;
    let fc = b.prepare_fc(w).expect("prepare_fc");
    let mut scratch = vec![0u64; fc.scratch_words(batch)];
    let mut ints = vec![0i32; batch * d_out];
    let mut ctx = ExecCtx { words64: &mut scratch, threads: 2 };
    fc.bmm(&a.data, batch, &mut ints, &mut ctx);
    ints
}

#[test]
fn every_backend_fc_matches_naive_eq2_at_odd_shapes() {
    let reg = BackendRegistry::builtin();
    run_cases(501, 25, |rng| {
        let batch = 1 + rng.gen_range(20);
        let d_out = 1 + rng.gen_range(40);
        let d_in = off64(rng, 300);
        let a = BitMatrix::random(batch, d_in, Layout::RowMajor, rng);
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, rng);
        let want = naive_fc(&a, &w);
        for b in reg.backends() {
            assert_eq!(
                run_fc_backend(b, &a, &w),
                want,
                "{} at {batch}x{d_out}x{d_in}",
                b.name()
            );
        }
    });
}

#[test]
fn every_backend_fc_single_row_and_single_column() {
    let reg = BackendRegistry::builtin();
    run_cases(502, 15, |rng| {
        let n = 1 + rng.gen_range(120);
        let k = off64(rng, 260);
        // 1 x N
        let a = BitMatrix::random(1, k, Layout::RowMajor, rng);
        let w = BitMatrix::random(n, k, Layout::RowMajor, rng);
        let want = naive_fc(&a, &w);
        for b in reg.backends() {
            assert_eq!(run_fc_backend(b, &a, &w), want, "{} 1x{n}x{k}", b.name());
        }
        // N x 1
        let a = BitMatrix::random(n, k, Layout::RowMajor, rng);
        let w = BitMatrix::random(1, k, Layout::RowMajor, rng);
        let want = naive_fc(&a, &w);
        for b in reg.backends() {
            assert_eq!(run_fc_backend(b, &a, &w), want, "{} {n}x1x{k}", b.name());
        }
    });
}

#[test]
fn every_backend_bconv_matches_exclude_amended_ref_at_odd_shapes() {
    let reg = BackendRegistry::builtin();
    run_cases(503, 15, |rng| {
        let p = BconvProblem {
            hw: 3 + rng.gen_range(6),
            n: 1 + rng.gen_range(8),
            c: off64(rng, 140),
            o: 1 + rng.gen_range(24),
            k: 3,
            stride: 1 + rng.gen_range(2),
            pad: rng.gen_range(2),
        };
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, rng);
        let want = bconv::naive_ref(&input, &filter, p);
        for b in reg.backends() {
            let conv = b.prepare_conv(&filter, p).expect("prepare_conv");
            let mut scratch = vec![0u64; conv.scratch_words(p)];
            let mut ints = vec![0i32; p.out_elems()];
            let mut ctx = ExecCtx { words64: &mut scratch, threads: 2 };
            conv.bconv(&input.data, p, &mut ints, &mut ctx);
            assert_eq!(ints, want, "{} at {p:?}", b.name());
        }
    });
}

// ---------------------------------------------------------------------
// Sparse schemes: GCN aggregation + sparse-operand Eq-2 equivalence
// ---------------------------------------------------------------------

/// A random square adjacency with self-loops at roughly `avg_degree`
/// out-edges per node — sweeping `avg_degree` sweeps block density
/// across the planner's sparse-vs-dense crossover.
fn random_adj(rng: &mut Rng, nodes: usize, avg_degree: usize) -> SparseBitMatrix {
    let mut edges: Vec<(usize, usize)> = (0..nodes * avg_degree)
        .map(|_| (rng.gen_range(nodes), rng.gen_range(nodes)))
        .collect();
    edges.extend((0..nodes).map(|i| (i, i)));
    SparseBitMatrix::from_edges(nodes, nodes, edges)
}

#[test]
fn every_backend_gcn_matches_dense_reference_across_sparsities() {
    // EVERY registered backend must produce the bit-exact integer
    // semantics of sparse::gcn_dense_reference — the sparse backends
    // through their block-sparse override of prepare_gcn, everything
    // else through the default dense staging
    let reg = BackendRegistry::builtin();
    run_cases(508, 8, |rng| {
        let nodes = 8 + rng.gen_range(56);
        let d_in = 64 * (1 + rng.gen_range(2));
        let d_out = 64 * (1 + rng.gen_range(2));
        let batch = 1 + rng.gen_range(4);
        let avg_degree = 1 + rng.gen_range(nodes);
        let adj = random_adj(rng, nodes, avg_degree);
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, rng);
        let x = BitMatrix::random(batch, nodes * d_in, Layout::RowMajor, rng);
        let want = gcn_dense_reference(&adj, &w, &x);
        for b in reg.backends() {
            let g = b.prepare_gcn(&adj, &w).expect("prepare_gcn");
            let mut scratch = vec![0u64; g.scratch_words(batch)];
            let mut ints = vec![0i32; batch * nodes * d_out];
            let mut ctx = ExecCtx { words64: &mut scratch, threads: 2 };
            g.gcn(&x.data, batch, &mut ints, &mut ctx);
            assert_eq!(
                ints,
                want,
                "{} at nodes={nodes} deg~{avg_degree} {d_in}->{d_out} b{batch}",
                b.name()
            );
        }
    });
}

#[test]
fn sparse_schemes_gcn_matches_reference_at_density_extremes() {
    // the degenerate graphs: edgeless (every aggregate is exactly 0)
    // and complete (every stored block present, tail block masked)
    let (nodes, d, batch) = (40usize, 64usize, 2usize);
    let mut rng = Rng::new(510);
    let w = BitMatrix::random(d, d, Layout::RowMajor, &mut rng);
    let x = BitMatrix::random(batch, nodes * d, Layout::RowMajor, &mut rng);
    let empty = SparseBitMatrix::empty(nodes, nodes);
    let full = SparseBitMatrix::from_edges(
        nodes,
        nodes,
        (0..nodes).flat_map(|i| (0..nodes).map(move |j| (i, j))),
    );
    for adj in [&empty, &full] {
        let want = gcn_dense_reference(adj, &w, &x);
        if adj.nnz_blocks() == 0 {
            assert!(want.iter().all(|&v| v == 0), "edgeless aggregate nonzero");
        }
        for b in [SparseBackend::spmm(), SparseBackend::gcn_fused()] {
            let g = b.prepare_gcn(adj, &w).expect("prepare_gcn");
            let mut scratch = vec![0u64; g.scratch_words(batch)];
            let mut ints = vec![0i32; batch * nodes * d];
            let mut ctx = ExecCtx { words64: &mut scratch, threads: 2 };
            g.gcn(&x.data, batch, &mut ints, &mut ctx);
            assert_eq!(
                ints,
                want,
                "{} at density {:.2}",
                b.name(),
                adj.block_density()
            );
        }
    }
}

#[test]
fn sparse_backends_fc_matches_naive_eq2_at_controlled_sparsities() {
    // the sparse schemes double as Eq-2 FC providers (absent weight
    // blocks read as all -1); agreement must hold from near-empty to
    // dense weight rows, at odd widths
    run_cases(509, 15, |rng| {
        let batch = 1 + rng.gen_range(12);
        let d_out = 1 + rng.gen_range(40);
        let d_in = off64(rng, 300);
        let mut w = BitMatrix::zeros(d_out, d_in, Layout::RowMajor);
        let ones = rng.gen_range(d_out * d_in / 4 + 1);
        for _ in 0..ones {
            w.set(rng.gen_range(d_out), rng.gen_range(d_in), true);
        }
        let a = BitMatrix::random(batch, d_in, Layout::RowMajor, rng);
        let want = naive_fc(&a, &w);
        for b in [SparseBackend::spmm(), SparseBackend::gcn_fused()] {
            assert_eq!(
                run_fc_backend(&b, &a, &w),
                want,
                "{} at {batch}x{d_out}x{d_in} ({ones} +1 bits)",
                b.name()
            );
        }
    });
}

// ---------------------------------------------------------------------
// SIMD engine sweep: every available dispatch path, not just detection
// ---------------------------------------------------------------------

#[test]
fn every_simd_engine_fc_matches_naive_eq2_at_odd_shapes() {
    let backends: Vec<SimdBackend> =
        PopcountEngine::available().into_iter().map(SimdBackend::with_engine).collect();
    run_cases(504, 20, |rng| {
        let batch = 1 + rng.gen_range(20);
        let d_out = 1 + rng.gen_range(40);
        let d_in = off64(rng, 300);
        let a = BitMatrix::random(batch, d_in, Layout::RowMajor, rng);
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, rng);
        let want = naive_fc(&a, &w);
        for b in &backends {
            assert_eq!(
                run_fc_backend(b, &a, &w),
                want,
                "engine {} at {batch}x{d_out}x{d_in}",
                b.engine().name()
            );
        }
    });
}

#[test]
fn every_simd_engine_fc_single_row_and_single_column() {
    let backends: Vec<SimdBackend> =
        PopcountEngine::available().into_iter().map(SimdBackend::with_engine).collect();
    run_cases(505, 10, |rng| {
        let n = 1 + rng.gen_range(120);
        let k = off64(rng, 260);
        for (rows, cols) in [(1, n), (n, 1)] {
            let a = BitMatrix::random(rows, k, Layout::RowMajor, rng);
            let w = BitMatrix::random(cols, k, Layout::RowMajor, rng);
            let want = naive_fc(&a, &w);
            for b in &backends {
                assert_eq!(
                    run_fc_backend(b, &a, &w),
                    want,
                    "engine {} {rows}x{cols}x{k}",
                    b.engine().name()
                );
            }
        }
    });
}

#[test]
fn every_simd_engine_bconv_matches_exclude_amended_ref() {
    let backends: Vec<SimdBackend> =
        PopcountEngine::available().into_iter().map(SimdBackend::with_engine).collect();
    run_cases(506, 10, |rng| {
        let p = BconvProblem {
            hw: 3 + rng.gen_range(6),
            n: 1 + rng.gen_range(8),
            c: off64(rng, 140),
            o: 1 + rng.gen_range(24),
            k: 3,
            stride: 1 + rng.gen_range(2),
            pad: rng.gen_range(2),
        };
        let input = BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, rng);
        let filter = BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, rng);
        let want = bconv::naive_ref(&input, &filter, p);
        for b in &backends {
            let conv = b.prepare_conv(&filter, p).expect("prepare_conv");
            let mut scratch = vec![0u64; conv.scratch_words(p)];
            let mut ints = vec![0i32; p.out_elems()];
            let mut ctx = ExecCtx { words64: &mut scratch, threads: 2 };
            conv.bconv(&input.data, p, &mut ints, &mut ctx);
            assert_eq!(ints, want, "engine {} at {p:?}", b.engine().name());
        }
    });
}

#[test]
fn simd_bmm64_native_layout_path_matches_the_repack_path() {
    // the planner chains Blocked64 edges into bmm64; it must agree
    // with the Row32 bmm path for every engine
    let backends: Vec<SimdBackend> =
        PopcountEngine::available().into_iter().map(SimdBackend::with_engine).collect();
    run_cases(507, 10, |rng| {
        let batch = 1 + rng.gen_range(16);
        let d_out = 1 + rng.gen_range(40);
        let d_in = off64(rng, 300);
        let a = BitMatrix::random(batch, d_in, Layout::RowMajor, rng);
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, rng);
        let a64 = tcbnn::bitops::pack64::BitMatrix64::from_bitmatrix(&a);
        for b in &backends {
            let fc = b.prepare_fc(&w).expect("prepare_fc");
            let via_row32 = run_fc_backend(b, &a, &w);
            let mut scratch = vec![0u64; fc.scratch_words(batch)];
            let mut ints = vec![0i32; batch * d_out];
            let mut ctx = ExecCtx { words64: &mut scratch, threads: 2 };
            fc.bmm64(&a64.data, batch, &mut ints, &mut ctx);
            assert_eq!(ints, via_row32, "engine {}", b.engine().name());
        }
    });
}

// ---------------------------------------------------------------------
// Registry extension proof: the toy backend
// ---------------------------------------------------------------------

static TOY_PREPARES: AtomicUsize = AtomicUsize::new(0);
static TOY_KERNEL_CALLS: AtomicUsize = AtomicUsize::new(0);

/// A test-only backend registered over `Scheme::Sbnn32`: execution
/// delegates to the shared scalar kernels (so results stay bit-exact)
/// while counting invocations, and the cost face claims to be
/// essentially free so the planner must pick it for every layer.
struct ToyBackend;

struct ToyFc(ScalarFc);

impl PreparedFc for ToyFc {
    fn scratch_words(&self, batch: usize) -> usize {
        self.0.scratch_words(batch)
    }
    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        TOY_KERNEL_CALLS.fetch_add(1, Ordering::SeqCst);
        self.0.bmm(src, batch, ints, ctx)
    }
}

struct ToyConv(ScalarConv);

impl PreparedConv for ToyConv {
    fn scratch_words(&self, p: BconvProblem) -> usize {
        self.0.scratch_words(p)
    }
    fn bconv(&self, src: &[u32], p: BconvProblem, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        TOY_KERNEL_CALLS.fetch_add(1, Ordering::SeqCst);
        self.0.bconv(src, p, ints, ctx)
    }
}

impl KernelBackend for ToyBackend {
    fn scheme(&self) -> Scheme {
        Scheme::Sbnn32
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        TOY_PREPARES.fetch_add(1, Ordering::SeqCst);
        Ok(Box::new(ToyFc(ScalarFc::new(w))))
    }

    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        _p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        TOY_PREPARES.fetch_add(1, Ordering::SeqCst);
        Ok(Box::new(ToyConv(ScalarConv::new(filter))))
    }

    fn layer_traces(
        &self,
        _layer: &LayerSpec,
        _dims: Dims,
        _batch: usize,
        _residual: ResidualMode,
        _model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        Vec::new()
    }

    /// Essentially free: the planner must rank the toy first everywhere.
    fn layer_secs(
        &self,
        _engine: &Engine,
        _layer: &LayerSpec,
        _dims: Dims,
        _batch: usize,
        _residual: ResidualMode,
        _model_has_residuals: bool,
    ) -> f64 {
        1e-12
    }
}

fn toy_conv_model() -> ModelDef {
    ModelDef {
        name: "toy-backend-conv",
        dataset: "synthetic",
        input: Dims { hw: 8, feat: 3 },
        classes: 5,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 40, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 40,
                o: 40,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 40, d_out: 72 },
            LayerSpec::FinalFc { d_in: 72, d_out: 5 },
        ],
        residual_blocks: 0,
    }
}

/// The toy registry: builtin backends with the toy registered over
/// `Scheme::Sbnn32`.
fn toy_registry() -> Arc<BackendRegistry> {
    let mut reg = BackendRegistry::builtin();
    reg.register(Box::new(ToyBackend));
    Arc::new(reg)
}

#[test]
fn toy_backend_wins_the_plan_and_executes_bit_exactly() {
    let reg = toy_registry();
    let planner = Planner::with_registry(&RTX2080TI, Arc::clone(&reg));
    let m = toy_conv_model();
    let batch = 8;

    // the planner must hand every layer to the (free) toy backend
    let plan = planner.plan(&m, batch);
    for lp in &plan.layers {
        assert_eq!(lp.scheme, Scheme::Sbnn32, "layer {} not routed to toy", lp.tag);
    }

    // executor prepares through the toy and stays bit-identical to the
    // registry-less reference forward
    let mut rng = Rng::new(601);
    let w = random_weights(&m, &mut rng);
    let x: Vec<f32> =
        (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
    let want = forward(&m, &w, &x, batch);

    let prepares_before = TOY_PREPARES.load(Ordering::SeqCst);
    let mut exec = EngineExecutor::with_registry(m.clone(), &w, plan, &reg).unwrap();
    assert!(
        TOY_PREPARES.load(Ordering::SeqCst) > prepares_before,
        "executor must prepare weights through the toy backend"
    );
    let calls_before = TOY_KERNEL_CALLS.load(Ordering::SeqCst);
    assert_eq!(exec.forward(&x, batch), &want[..]);
    assert!(
        TOY_KERNEL_CALLS.load(Ordering::SeqCst) > calls_before,
        "the toy kernels must actually run"
    );

    // the reference forward also routes through the registry
    assert_eq!(forward_with(&m, &w, &x, batch, &reg, Scheme::Sbnn32), want);
}

/// Acceptance: the toy backend served end to end through
/// `coordinator::server`, logits identical to the builtin engine.
#[test]
fn toy_backend_served_through_coordinator() {
    let m = mnist_mlp();
    let mut rng = Rng::new(602);
    let weights = random_weights(&m, &mut rng);

    // ground truth from the builtin-registry engine
    let planner = Planner::new(&RTX2080TI);
    let mut builtin = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8])
        .build()
        .unwrap();
    let n = 24usize;
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let mut want = Vec::new();
    for x in &inputs {
        let mut padded = Vec::with_capacity(8 * 784);
        for _ in 0..8 {
            padded.extend_from_slice(x);
        }
        let out = builtin.run_batch(&padded, 8).unwrap();
        want.push(out[..10].to_vec());
    }

    let calls_before = TOY_KERNEL_CALLS.load(Ordering::SeqCst);
    let m2 = m.clone();
    let srv = InferenceServer::start(
        ServerConfig {
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            ..Default::default()
        },
        move || {
            let planner = Planner::with_registry(&RTX2080TI, toy_registry());
            // Search policy: the toy's free cost face must win the plan
            Ok(Box::new(
                EngineModel::builder(&planner, &m2, &weights)
                    .buckets(vec![8])
                    .policy(PlanPolicy::Search)
                    .build()?,
            ) as Box<dyn BatchModel>)
        },
    );
    let resps = srv.submit_all(inputs);
    assert_eq!(resps.len(), n);
    for (i, r) in resps.iter().enumerate() {
        assert_eq!(r.logits, want[i], "request {i} logits");
    }
    assert_eq!(srv.metrics.completed(), n as u64);
    assert!(
        TOY_KERNEL_CALLS.load(Ordering::SeqCst) > calls_before,
        "served batches must run on the toy backend"
    );
}
