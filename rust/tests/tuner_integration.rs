//! Tuner acceptance tests (ISSUE 4): measured calibration corrects a
//! deliberately wrong analytic cost model, the live feedback loop
//! re-plans a served model onto the genuinely faster backend within a
//! bounded number of batches, and executor outputs stay bit-identical
//! across every plan change.
//!
//! The cast: two synthetic *host* backends (empty GPU trace faces)
//! registered over existing scheme keys, both executing through the
//! shared scalar kernels (so results are bit-exact everywhere):
//!
//! * `LiarBackend` (over `Scheme::Sbnn32`) — its analytic cost face
//!   claims it is the cheapest backend alive, but every kernel call
//!   spins for ~250us.  `CostSource::Analytic` mis-ranks it first.
//! * `HonestBackend` (over `Scheme::Sbnn64`) — claims a cost in the
//!   right order of magnitude and executes at plain scalar speed.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tcbnn::bitops::{BitMatrix, BitTensor4};
use tcbnn::coordinator::server::BatchModel;
use tcbnn::engine::{EngineModel, PlanCache, Planner};
use tcbnn::kernels::backend::{
    BackendRegistry, ExecCtx, KernelBackend, PreparedConv, PreparedFc,
};
use tcbnn::kernels::backends::scalar::{ScalarConv, ScalarFc};
use tcbnn::kernels::bconv::BconvProblem;
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::{ModelDef, ResidualMode, Scheme};
use tcbnn::sim::{Engine, KernelTrace, RTX2080TI};
use tcbnn::tuner::{
    fit_profile, layer_features, microbench, CalibrationProfile, CostSource,
    HostFingerprint, LiveCosts, MicrobenchConfig, SchemeCoeffs,
};
use tcbnn::util::Rng;

/// Busy-wait (not sleep: sleeps are imprecise at this scale and the
/// point is to burn measurable compute time).
fn spin(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

const LIAR_SPIN: Duration = Duration::from_micros(250);

struct SpinFc {
    inner: ScalarFc,
    spin: Duration,
}

impl PreparedFc for SpinFc {
    fn scratch_words(&self, batch: usize) -> usize {
        self.inner.scratch_words(batch)
    }
    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        spin(self.spin);
        self.inner.bmm(src, batch, ints, ctx)
    }
}

struct SpinConv {
    inner: ScalarConv,
    spin: Duration,
}

impl PreparedConv for SpinConv {
    fn scratch_words(&self, p: BconvProblem) -> usize {
        self.inner.scratch_words(p)
    }
    fn bconv(&self, src: &[u32], p: BconvProblem, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        spin(self.spin);
        self.inner.bconv(src, p, ints, ctx)
    }
}

/// A synthetic host backend: scalar execution plus an optional per-call
/// spin, and an analytic cost face scaled by `claim_word_secs` /
/// `claim_dispatch` — set those low and it lies, set them honestly and
/// it tells the truth.
struct SyntheticBackend {
    scheme: Scheme,
    spin: Duration,
    claim_word_secs: f64,
    claim_dispatch: f64,
}

impl KernelBackend for SyntheticBackend {
    fn scheme(&self) -> Scheme {
        self.scheme
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        Ok(Box::new(SpinFc { inner: ScalarFc::new(w), spin: self.spin }))
    }

    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        _p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        Ok(Box::new(SpinConv { inner: ScalarConv::new(filter), spin: self.spin }))
    }

    /// Host backend: no GPU trace face (what makes it calibratable).
    fn layer_traces(
        &self,
        _layer: &LayerSpec,
        _dims: Dims,
        _batch: usize,
        _residual: ResidualMode,
        _model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        Vec::new()
    }

    fn layer_secs(
        &self,
        _engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        let f = layer_features(layer, dims, batch, residual, model_has_residuals);
        f.word_ops * self.claim_word_secs + f.fp_ops * 1e-10 + self.claim_dispatch
    }
}

/// Liar: claims to be ~free, actually spins 250us per kernel call.
fn liar() -> Box<dyn KernelBackend> {
    Box::new(SyntheticBackend {
        scheme: Scheme::Sbnn32,
        spin: LIAR_SPIN,
        claim_word_secs: 1e-13,
        claim_dispatch: 1e-9,
    })
}

/// Honest: right order of magnitude for scalar host execution.
fn honest() -> Box<dyn KernelBackend> {
    Box::new(SyntheticBackend {
        scheme: Scheme::Sbnn64,
        spin: Duration::ZERO,
        claim_word_secs: 1e-9,
        claim_dispatch: 5e-6,
    })
}

/// Two-backend registry: the liar and the honest backend only.
fn registry() -> Arc<BackendRegistry> {
    let mut reg = BackendRegistry::empty();
    reg.register(liar());
    reg.register(honest());
    Arc::new(reg)
}

/// A small flat-input MLP (every layer backend-dispatched).
fn tuner_mlp() -> ModelDef {
    ModelDef {
        name: "tuner-test-mlp",
        dataset: "synthetic",
        input: Dims { hw: 0, feat: 256 },
        classes: 10,
        layers: vec![
            LayerSpec::BinFc { d_in: 256, d_out: 128 },
            LayerSpec::BinFc { d_in: 128, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

/// Acceptance (calibration): the liar wins every layer under
/// `CostSource::Analytic`; after a measured calibration pass the
/// ranking flips to the honest backend — exactly the paper's "the
/// winning kernel is not analytically obvious" lesson.
#[test]
fn calibration_corrects_a_misranked_backend() {
    let reg = registry();
    let m = tuner_mlp();

    // 1. analytic mis-ranking: the liar's claimed costs win everywhere
    let analytic_planner = Planner::with_registry(&RTX2080TI, Arc::clone(&reg));
    let analytic_plan = analytic_planner.plan(&m, 8);
    for lp in &analytic_plan.layers {
        assert_eq!(
            lp.scheme,
            Scheme::Sbnn32,
            "analytic source must mis-rank the liar first on {}",
            lp.tag
        );
    }

    // 2. calibrate: measure both synthetic backends on the real grid
    let cfg = MicrobenchConfig { quick: true, seed: 5, threads: 1 };
    let measurements = microbench::run(&reg, &cfg);
    assert!(
        measurements.iter().any(|x| x.scheme == Scheme::Sbnn32)
            && measurements.iter().any(|x| x.scheme == Scheme::Sbnn64),
        "both synthetic backends are host backends and must be measured"
    );
    // (no repack measurements needed here — the scheme ranking is what
    // this test exercises; repack fitting is covered elsewhere)
    let profile = fit_profile(
        HostFingerprint::detect_with_cores(&reg, cfg.threads),
        &measurements,
        &[],
    );
    let liar_coeffs = profile.coeffs(Scheme::Sbnn32).expect("liar fitted");
    let honest_coeffs = profile.coeffs(Scheme::Sbnn64).expect("honest fitted");
    // the spin shows up as a huge fitted dispatch constant
    assert!(
        liar_coeffs.dispatch_secs > honest_coeffs.dispatch_secs * 5.0,
        "liar dispatch {:.1}us vs honest {:.1}us",
        liar_coeffs.dispatch_secs * 1e6,
        honest_coeffs.dispatch_secs * 1e6
    );

    // 3. calibrated ranking: the honest backend wins every layer
    let calibrated_planner = Planner::with_registry(&RTX2080TI, Arc::clone(&reg))
        .with_cost_source(CostSource::Calibrated(Arc::new(profile)));
    let calibrated_plan = calibrated_planner.plan(&m, 8);
    for lp in &calibrated_plan.layers {
        assert_eq!(
            lp.scheme,
            Scheme::Sbnn64,
            "calibration must rank the honest backend first on {}",
            lp.tag
        );
    }
    // the two plans are cache-distinguishable by construction
    assert_ne!(analytic_plan.cost_profile, calibrated_plan.cost_profile);
}

/// Acceptance (live loop): a served `EngineModel` under
/// `CostSource::Live` starts on the liar (the prior slightly favors
/// it), observes the measured latencies, and re-plans onto the honest
/// backend within a bounded number of batches — with every output
/// bit-identical across the re-plan.
#[test]
fn live_feedback_replans_onto_the_faster_backend() {
    let reg = registry();
    let m = tuner_mlp();
    let mut rng = Rng::new(901);
    let weights = random_weights(&m, &mut rng);

    // a stale/wrong prior: liar slightly cheaper than honest, both in
    // the plausible-host range — but the liar actually spins 250us/call
    let prior = Arc::new(CalibrationProfile {
        fingerprint: HostFingerprint::detect(&reg),
        schemes: vec![
            (
                "SBNN-32".to_string(),
                SchemeCoeffs {
                    secs_per_word_op: 5e-10,
                    secs_per_sparse_block: 0.0,
                    secs_per_byte: 0.0,
                    dispatch_secs: 1e-6,
                    secs_per_fp_op: 1e-10,
                    samples: 4,
                    gcn_samples: 0,
                    rel_rmse: 0.0,
                },
            ),
            (
                "SBNN-64".to_string(),
                SchemeCoeffs {
                    secs_per_word_op: 1e-9,
                    secs_per_sparse_block: 0.0,
                    secs_per_byte: 0.0,
                    dispatch_secs: 2e-6,
                    secs_per_fp_op: 1e-10,
                    samples: 4,
                    gcn_samples: 0,
                    rel_rmse: 0.0,
                },
            ),
        ],
        repacks: Vec::new(),
    });
    let live = Arc::new(LiveCosts::new());
    let planner = Planner::with_registry(&RTX2080TI, Arc::clone(&reg))
        .with_cost_source(CostSource::Live {
            prior: Arc::clone(&prior),
            live: Arc::clone(&live),
        });
    let mut em = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8])
        .build()
        .unwrap();
    for lp in &em.plan().layers {
        assert_eq!(lp.scheme, Scheme::Sbnn32, "prior must favor the liar first");
    }

    let x: Vec<f32> = (0..8 * 256).map(|_| rng.next_f32() - 0.5).collect();
    let want = forward(&m, &weights, &x, 8);
    let mut switched_at = None;
    const BOUND: usize = 10;
    for batch_no in 0..BOUND {
        let out = em.run_batch(&x, 8).unwrap();
        assert_eq!(out, want, "batch {batch_no}: outputs must stay bit-identical");
        if switched_at.is_none()
            && em.plan().layers.iter().all(|lp| lp.scheme == Scheme::Sbnn64)
        {
            switched_at = Some(batch_no);
        }
    }
    let switched_at = switched_at.unwrap_or_else(|| {
        panic!(
            "live loop did not re-plan onto the honest backend within {BOUND} \
             batches (drift {:?})",
            em.metrics.cost_drift()
        )
    });
    assert!(em.metrics.replans() >= 1, "re-plan must be counted in metrics");
    assert!(
        !em.metrics.cost_drift().is_empty(),
        "drift snapshot must surface through metrics"
    );
    // bounded: min_samples=2 + per-batch checks put the flip within the
    // first few batches; 10 is the generous ceiling
    assert!(switched_at < BOUND);
    // and it keeps serving identically after the switch
    assert_eq!(em.run_batch(&x, 8).unwrap(), want);
}

/// Acceptance (cache invalidation): plans cached under one calibration
/// profile are stale for a planner using another (or the analytic
/// source), and the profile artifact itself lives next to the cache.
#[test]
fn plan_cache_invalidates_across_cost_profiles() {
    let reg = registry();
    let m = tuner_mlp();
    let dir = std::env::temp_dir()
        .join(format!("tcbnn_tuner_it_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PlanCache::open(&dir).unwrap();

    let analytic = Planner::with_registry(&RTX2080TI, Arc::clone(&reg));
    let cfg = MicrobenchConfig { quick: true, seed: 5, threads: 1 };
    let profile = Arc::new(fit_profile(
        HostFingerprint::detect_with_cores(&reg, cfg.threads),
        &microbench::run(&reg, &cfg),
        &microbench::run_repacks(&cfg),
    ));
    let calibrated = Planner::with_registry(&RTX2080TI, Arc::clone(&reg))
        .with_cost_source(CostSource::Calibrated(Arc::clone(&profile)));

    // persist the profile where a serving process would find it
    profile.save(cache.profile_path()).unwrap();
    let reloaded = CalibrationProfile::load(cache.profile_path()).unwrap();
    assert_eq!(reloaded.id(), profile.id());
    // the fingerprint records the parallelism the benches ran with
    // (threads: 1 above), NOT the host default — a profile measured at
    // a different worker count must not validate as matching
    assert_eq!(reloaded.fingerprint.cores, cfg.threads);
    assert_eq!(
        reloaded.fingerprint.matches_host(&reg),
        cfg.threads == tcbnn::util::threadpool::default_threads(),
    );

    // analytic entry, then the calibrated planner must re-plan (miss)
    let a1 = cache.get_or_plan(&analytic, &m, 8);
    assert_eq!((cache.hits(), cache.misses()), (0, 1));
    let c1 = cache.get_or_plan(&calibrated, &m, 8);
    assert_eq!((cache.hits(), cache.misses()), (0, 2), "profile change = miss");
    assert_ne!(a1.cost_profile, c1.cost_profile);
    assert_ne!(
        a1.layers.iter().map(|l| l.scheme).collect::<Vec<_>>(),
        c1.layers.iter().map(|l| l.scheme).collect::<Vec<_>>(),
        "the calibration flips the winners in this registry"
    );
    // same profile again: hit
    let c2 = cache.get_or_plan(&calibrated, &m, 8);
    assert_eq!(cache.hits(), 1);
    assert_eq!(c2, c1);
    // back to analytic: the calibrated entry is stale again
    let a2 = cache.get_or_plan(&analytic, &m, 8);
    assert_eq!((cache.hits(), cache.misses()), (1, 3));
    assert_eq!(a2, a1, "re-plan restores the analytic plan exactly");
}
