//! Engine acceptance tests: planner-vs-cost-model agreement on the six
//! Table-5 models, plan-cache JSON round-trips, executor equivalence
//! with the naive forward path, and end-to-end serving through
//! `coordinator::server` backed by the engine.

use std::time::Duration;

use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::engine::{EngineModel, ModelPlan, PlanCache, PlanPolicy, Planner};
use tcbnn::nn::cost::{layer_secs, model_cost};
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::{all_models, mnist_mlp};
use tcbnn::nn::{ModelDef, ResidualMode, Scheme};
use tcbnn::sim::{Engine, RTX2080, RTX2080TI};
use tcbnn::util::Rng;

/// Acceptance: for each layer of the six Table-5 models the
/// *scheme-only* planner (`with_layout_search(false)` — the historical
/// per-layer search the layout DP generalizes) must pick exactly the
/// scheme `nn::cost` ranks cheapest.  The full DP's guarantee is
/// separate: it never predicts worse than this baseline
/// (`rust/tests/layout_equivalence.rs`).
#[test]
fn planner_picks_cost_model_winner_per_layer() {
    for gpu in [&RTX2080TI, &RTX2080] {
        let engine = Engine::new(gpu);
        let planner = Planner::new(gpu).with_layout_search(false);
        for m in all_models() {
            for batch in [8usize, 128] {
                let plan = planner.plan(&m, batch);
                let mut dims = m.input;
                for (li, l) in m.layers.iter().enumerate() {
                    // brute-force the cheapest scheme with the cost model
                    let mut best = Scheme::all()[0];
                    let mut best_secs = f64::INFINITY;
                    for s in Scheme::all() {
                        let secs = layer_secs(
                            &engine,
                            s,
                            l,
                            dims,
                            batch,
                            ResidualMode::Full,
                            m.residual_blocks > 0,
                        );
                        if secs < best_secs {
                            best = s;
                            best_secs = secs;
                        }
                    }
                    assert_eq!(
                        plan.layers[li].scheme,
                        best,
                        "{} layer {li} ({}) on {} at batch {batch}",
                        m.name,
                        l.tag(),
                        gpu.name
                    );
                    assert!((plan.layers[li].secs - best_secs).abs() <= 1e-18);
                    dims = dims.after(l);
                }
            }
        }
    }
}

/// The refactored per-layer costing must reproduce `model_cost` exactly
/// (same traces, same totals) — the planner and the paper tables stay
/// on one source of truth.
#[test]
fn layer_costs_sum_to_model_cost() {
    let gpu = &RTX2080TI;
    let engine = Engine::new(gpu);
    for m in all_models() {
        for scheme in Scheme::all() {
            let want = model_cost(&m, 8, gpu, scheme, ResidualMode::Full, true);
            let sync = gpu.secs(gpu.coop_sync_cycles);
            let mut dims = m.input;
            let mut total = gpu.launch_overhead_s;
            for l in &m.layers {
                total += layer_secs(
                    &engine,
                    scheme,
                    l,
                    dims,
                    8,
                    ResidualMode::Full,
                    m.residual_blocks > 0,
                ) + sync;
                dims = dims.after(l);
            }
            let rel = (total - want.total_secs).abs() / want.total_secs;
            assert!(rel < 1e-12, "{} {}: rel err {rel}", m.name, scheme.name());
        }
    }
}

/// Acceptance: a ModelPlan round-trips through the JSON plan cache for
/// every Table-5 model.
#[test]
fn plans_roundtrip_through_json_and_cache() {
    let planner = Planner::new(&RTX2080TI);
    let dir = std::env::temp_dir()
        .join(format!("tcbnn_engine_it_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PlanCache::open(&dir).unwrap();
    let mut planned = 0u64;
    for m in all_models() {
        let plan = planner.plan(&m, 32);
        // plain JSON round-trip
        let back = ModelPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan, "{} JSON round-trip", m.name);
        // through the on-disk cache
        let first = cache.get_or_plan(&planner, &m, 32);
        assert_eq!(first, plan, "{} fresh plan", m.name);
        let second = cache.get_or_plan(&planner, &m, 32);
        assert_eq!(second, plan, "{} cached plan", m.name);
        planned += 1;
    }
    assert_eq!(cache.misses(), planned);
    assert_eq!(cache.hits(), planned);
}

fn cifar_lite() -> ModelDef {
    ModelDef {
        name: "cifar-lite",
        dataset: "synthetic",
        input: Dims { hw: 16, feat: 3 },
        classes: 10,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 32,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinConv {
                c: 64,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 64, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

/// The arena executor must be bit-identical to the naive nn::forward
/// path on a conv model, across batch sizes on one arena.  The naive
/// path only accepts multiple-of-8 batches (its conv tiles rows in
/// blocks of 8), so odd batches are checked against the batch-8 run's
/// row prefix (rows are independent in both paths).
#[test]
fn engine_executor_matches_naive_forward() {
    let m = cifar_lite();
    let mut rng = Rng::new(2024);
    let weights = random_weights(&m, &mut rng);
    let plan = Planner::new(&RTX2080TI).plan(&m, 8);
    let mut exec =
        tcbnn::engine::EngineExecutor::new(m.clone(), &weights, plan).unwrap();
    let x8: Vec<f32> = (0..8 * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
    let want8 = forward(&m, &weights, &x8, 8);
    let got8 = exec.forward(&x8, 8).to_vec();
    assert_eq!(got8, want8, "batch 8");
    for batch in [5usize, 1] {
        let x = x8[..batch * m.input.flat()].to_vec();
        let got = exec.forward(&x, batch);
        assert_eq!(got, &want8[..batch * 10], "batch {batch} vs batch-8 prefix");
    }
}

/// Acceptance: a Table-5 model served end-to-end through
/// `coordinator::server` backed by the engine, with engine images/sec
/// visible through the metrics.
#[test]
fn table5_model_served_through_coordinator() {
    let m = mnist_mlp();
    let mut rng = Rng::new(7);
    let weights = random_weights(&m, &mut rng);

    // direct executor pass for ground truth
    let planner = Planner::new(&RTX2080TI);
    let mut direct = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8, 32])
        .build()
        .unwrap();
    let n = 48usize;
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let mut want = Vec::new();
    for x in &inputs {
        // batch-1 padded to bucket 8 by replicating the row, like the
        // batcher does
        let mut padded = Vec::with_capacity(8 * 784);
        for _ in 0..8 {
            padded.extend_from_slice(x);
        }
        let out = direct.run_batch(&padded, 8).unwrap();
        want.push(out[..10].to_vec());
    }

    // now through the full serving stack
    let m2 = m.clone();
    let srv = InferenceServer::start(
        ServerConfig {
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            ..Default::default()
        },
        move || {
            let planner = Planner::new(&RTX2080TI);
            Ok(Box::new(
                EngineModel::builder(&planner, &m2, &weights)
                    .buckets(vec![8, 32])
                    .policy(PlanPolicy::Search)
                    .build()?,
            ) as Box<dyn BatchModel>)
        },
    );
    let resps = srv.submit_all(inputs);
    assert_eq!(resps.len(), n);
    for (i, r) in resps.iter().enumerate() {
        assert_eq!(r.logits, want[i], "request {i} logits");
    }
    assert_eq!(srv.metrics.completed(), n as u64);
    assert!(srv.metrics.throughput_fps() > 0.0);
}

/// Engine metrics surface images/sec from inside the served model.
#[test]
fn engine_metrics_visible_through_server() {
    let m = mnist_mlp();
    let mut rng = Rng::new(9);
    let weights = random_weights(&m, &mut rng);
    let planner = Planner::new(&RTX2080TI);
    let em = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8, 32])
        .build()
        .unwrap();
    let engine_metrics = em.metrics_handle();
    let mut slot = Some(em);
    let srv = InferenceServer::start(ServerConfig::default(), move || {
        Ok(Box::new(slot.take().expect("single factory call")) as Box<dyn BatchModel>)
    });
    let inputs: Vec<Vec<f32>> =
        (0..32).map(|i| vec![(i as f32) / 32.0 - 0.5; 784]).collect();
    let _ = srv.submit_all(inputs);
    assert!(engine_metrics.engine_rows() >= 32);
    assert!(engine_metrics.engine_images_per_sec() > 0.0);
    assert!(engine_metrics.report().contains("engine="));
}

/// The executor arena never grows after warmup — the zero-allocation
/// invariant the bench reports on.
#[test]
fn arena_stays_constant_across_requests() {
    let m = cifar_lite();
    let mut rng = Rng::new(55);
    let weights = random_weights(&m, &mut rng);
    let plan = Planner::new(&RTX2080TI).plan(&m, 32);
    let mut exec =
        tcbnn::engine::EngineExecutor::new(m.clone(), &weights, plan).unwrap();
    let x: Vec<f32> = (0..32 * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
    let _ = exec.forward(&x, 32);
    let watermark = exec.arena_bytes();
    for _ in 0..5 {
        let _ = exec.forward(&x, 32);
        assert_eq!(exec.arena_bytes(), watermark);
    }
}
