//! Coordinator integration: server + batcher + PJRT model end to end,
//! plus mock-model stress covering batching invariants under load.

use std::time::Duration;

use tcbnn::coordinator::server::{BatchModel, InferenceServer, MockModel, ServerConfig};
use tcbnn::runtime::{Blob, MlpModel};
use tcbnn::util::Rng;

#[test]
fn mock_server_under_concurrent_load() {
    let srv = InferenceServer::start(ServerConfig::default(), || {
        Ok(Box::new(MockModel {
            row_elems: 16,
            out_elems: 4,
            delay: Duration::from_micros(200),
        }) as Box<dyn BatchModel>)
    });
    // 4 client threads x 50 requests
    std::thread::scope(|s| {
        for t in 0..4 {
            let srv = &srv;
            s.spawn(move || {
                let mut rng = Rng::new(t as u64);
                let rxs: Vec<_> = (0..50)
                    .map(|i| {
                        let mut v = vec![0.0f32; 16];
                        v[0] = (t * 1000 + i) as f32 + rng.next_f32() * 0.25;
                        (v[0], srv.submit(v))
                    })
                    .collect();
                for (tag, rx) in rxs {
                    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    assert_eq!(r.logits[0], tag, "response routed to wrong client");
                    assert_eq!(r.argmax, 3);
                }
            });
        }
    });
    assert_eq!(srv.metrics.completed(), 200);
    assert!(srv.metrics.batches() <= 200, "some batching happened");
    let s = srv.metrics.latency_summary();
    assert!(s.p99 < 5.0, "p99 sane: {}", s.p99);
}

#[test]
fn pjrt_mlp_served_end_to_end() {
    let dir = tcbnn::artifact_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let test = Blob::load(&format!("{dir}/testset")).unwrap();
    let images = test.as_f32("images").unwrap();
    let labels = test.as_i32("labels").unwrap();
    let n = 256usize;

    let dir2 = dir.clone();
    let srv = InferenceServer::start(
        ServerConfig {
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            ..Default::default()
        },
        move || Ok(Box::new(MlpModel::load(&dir2)?) as Box<dyn BatchModel>),
    );
    let inputs: Vec<Vec<f32>> =
        (0..n).map(|i| images[i * 800..(i + 1) * 800].to_vec()).collect();
    let resps = srv.submit_all(inputs);
    assert_eq!(resps.len(), n);
    let correct = resps
        .iter()
        .enumerate()
        .filter(|(i, r)| r.argmax as i32 == labels[*i])
        .count();
    let acc = correct as f64 / n as f64;
    // the deployed model scores ~88% on the synthetic test set; the
    // serving path must not degrade it
    assert!(acc > 0.75, "served accuracy {acc}");
    assert_eq!(srv.metrics.completed(), n as u64);
    assert!(srv.metrics.throughput_fps() > 0.0);
}

#[test]
fn mlp_direct_infer_matches_served_results() {
    let dir = tcbnn::artifact_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let test = Blob::load(&format!("{dir}/testset")).unwrap();
    let images = test.as_f32("images").unwrap();
    let mut model = MlpModel::load(&dir).unwrap();
    let direct = model.infer(&images[..8 * 800], 8).unwrap();

    let dir2 = dir.clone();
    let srv = InferenceServer::start(ServerConfig::default(), move || {
        Ok(Box::new(MlpModel::load(&dir2)?) as Box<dyn BatchModel>)
    });
    let inputs: Vec<Vec<f32>> =
        (0..8).map(|i| images[i * 800..(i + 1) * 800].to_vec()).collect();
    let resps = srv.submit_all(inputs);
    for (i, r) in resps.iter().enumerate() {
        for j in 0..10 {
            assert!(
                (r.logits[j] - direct[i * 10 + j]).abs() < 1e-4,
                "img {i} logit {j}"
            );
        }
    }
}
