//! Fastpath acceptance tests: the blocked u64 backend must agree with
//! the paper-scheme computes on aligned shapes and be servable end to
//! end through `coordinator::server`.
//!
//! (The odd-shape property coverage — non-multiple-of-64 widths, 1xN,
//! Nx1 — lives in `backend_equivalence.rs` now, where it runs against
//! EVERY registered backend instead of a per-scheme copy here.)

use std::time::Duration;

use tcbnn::bitops::{BitMatrix, BitTensor4, Layout, TensorLayout};
use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::engine::{EngineExecutor, EngineModel, PlanPolicy, Planner};
use tcbnn::kernels::backend::BackendRegistry;
use tcbnn::kernels::bconv::btc::BconvDesign1;
use tcbnn::kernels::bconv::{BconvProblem, BconvScheme};
use tcbnn::kernels::bmm::btc::Design1;
use tcbnn::kernels::bmm::{self, BmmScheme};
use tcbnn::kernels::fastpath;
use tcbnn::nn::forward::{forward, forward_with, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::mnist_mlp;
use tcbnn::nn::{ModelDef, Scheme};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::Rng;

#[test]
fn bmm_matches_design1_at_tile_aligned_but_not_64_shapes() {
    // Design-1 needs m,n % 8 and k % 32; k = 96/160/224 are aligned for
    // it but NOT multiples of 64 — the fastpath tail-word path
    let mut rng = Rng::new(302);
    for (m, n, k) in [(8, 16, 96), (16, 8, 160), (24, 24, 224), (8, 8, 32)] {
        let a = BitMatrix::random(m, k, Layout::RowMajor, &mut rng);
        let b = BitMatrix::random(k, n, Layout::ColMajor, &mut rng);
        let want = Design1.compute(&a, &b);
        assert_eq!(fastpath::bmm::bmm(&a, &b, 2), want, "{m}x{n}x{k}");
        assert_eq!(bmm::naive_ref(&a, &b), want, "{m}x{n}x{k} naive");
    }
}

#[test]
fn bconv_matches_design1_at_aligned_channels() {
    let mut rng = Rng::new(305);
    for p in [
        BconvProblem { hw: 6, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 1 },
        BconvProblem { hw: 8, n: 8, c: 128, o: 16, k: 3, stride: 2, pad: 1 },
        BconvProblem { hw: 5, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 0 },
    ] {
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        let want = BconvDesign1.compute(&input, &filter, p);
        assert_eq!(fastpath::bconv::bconv(&input, &filter, p, 2), want, "{p:?}");
    }
}

fn odd_conv_model() -> ModelDef {
    // deliberately non-64-multiple widths end to end (96, 40, 640, 72)
    ModelDef {
        name: "fastpath-odd",
        dataset: "synthetic",
        input: Dims { hw: 8, feat: 3 },
        classes: 5,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 96, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 96,
                o: 40,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 40, d_out: 72 },
            LayerSpec::FinalFc { d_in: 72, d_out: 5 },
        ],
        residual_blocks: 0,
    }
}

#[test]
fn fastpath_forward_is_bit_identical_to_default() {
    // the merged entry point: same registry, fastpath scheme
    let m = odd_conv_model();
    let mut rng = Rng::new(306);
    let w = random_weights(&m, &mut rng);
    let batch = 8;
    let x: Vec<f32> =
        (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
    assert_eq!(
        forward(&m, &w, &x, batch),
        forward_with(&m, &w, &x, batch, BackendRegistry::global(), Scheme::Fastpath)
    );
}

#[test]
fn executor_fastpath_plan_matches_naive_on_odd_model() {
    let m = odd_conv_model();
    let mut rng = Rng::new(307);
    let w = random_weights(&m, &mut rng);
    let batch = 8;
    let plan = Planner::new(&RTX2080TI).plan_fixed(&m, batch, Scheme::Fastpath);
    for lp in &plan.layers {
        assert_eq!(lp.scheme, Scheme::Fastpath);
    }
    let mut exec = EngineExecutor::new(m.clone(), &w, plan).unwrap();
    let x: Vec<f32> =
        (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
    let want = forward(&m, &w, &x, batch);
    assert_eq!(exec.forward(&x, batch), &want[..]);
}

/// Acceptance: a fastpath-pinned Table-5 model served end to end
/// through `coordinator::server` (builder + `PlanPolicy::Fixed`),
/// logits identical to a search-planned model of the same weights.
#[test]
fn fastpath_model_served_through_coordinator() {
    let m = mnist_mlp();
    let mut rng = Rng::new(308);
    let weights = random_weights(&m, &mut rng);
    let planner = Planner::new(&RTX2080TI);

    // ground truth from the search-planned engine
    let mut scalar = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8])
        .build()
        .unwrap();
    let n = 24usize;
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let mut want = Vec::new();
    for x in &inputs {
        let mut padded = Vec::with_capacity(8 * 784);
        for _ in 0..8 {
            padded.extend_from_slice(x);
        }
        let out = scalar.run_batch(&padded, 8).unwrap();
        want.push(out[..10].to_vec());
    }

    let m2 = m.clone();
    let srv = InferenceServer::start(
        ServerConfig {
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            ..Default::default()
        },
        move || {
            let planner = Planner::new(&RTX2080TI);
            Ok(Box::new(
                EngineModel::builder(&planner, &m2, &weights)
                    .buckets(vec![8])
                    .policy(PlanPolicy::Fixed(Scheme::Fastpath))
                    .build()?,
            ) as Box<dyn BatchModel>)
        },
    );
    let resps = srv.submit_all(inputs);
    assert_eq!(resps.len(), n);
    for (i, r) in resps.iter().enumerate() {
        assert_eq!(r.logits, want[i], "request {i} logits");
    }
    assert_eq!(srv.metrics.completed(), n as u64);
}
