//! Integration: the rust runtime loads the AOT artifacts and reproduces
//! the python oracle bit-for-bit.  This is the core L1/L2 <-> L3 contract.
//!
//! Requires `make artifacts` to have run (skipped otherwise).

use tcbnn::bitops::pack;
use tcbnn::runtime::{Blob, Engine, TensorData};
use tcbnn::util::Rng;

fn artifacts_or_skip() -> Option<String> {
    let dir = tcbnn::artifact_dir();
    if std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// The mlp weight blob as the (w1..b4) argument tail of mlp_b{B}.
fn mlp_weight_args(blob: &Blob) -> Vec<TensorData> {
    let mut args = vec![TensorData::F32(blob.as_f32("in_thresh").unwrap())];
    for i in 1..=3 {
        args.push(TensorData::U32(blob.as_u32(&format!("w{i}")).unwrap()));
        args.push(TensorData::F32(blob.as_f32(&format!("t{i}")).unwrap()));
        args.push(TensorData::I32(blob.as_i32(&format!("f{i}")).unwrap()));
    }
    args.push(TensorData::U32(blob.as_u32("w4").unwrap()));
    args.push(TensorData::F32(blob.as_f32("g4").unwrap()));
    args.push(TensorData::F32(blob.as_f32("b4").unwrap()));
    args
}

#[test]
fn mlp_matches_python_oracle() {
    let Some(dir) = artifacts_or_skip() else { return };
    let mut eng = Engine::new(&dir).expect("engine");
    let blob = Blob::load(&format!("{dir}/mlp_weights")).expect("weights");
    let test = Blob::load(&format!("{dir}/testset")).expect("testset");
    let oracle = Blob::load(&format!("{dir}/oracle_logits")).expect("oracle");

    let images = test.as_f32("images").unwrap();
    let want = oracle.as_f32("logits").unwrap(); // (8, 10) python logits

    let mut args = vec![TensorData::F32(images[..8 * 800].to_vec())];
    args.extend(mlp_weight_args(&blob));
    let outs = eng.run("mlp_b8", &args).expect("run mlp_b8");
    let got = outs[0].as_f32().unwrap();

    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-3 * w.abs().max(1.0),
            "logit {i}: got {g}, oracle {w}"
        );
    }
}

#[test]
fn mlp_batch_consistency_across_buckets() {
    // the same image must produce the same logits through the b8 and b32
    // graphs (padding the batch with copies).
    let Some(dir) = artifacts_or_skip() else { return };
    let mut eng = Engine::new(&dir).expect("engine");
    let blob = Blob::load(&format!("{dir}/mlp_weights")).expect("weights");
    let test = Blob::load(&format!("{dir}/testset")).expect("testset");
    let img: Vec<f32> = test.as_f32("images").unwrap()[..800].to_vec();

    let run_with = |eng: &mut Engine, batch: usize, name: &str| -> Vec<f32> {
        let mut x = Vec::with_capacity(batch * 800);
        for _ in 0..batch {
            x.extend_from_slice(&img);
        }
        let mut args = vec![TensorData::F32(x)];
        args.extend(mlp_weight_args(&blob));
        let outs = eng.run(name, &args).expect("run");
        outs[0].as_f32().unwrap()[..10].to_vec()
    };

    let l8 = run_with(&mut eng, 8, "mlp_b8");
    let l32 = run_with(&mut eng, 32, "mlp_b32");
    for (a, b) in l8.iter().zip(&l32) {
        assert!((a - b).abs() < 1e-4, "bucket mismatch {a} vs {b}");
    }
}

#[test]
fn bmm_artifact_matches_rust_bitops() {
    // the standalone packed-BMM artifact must agree with rust Eq-2 math.
    let Some(dir) = artifacts_or_skip() else { return };
    let mut eng = Engine::new(&dir).expect("engine");
    let n = 1024usize;
    let words = n / 32;
    let mut rng = Rng::new(99);
    let a: Vec<u32> = rng.u32_vec(n * words);
    let b: Vec<u32> = rng.u32_vec(n * words);

    let outs = eng
        .run("bmm_1024", &[TensorData::U32(a.clone()), TensorData::U32(b.clone())])
        .expect("run bmm");
    let got = outs[0].as_i32().unwrap();

    // spot-check 200 random entries against pack::pm1_dot
    for _ in 0..200 {
        let i = rng.gen_range(n);
        let j = rng.gen_range(n);
        let want = pack::pm1_dot(
            &a[i * words..(i + 1) * words],
            &b[j * words..(j + 1) * words],
            n,
        );
        assert_eq!(got[i * n + j], want, "entry ({i},{j})");
    }
}

#[test]
fn conv_block_artifact_matches_rust_bconv() {
    // the fused Pallas bconv_bin + OR-pool HLO must agree with the rust
    // functional kernels (cross-layer contract for the conv path)
    use tcbnn::bitops::{BitTensor4, TensorLayout};
    use tcbnn::kernels::bconv::btc::BconvDesign1;
    use tcbnn::kernels::bconv::{BconvProblem, BconvScheme};

    let Some(dir) = artifacts_or_skip() else { return };
    let mut eng = Engine::new(&dir).expect("engine");
    let (h, w, n, c, o, k) = (16usize, 16, 8, 128, 128, 3);
    let mut rng = Rng::new(123);
    let input = BitTensor4::random([h, w, n, c], TensorLayout::Hwnc, &mut rng);
    let filter = BitTensor4::random([k, k, o, c], TensorLayout::Kkoc, &mut rng);
    let thresh = vec![0.0f32; o];
    let flip = vec![0i32; o];

    let outs = eng
        .run(
            "conv_block",
            &[
                TensorData::U32(input.data.clone()),
                TensorData::U32(filter.data.clone()),
                TensorData::F32(thresh.clone()),
                TensorData::I32(flip),
            ],
        )
        .expect("run conv_block");
    let got = outs[0].as_u32().unwrap(); // (8, 8, 8, 4) packed

    // rust reference: bconv -> threshold at 0 -> 2x2 OR pool
    let p = BconvProblem { hw: h, n, c, o, k, stride: 1, pad: 1 };
    let ints = BconvDesign1.compute(&input, &filter, p);
    let ohw = p.out_hw();
    let mut bits = BitTensor4::zeros([ohw, ohw, n, o], TensorLayout::Hwnc);
    for op in 0..ohw {
        for oq in 0..ohw {
            for ni in 0..n {
                for oi in 0..o {
                    if ints[((op * ohw + oq) * n + ni) * o + oi] >= 0 {
                        bits.set(op, oq, ni, oi, true);
                    }
                }
            }
        }
    }
    // OR pool to (8, 8)
    let mut want = Vec::new();
    for hi in 0..ohw / 2 {
        for wi in 0..ohw / 2 {
            for ni in 0..n {
                for wrd in 0..o / 32 {
                    want.push(
                        bits.inner(2 * hi, 2 * wi, ni)[wrd]
                            | bits.inner(2 * hi + 1, 2 * wi, ni)[wrd]
                            | bits.inner(2 * hi, 2 * wi + 1, ni)[wrd]
                            | bits.inner(2 * hi + 1, 2 * wi + 1, ni)[wrd],
                    );
                }
            }
        }
    }
    assert_eq!(got.len(), want.len());
    assert_eq!(got, &want[..], "pallas conv_block != rust bconv pipeline");
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(dir) = artifacts_or_skip() else { return };
    let eng = Engine::new(&dir).expect("engine");
    for name in ["mlp_b8", "mlp_b32", "mlp_b128", "bmm_1024", "conv_block"] {
        assert!(
            eng.manifest.get(name).is_some(),
            "artifact {name} missing from manifest"
        );
    }
    assert_eq!(eng.platform().to_lowercase().contains("cpu"), true);
}
