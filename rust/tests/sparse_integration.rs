//! End-to-end acceptance for the sparse/BitGNN stack: the planner's
//! adjacency-density crossover (sparse schemes win the power-law
//! graph, dense schemes keep the block-dense grid), bit-exact engine
//! execution of GCN models against the reference forward, the plan
//! schema's sparsity fingerprint, and a GCN model served through
//! `serve::Fleet` with live windowed throughput.

use std::time::Duration;

use tcbnn::coordinator::server::BatchModel;
use tcbnn::engine::{EngineExecutor, EngineModel, Planner};
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::LayerSpec;
use tcbnn::nn::model::{gcn_grid, gcn_powerlaw, mnist_mlp};
use tcbnn::nn::Scheme;
use tcbnn::serve::{Fleet, FleetModelConfig};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::Rng;

#[test]
fn planner_picks_sparse_schemes_for_the_powerlaw_graph() {
    let planner = Planner::new(&RTX2080TI);
    let m = gcn_powerlaw();
    let plan = planner.plan(&m, 8);
    let mut gcn_layers = 0;
    for lp in &plan.layers {
        if matches!(m.layers[lp.index], LayerSpec::BinGcn { .. }) {
            gcn_layers += 1;
            assert!(
                matches!(lp.scheme, Scheme::Spmm | Scheme::GcnFused),
                "layer {} planned {} — a power-law adjacency is sparse \
                 enough that a sparse scheme must win the layout DP",
                lp.tag,
                lp.scheme.name()
            );
        }
    }
    assert_eq!(gcn_layers, 2, "GCN-PowerLaw carries two BinGcn layers");
}

#[test]
fn planner_keeps_the_dense_path_for_the_grid_graph() {
    let planner = Planner::new(&RTX2080TI);
    let m = gcn_grid();
    let plan = planner.plan(&m, 8);
    let mut gcn_layers = 0;
    for lp in &plan.layers {
        if matches!(m.layers[lp.index], LayerSpec::BinGcn { .. }) {
            gcn_layers += 1;
            assert!(
                !matches!(lp.scheme, Scheme::Spmm | Scheme::GcnFused),
                "layer {} planned {} — the block-dense grid adjacency \
                 must stay on a dense scheme",
                lp.tag,
                lp.scheme.name()
            );
        }
    }
    assert_eq!(gcn_layers, 2, "GCN-Grid carries two BinGcn layers");
}

#[test]
fn gcn_engine_execution_matches_the_reference_forward() {
    // the searched plan (sparse schemes on the power-law graph, dense
    // on the grid) must stay bit-identical to the registry-less
    // reference forward
    let batch = 8;
    for m in [gcn_powerlaw(), gcn_grid()] {
        let mut rng = Rng::new(888);
        let w = random_weights(&m, &mut rng);
        let x: Vec<f32> = (0..batch * m.input.flat())
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let want = forward(&m, &w, &x, batch);
        let planner = Planner::new(&RTX2080TI);
        let mut exec = EngineExecutor::new(m.clone(), &w, planner.plan(&m, batch))
            .expect("engine executor");
        assert_eq!(exec.forward(&x, batch), &want[..], "{}", m.name);
    }
}

#[test]
fn sparsity_fingerprints_separate_graphs_and_dense_models() {
    // the plan schema's cache-invalidation key: dense models stamp
    // "dense", graph models stamp their adjacency fingerprint, and
    // different graphs never collide
    let planner = Planner::new(&RTX2080TI);
    let dense = planner.sparsity_fingerprint(&mnist_mlp());
    let pl = planner.sparsity_fingerprint(&gcn_powerlaw());
    let grid = planner.sparsity_fingerprint(&gcn_grid());
    assert_eq!(dense, "dense");
    assert_ne!(pl, "dense");
    assert_ne!(grid, "dense");
    assert_ne!(pl, grid, "distinct graphs must fingerprint differently");
    // and the stamp lands in the searched plan itself
    assert_eq!(planner.plan(&gcn_powerlaw(), 8).sparsity, pl);
}

#[test]
fn gcn_model_serves_through_the_fleet_with_live_windows() {
    let m = gcn_powerlaw();
    let seed = 777u64;
    let weights = random_weights(&m, &mut Rng::new(seed));

    // ground truth: a direct EngineModel over the same weights
    let planner = Planner::new(&RTX2080TI);
    let mut reference = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8])
        .build()
        .expect("reference engine model");
    let n = 12usize;
    let mut rng = Rng::new(seed.wrapping_add(1));
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..m.input.flat()).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let want: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| {
            let mut padded = Vec::with_capacity(8 * m.input.flat());
            for _ in 0..8 {
                padded.extend_from_slice(x);
            }
            reference.run_batch(&padded, 8).expect("reference batch")
                [..m.classes]
                .to_vec()
        })
        .collect();

    let mut fleet = Fleet::new();
    let m2 = m.clone();
    fleet.register(
        "gcn",
        FleetModelConfig { shards: 1, ..Default::default() },
        move || {
            let planner = Planner::new(&RTX2080TI);
            let weights = random_weights(&m2, &mut Rng::new(seed));
            Ok(Box::new(
                EngineModel::builder(&planner, &m2, &weights)
                    .buckets(vec![8])
                    .build()?,
            ) as Box<dyn BatchModel>)
        },
    );
    let mut pending = Vec::new();
    for x in &inputs {
        pending.push(fleet.submit("gcn", x.clone()).expect("admitted"));
    }
    for (i, rx) in pending.into_iter().enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("accepted GCN request answered");
        assert_eq!(r.logits, want[i], "request {i} logits");
    }
    let snap = fleet.snapshot("gcn").expect("registered");
    assert_eq!(snap.requests, n as u64);
    assert!(
        snap.windows.iter().any(|w| w.requests > 0 && w.rps > 0.0),
        "no live windowed throughput right after serving GCN traffic"
    );
    fleet.shutdown();
}
