//! Layout co-design acceptance tests (ISSUE 5):
//!
//! 1. the layout-aware arena executor is **bit-identical** to the
//!    serial `nn::forward` reference for every registered backend —
//!    including the fastpath's `Blocked64`-chained FC plans — and for
//!    mixed-scheme plans that force explicit repack edges in both
//!    directions;
//! 2. the planner's (scheme, layout) DP **never predicts a plan worse
//!    than the scheme-only planner** on the Table-5 model set;
//! 3. the plan cache treats v3 (pre-layout) plans and v4 documents
//!    with missing/unknown layout edges as a **miss**;
//! 4. explicit repack ops are **counted** (executor `repack_stats`)
//!    and surfaced through coordinator `Metrics` when served.

use tcbnn::coordinator::server::BatchModel;
use tcbnn::engine::{EngineExecutor, EngineModel, PlanCache, PlanPolicy, Planner};
use tcbnn::kernels::backend::BackendRegistry;
use tcbnn::layout::LayoutKind;
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::{all_models, mnist_mlp};
use tcbnn::nn::{ModelDef, Scheme};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::Rng;

fn conv_model() -> ModelDef {
    ModelDef {
        name: "layout-conv-test",
        dataset: "synthetic",
        input: Dims { hw: 8, feat: 3 },
        classes: 4,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 32,
                o: 32,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 32, d_out: 96 },
            LayerSpec::FinalFc { d_in: 96, d_out: 4 },
        ],
        residual_blocks: 0,
    }
}

/// Acceptance: every registered backend's fixed plan — now carrying
/// the DP's layout edges (the fastpath chains FC layers in Blocked64)
/// — executes bit-identically to the serial reference forward.
#[test]
fn every_backend_fixed_plan_matches_forward_bit_for_bit() {
    let planner = Planner::new(&RTX2080TI);
    for (m, seed) in [(conv_model(), 31u64), (mnist_mlp(), 33u64)] {
        let batch = 8;
        let mut rng = Rng::new(seed);
        let weights = random_weights(&m, &mut rng);
        let x: Vec<f32> =
            (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
        let want = forward(&m, &weights, &x, batch);
        for scheme in BackendRegistry::global().schemes() {
            let plan = planner.plan_fixed(&m, batch, scheme);
            if scheme == Scheme::Fastpath {
                // the layout DP must have chained at least one edge
                assert!(
                    plan.layers
                        .iter()
                        .any(|lp| lp.in_layout == LayoutKind::Blocked64),
                    "{}: fastpath plan never uses its native layout",
                    m.name
                );
            }
            let mut exec = EngineExecutor::new(m.clone(), &weights, plan).unwrap();
            assert_eq!(
                exec.forward(&x, batch),
                &want[..],
                "{} under {}",
                m.name,
                scheme.name()
            );
            // chained edges move nothing: no explicit repack ops
            assert!(exec.repack_stats().is_empty(), "{}", scheme.name());
        }
    }
}

/// Acceptance: mixed-scheme plans that force explicit repack edges in
/// BOTH directions stay bit-identical to the reference, and the
/// executor counts every materialized conversion.
#[test]
fn forced_repack_edges_are_bit_identical_and_counted() {
    let batch = 8;

    // Row32 -> Blocked64 at a conv->FC boundary: scalar convs, then the
    // fastpath classifier fed its native u64 form via an explicit edge
    let m = conv_model();
    let mut rng = Rng::new(41);
    let weights = random_weights(&m, &mut rng);
    let x: Vec<f32> =
        (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
    let want = forward(&m, &weights, &x, batch);
    let mut plan = Planner::new(&RTX2080TI)
        .with_layout_search(false)
        .plan_fixed(&m, batch, Scheme::Sbnn32);
    plan.layers[2].scheme = Scheme::Fastpath; // BinFc
    plan.layers[2].in_layout = LayoutKind::Blocked64;
    let mut exec = EngineExecutor::new(m.clone(), &weights, plan).unwrap();
    assert_eq!(exec.forward(&x, batch), &want[..], "32->64 edge");
    let stats = exec.repack_stats();
    assert_eq!(stats.len(), 1, "{stats:?}");
    assert_eq!(stats[0].0, "FASTPATH");
    assert_eq!(stats[0].1, 1, "one explicit conversion per pass");
    assert!(stats[0].2 > 0);

    // Blocked64 -> Row32 between FC layers: a fastpath layer emits its
    // native u64 output, the next (Row32-only scalar) layer forces the
    // executor to convert back on the edge
    let m = mnist_mlp();
    let mut rng = Rng::new(43);
    let weights = random_weights(&m, &mut rng);
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32() - 0.5).collect();
    let want = forward(&m, &weights, &x, batch);
    let mut plan = Planner::new(&RTX2080TI)
        .with_layout_search(false)
        .plan_fixed(&m, batch, Scheme::Sbnn32);
    plan.layers[1].scheme = Scheme::Fastpath;
    plan.layers[1].out_layout = LayoutKind::Blocked64;
    let mut exec = EngineExecutor::new(m.clone(), &weights, plan).unwrap();
    assert_eq!(exec.forward(&x, batch), &want[..], "64->32 edge");
    let stats = exec.repack_stats();
    // the consuming layer (layer 2, still Sbnn32) did the conversion
    assert_eq!(stats.len(), 1, "{stats:?}");
    assert_eq!(stats[0].0, "SBNN-32");
    assert_eq!(stats[0].1, 1);
    // counters accumulate across passes
    assert_eq!(exec.forward(&x, batch), &want[..]);
    assert_eq!(exec.repack_stats()[0].1, 2);
}

/// Acceptance: a plan whose layout edge names a backend that cannot
/// execute it is rejected at build time, not mid-request.
#[test]
fn unexecutable_layout_edge_is_a_build_error() {
    let m = mnist_mlp();
    let mut rng = Rng::new(45);
    let weights = random_weights(&m, &mut rng);
    let mut plan = Planner::new(&RTX2080TI)
        .with_layout_search(false)
        .plan_fixed(&m, 8, Scheme::Sbnn32);
    // scalar backends are Row32-only: feeding one Blocked64 must fail
    plan.layers[1].in_layout = LayoutKind::Blocked64;
    let err = EngineExecutor::new(m, &weights, plan)
        .err()
        .expect("scalar backend cannot execute Blocked64");
    assert!(
        format!("{err:#}").contains("cannot execute planned input layout"),
        "{err:#}"
    );
}

/// Acceptance: the (scheme, layout) DP with repack costs never
/// predicts a plan worse than the scheme-only planner on the Table-5
/// model set — the all-Row32 path is always in its search space.
#[test]
fn dp_never_predicts_worse_than_scheme_only_on_table5() {
    let dp = Planner::new(&RTX2080TI);
    let scheme_only = Planner::new(&RTX2080TI).with_layout_search(false);
    for m in all_models() {
        for batch in [8usize, 128] {
            let a = dp.plan(&m, batch);
            let b = scheme_only.plan(&m, batch);
            assert!(
                a.total_secs <= b.total_secs * (1.0 + 1e-12),
                "{} b{batch}: DP {} vs scheme-only {}",
                m.name,
                a.total_secs,
                b.total_secs
            );
        }
    }
    // and on an all-FC model pinned to the fastpath the chain is a
    // strict win, with the savings attributed to the layout edges
    let m = mnist_mlp();
    let chained = dp.plan_fixed(&m, 8, Scheme::Fastpath);
    let row32 = scheme_only.plan_fixed(&m, 8, Scheme::Fastpath);
    assert!(chained.total_secs < row32.total_secs);
}

/// Acceptance: the plan cache treats v3 plans and v4 documents with
/// missing or unknown layout edges as a miss (and self-heals).
#[test]
fn plan_cache_treats_v3_and_missing_layout_edges_as_miss() {
    let dir = std::env::temp_dir()
        .join(format!("tcbnn_layout_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PlanCache::open(&dir).unwrap();
    let planner = Planner::new(&RTX2080TI);
    let m = mnist_mlp();
    let fresh = cache.get_or_plan(&planner, &m, 8);
    assert_eq!((cache.hits(), cache.misses()), (0, 1));
    let entry = cache.entry_path(&fresh.model, 8, &fresh.gpu);

    // an older-schema (pre-sparsity) document is stale
    let v4 = fresh.to_json().replace("\"schema\":5", "\"schema\":4");
    std::fs::write(&entry, v4).unwrap();
    assert!(cache.get(&fresh.model, 8, &fresh.gpu).is_none(), "v4 must miss");

    // a current-schema document with its layout edges stripped is unreadable
    let no_edges = fresh
        .to_json()
        .replace("\"in_layout\":\"Row32\",", "")
        .replace("\"in_layout\":\"Blocked64\",", "");
    std::fs::write(&entry, no_edges).unwrap();
    assert!(
        cache.get(&fresh.model, 8, &fresh.gpu).is_none(),
        "missing layout edges must miss"
    );

    // ... as is one naming a layout this build does not know
    let unknown = fresh.to_json().replace("\"Row32\"", "\"Row128\"");
    std::fs::write(&entry, unknown).unwrap();
    assert!(cache.get(&fresh.model, 8, &fresh.gpu).is_none());

    // and get_or_plan self-heals the entry back to the v4 plan
    let healed = cache.get_or_plan(&planner, &m, 8);
    assert_eq!(healed, fresh);
    assert!(cache.get(&fresh.model, 8, &fresh.gpu).is_some());
}

/// Acceptance: explicit repack traffic of a *served* model surfaces
/// through coordinator `Metrics` next to the plan-cache counters.  The
/// plan arrives through the cache (`PlanPolicy::Cached`), which is
/// exactly how a foreign plan with explicit edges reaches a server.
#[test]
fn served_repack_traffic_surfaces_through_metrics() {
    let m = mnist_mlp();
    let mut rng = Rng::new(47);
    let weights = random_weights(&m, &mut rng);
    let planner = Planner::new(&RTX2080TI).with_layout_search(false);
    let dir = std::env::temp_dir()
        .join(format!("tcbnn_layout_metrics_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PlanCache::open(&dir).unwrap();
    // seed the cache with a plan that forces one explicit edge: the
    // classifier runs fastpath and wants its native Blocked64 form
    let mut plan = planner.plan_fixed(&m, 8, Scheme::Sbnn32);
    let last = plan.layers.len() - 1;
    plan.layers[last].scheme = Scheme::Fastpath;
    plan.layers[last].in_layout = LayoutKind::Blocked64;
    cache.put(&plan).unwrap();

    let mut em = EngineModel::builder(&planner, &m, &weights)
        .buckets(vec![8])
        .policy(PlanPolicy::Cached)
        .cache(&cache)
        .build()
        .unwrap();
    assert_eq!(em.metrics.plan_cache_hits(), 1, "the doctored plan must hit");
    assert_eq!(em.plan().layers[last].in_layout, LayoutKind::Blocked64);

    let x: Vec<f32> = (0..8 * 784).map(|_| rng.next_f32() - 0.5).collect();
    let want = {
        let reference = forward(&m, &weights, &x, 8);
        let out = em.run_batch(&x, 8).unwrap();
        assert_eq!(out, reference, "served outputs stay bit-identical");
        out
    };
    let stats = em.metrics.repack_stats();
    assert_eq!(stats.len(), 1, "{stats:?}");
    assert_eq!(stats[0].0, "FASTPATH");
    assert_eq!(stats[0].1, 1);
    assert!(stats[0].2 > 0);
    let report = em.metrics.report();
    assert!(report.contains("plan_cache=1h/0m"), "{report}");
    assert!(report.contains("repack=1ops/"), "{report}");
    // counters keep accumulating across batches
    assert_eq!(em.run_batch(&x, 8).unwrap(), want);
    assert_eq!(em.metrics.repack_stats()[0].1, 2);
}
