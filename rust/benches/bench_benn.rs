//! Bench: Figs 27-28 — BENN ensemble scaling up (PCIe/NCCL) and out
//! (IB/MPI).

use tcbnn::sim::RTX2080TI;

fn main() {
    let t = tcbnn::figures::figs_27_28(&RTX2080TI);
    println!("{}", t.render());
    let _ = t.write_csv("results", "bench_fig27_28");
}
