//! Bench: Figs 2-13 — the §4 characterization microbenchmarks on both
//! simulated GPUs (load/store latency vs ldm, BMMA pipeline).

use tcbnn::sim::config::all_gpus;

fn main() {
    for gpu in all_gpus() {
        let tag = gpu.name.to_lowercase();
        for (name, t) in [
            (format!("bench_fig02_05_{tag}"), tcbnn::figures::fig_load_latency(gpu)),
            (format!("bench_fig06_09_{tag}"), tcbnn::figures::fig_store_latency(gpu)),
            (format!("bench_fig10_13_{tag}"), tcbnn::figures::fig_bmma_pipeline(gpu)),
        ] {
            println!("{}", t.render());
            let _ = t.write_csv("results", &name);
        }
    }
}
