//! Ablations for the design choices DESIGN.md calls out:
//!   A1  FSB tile width: why BW = 128 and not 384 (the other fast stride)
//!   A2  warps-per-CTA for the BTC BMM designs (the paper picks 2)
//!   A3  accumulator strategy: same c_frag vs. rotating accumulators
//!   A4  simulator robustness: design ordering under L1-miss perturbation

use tcbnn::kernels::bmm::{self, BmmProblem, BmmScheme};
use tcbnn::kernels::IoMode;
use tcbnn::sim::{Engine, KernelTrace, MemSpace, RTX2080TI};
use tcbnn::util::table::Table;

/// Design-3-like trace with a configurable tile stride / CTA shape /
/// accumulator strategy.
fn d3_like(p: BmmProblem, ldm: usize, warps_per_cta: usize, same_acc: bool) -> KernelTrace {
    let mut t = KernelTrace::new("ablation");
    let warps = (p.m / 8) * (p.n / 8);
    t.warps_per_cta = warps_per_cta;
    t.grid_ctas = warps.div_ceil(warps_per_cta).max(1);
    let ksteps = p.k / 128;
    t.warp.load_tiles(ldm, MemSpace::Global, 2 * ksteps);
    if same_acc {
        t.warp.bmma_same_acc_ops = ksteps;
    } else {
        t.warp.bmma_ops = ksteps;
    }
    t.warp.intu_ops = 80;
    t.warp.bulk_store_bytes = 8;
    t.compulsory_bytes = p.operand_bytes() + (p.m * p.n / 8) as f64;
    t.load_footprint_bytes = p.operand_bytes();
    t
}

fn main() {
    let e = Engine::new(&RTX2080TI);
    let sizes = [1024usize, 2048, 4096, 8192];

    // ---- A1: FSB tile width --------------------------------------------
    let mut t1 = Table::new(
        "A1: FSB tile stride choice (us, BNN-specific BMM)",
        &["n", "ldm=128 (FSB)", "ldm=384", "ldm=width (no FSB)"],
    );
    for n in sizes {
        let p = BmmProblem::square(n);
        let f = |ldm| e.cost(&d3_like(p, ldm, 2, true)).total_secs * 1e6;
        t1.row(&[
            n.to_string(),
            format!("{:.1}", f(128)),
            format!("{:.1}", f(384)),
            format!("{:.1}", f(n)),
        ]);
    }
    println!("{}", t1.render());
    let _ = t1.write_csv("results", "ablation_fsb_stride");

    // ---- A2: warps per CTA ----------------------------------------------
    let mut t2 = Table::new(
        "A2: warps per CTA, Design-3 at 4096 (us)",
        &["warps_per_cta", "latency_us", "active_warps_per_sm"],
    );
    let p = BmmProblem::square(4096);
    for w in [1usize, 2, 4, 8, 16] {
        let tr = d3_like(p, 128, w, true);
        let c = e.cost(&tr);
        t2.row(&[
            w.to_string(),
            format!("{:.1}", c.total_secs * 1e6),
            c.active_warps_per_sm.to_string(),
        ]);
    }
    println!("{}", t2.render());
    let _ = t2.write_csv("results", "ablation_warps_per_cta");

    // ---- A3: accumulator strategy ----------------------------------------
    let mut t3 = Table::new(
        "A3: accumulator strategy (us): same c_frag (+10cy dep) vs rotating",
        &["n", "same_accumulator", "rotating_accumulators", "gain_pct"],
    );
    for n in sizes {
        let p = BmmProblem::square(n);
        let same = e.cost(&d3_like(p, 128, 2, true)).total_secs;
        let rot = e.cost(&d3_like(p, 128, 2, false)).total_secs;
        t3.row(&[
            n.to_string(),
            format!("{:.1}", same * 1e6),
            format!("{:.1}", rot * 1e6),
            format!("{:.1}", (same - rot) / same * 100.0),
        ]);
    }
    println!("{}", t3.render());
    let _ = t3.write_csv("results", "ablation_accumulator");

    // ---- A4: robustness of the headline ordering --------------------------
    // perturb the L1 miss model +/-50% and check bmmafmt still beats bmma
    let mut t4 = Table::new(
        "A4: conclusion robustness under L1-model perturbation (4096, general)",
        &["l1_miss_scale", "bmma_us", "bmmafmt_us", "fmt_wins"],
    );
    for scale in [0.5f64, 0.75, 1.0, 1.5, 2.0] {
        let mut gpu = RTX2080TI.clone();
        gpu.l1_miss_rate = (gpu.l1_miss_rate * scale).min(1.0);
        let e2 = Engine::new(&gpu);
        let p = BmmProblem::square(4096);
        let d1 = bmm::simulate(&e2, &bmm::btc::Design1, p, IoMode::General);
        let d3 = bmm::simulate(&e2, &bmm::btc::Design3, p, IoMode::General);
        t4.row(&[
            format!("{scale:.2}"),
            format!("{:.1}", d1 * 1e6),
            format!("{:.1}", d3 * 1e6),
            (d3 < d1).to_string(),
        ]);
    }
    println!("{}", t4.render());
    let _ = t4.write_csv("results", "ablation_robustness");
}
