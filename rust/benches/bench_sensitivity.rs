//! Bench: the §7.5 sensitivity studies — Fig 24 (per-layer breakdown),
//! Table 10 (sync overhead), Fig 25 (batch scaling), Fig 26 (residuals).

use tcbnn::sim::RTX2080;

fn main() {
    for (name, t) in [
        ("bench_fig24", tcbnn::figures::fig24_breakdown(&RTX2080)),
        ("bench_table10", tcbnn::figures::table10_sync(&RTX2080)),
        ("bench_fig25", tcbnn::figures::fig25_batch(&RTX2080)),
        ("bench_fig26", tcbnn::figures::fig26_shortcut(&RTX2080)),
    ] {
        println!("{}", t.render());
        let _ = t.write_csv("results", name);
    }
}
