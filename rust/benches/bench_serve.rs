//! Bench: the serve/ fleet under sustained load — real `EngineModel`
//! replicas (no mocks) replaying a large request stream through
//! admission control and priority shedding, reporting end-to-end
//! request throughput, engine img/s, and shed rates as JSON.
//!
//!   cargo bench --bench bench_serve                  # ~1M requests
//!   cargo bench --bench bench_serve -- --quick       # CI sizing (~20k)
//!   cargo bench --bench bench_serve -- --requests N  # explicit count
//!   cargo bench --bench bench_serve -- --out BENCH_SERVE.json
//!
//! Two models share the host: `mnist` (priority 0, the latency-
//! critical tenant, queue-depth capped so the replay genuinely sheds)
//! and `gcn` (priority 1, the background BitGNN tenant — it yields
//! with `Overload::LowPriority` whenever the critical backlog crosses
//! the fleet's pressure threshold).  The submitter keeps a bounded
//! in-flight window larger than the critical queue cap, so admission
//! and priority shedding both fire at full submission speed.
//!
//! The JSON document carries, per model: submitted/served/shed counts,
//! the shed and priority-shed split, fleet throughput (req/s), and the
//! engine-side img/s — the numbers docs/BENCH.md's serving section
//! quotes.  This bench is informational (no baseline gate): absolute
//! throughput is machine-dependent, and the CI serve path is gated by
//! serve-smoke and the sparse/GNN integration test instead.

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use tcbnn::coordinator::server::{BatchModel, Response};
use tcbnn::engine::json::Value;
use tcbnn::engine::{EngineModel, PlanCache, PlanPolicy, Planner};
use tcbnn::nn::forward::random_weights;
use tcbnn::nn::model::{gcn_powerlaw, mnist_mlp};
use tcbnn::nn::ModelDef;
use tcbnn::obs::Snapshot;
use tcbnn::serve::{AdmissionConfig, Fleet, FleetError, FleetModelConfig, Overload};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::cli::Args;
use tcbnn::util::Rng;

/// Critical tenant's queue cap; the in-flight window below exceeds it
/// so QueueFull sheds actually happen during the replay.
const CRITICAL_QUEUE_DEPTH: usize = 512;

/// Higher-priority backlog at which the background tenant yields.
const PRIORITY_PRESSURE: usize = 256;

/// Submitter-side in-flight window: receivers held before the oldest
/// is drained.  Must exceed `CRITICAL_QUEUE_DEPTH`, or submitter
/// backpressure would keep the queues below both shed thresholds.
const INFLIGHT_WINDOW: usize = 4096;

struct TenantStats {
    name: &'static str,
    submitted: u64,
    shed_queue: u64,
    shed_rate_limited: u64,
    shed_priority: u64,
}

impl TenantStats {
    fn new(name: &'static str) -> TenantStats {
        TenantStats {
            name,
            submitted: 0,
            shed_queue: 0,
            shed_rate_limited: 0,
            shed_priority: 0,
        }
    }

    fn sheds(&self) -> u64 {
        self.shed_queue + self.shed_rate_limited + self.shed_priority
    }

    fn served(&self) -> u64 {
        self.submitted - self.sheds()
    }

    fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.sheds() as f64 / self.submitted as f64
        }
    }

    /// The fleet's own counters must agree with the submitter's view.
    fn assert_consistent(&self, snap: &Snapshot) {
        assert_eq!(
            snap.sheds,
            self.sheds(),
            "{}: fleet shed counter disagrees with the submitter",
            self.name
        );
        assert_eq!(
            snap.priority_sheds, self.shed_priority,
            "{}: priority_sheds disagrees",
            self.name
        );
    }
}

fn register_engine_model(
    fleet: &mut Fleet,
    name: &'static str,
    model: &ModelDef,
    cfg: FleetModelConfig,
    buckets: Vec<usize>,
    cache_dir: &str,
    seed: u64,
) {
    let planner = Planner::new(&RTX2080TI);
    let model = model.clone();
    let cache_dir = cache_dir.to_string();
    fleet.register(name, cfg, move || {
        let weights = random_weights(&model, &mut Rng::new(seed));
        let cache = PlanCache::open(&cache_dir)?;
        let em = EngineModel::builder(&planner, &model, &weights)
            .buckets(buckets.clone())
            .policy(PlanPolicy::Cached)
            .cache(&cache)
            .build()?;
        Ok(Box::new(em) as Box<dyn BatchModel>)
    });
}

/// Block on the oldest in-flight receivers until at most `keep`
/// remain.  Every accepted request must be answered — the fleet is
/// only torn down after the final (keep = 0) drain, so a lost waiter
/// here is a real bug, not a shutdown race.
fn drain(inflight: &mut VecDeque<Receiver<Response>>, keep: usize, answered: &mut u64) {
    while inflight.len() > keep {
        let rx = inflight.pop_front().unwrap();
        rx.recv_timeout(Duration::from_secs(120))
            .expect("accepted request lost its waiter");
        *answered += 1;
    }
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let default_requests = if quick { 20_000 } else { 1_000_000 };
    let total_requests = args.get_usize("requests", default_requests);
    let out_path = args.get_or("out", "BENCH_SERVE.json").to_string();
    let seed = args.get_usize("seed", 99) as u64;

    let critical_model = mnist_mlp();
    let background_model = gcn_powerlaw();
    let cache_dir = std::env::temp_dir()
        .join(format!("tcbnn_bench_serve_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let cache_dir = cache_dir.to_string_lossy().to_string();

    // pre-warm the shared plan cache so every replica's Cached build is
    // a read-only hit (no concurrent same-file writes across shards)
    {
        let planner = Planner::new(&RTX2080TI);
        let cache = PlanCache::open(&cache_dir).expect("plan cache dir");
        for &b in &[8usize, 32] {
            cache.get_or_plan(&planner, &critical_model, b);
        }
        cache.get_or_plan(&planner, &background_model, 8);
    }

    let mut fleet = Fleet::new();
    fleet.set_priority_pressure(PRIORITY_PRESSURE);
    register_engine_model(
        &mut fleet,
        "mnist",
        &critical_model,
        FleetModelConfig {
            shards: 2,
            priority: 0,
            admission: AdmissionConfig {
                rate: None,
                burst: 64.0,
                max_queue_depth: CRITICAL_QUEUE_DEPTH,
            },
            ..Default::default()
        },
        vec![8, 32],
        &cache_dir,
        seed,
    );
    register_engine_model(
        &mut fleet,
        "gcn",
        &background_model,
        FleetModelConfig { shards: 1, priority: 1, ..Default::default() },
        vec![8],
        &cache_dir,
        seed.wrapping_add(1),
    );

    // input templates, reused across submits (submit takes an owned
    // Vec, so each send clones a template — no per-request RNG work)
    let mut rng = Rng::new(seed);
    let critical_rows: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            (0..critical_model.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect()
        })
        .collect();
    let background_rows: Vec<Vec<f32>> = (0..2)
        .map(|_| {
            (0..background_model.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect()
        })
        .collect();

    let mut critical = TenantStats::new("mnist");
    let mut background = TenantStats::new("gcn");
    let mut inflight: VecDeque<Receiver<Response>> = VecDeque::new();
    let mut answered = 0u64;

    println!(
        "replaying {total_requests} requests (7:1 critical:background, \
         in-flight window {INFLIGHT_WINDOW}, critical queue cap \
         {CRITICAL_QUEUE_DEPTH}, priority pressure {PRIORITY_PRESSURE})"
    );
    let t0 = Instant::now();
    for i in 0..total_requests {
        // 7:1 mix keeps the critical tenant saturated so both shed
        // mechanisms stay exercised throughout the replay
        let to_background = i % 8 == 7;
        let (name, stats, row) = if to_background {
            (
                "gcn",
                &mut background,
                background_rows[i / 8 % background_rows.len()].clone(),
            )
        } else {
            (
                "mnist",
                &mut critical,
                critical_rows[i % critical_rows.len()].clone(),
            )
        };
        stats.submitted += 1;
        match fleet.submit(name, row) {
            Ok(rx) => inflight.push_back(rx),
            Err(FleetError::Overloaded(Overload::QueueFull)) => stats.shed_queue += 1,
            Err(FleetError::Overloaded(Overload::RateLimited)) => {
                stats.shed_rate_limited += 1
            }
            Err(FleetError::Overloaded(Overload::LowPriority)) => {
                stats.shed_priority += 1
            }
            Err(e) => panic!("unexpected submit failure: {e}"),
        }
        drain(&mut inflight, INFLIGHT_WINDOW, &mut answered);
    }
    drain(&mut inflight, 0, &mut answered);
    let wall_s = t0.elapsed().as_secs_f64();

    let accepted = critical.served() + background.served();
    assert_eq!(answered, accepted, "accepted != answered");

    let snapshots: Vec<(&'static str, Snapshot)> = [&critical, &background]
        .iter()
        .map(|s| (s.name, fleet.snapshot(s.name).expect("registered")))
        .collect();
    println!(
        "\nreplayed {total_requests} requests in {wall_s:.1}s \
         ({:.0} submitted req/s, {answered} answered)",
        total_requests as f64 / wall_s
    );
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10} {:>10} {:>9} {:>12}",
        "model", "submitted", "served", "shed", "q-full", "prio", "shed%", "engine img/s"
    );
    for stats in [&critical, &background] {
        let snap = &snapshots.iter().find(|(n, _)| *n == stats.name).unwrap().1;
        stats.assert_consistent(snap);
        println!(
            "{:<8} {:>10} {:>10} {:>10} {:>10} {:>10} {:>8.1}% {:>12.0}",
            stats.name,
            stats.submitted,
            stats.served(),
            stats.sheds(),
            stats.shed_queue,
            stats.shed_priority,
            stats.shed_rate() * 100.0,
            snap.engine_img_s(),
        );
    }

    let models = [&critical, &background]
        .iter()
        .map(|stats| {
            let snap = &snapshots.iter().find(|(n, _)| *n == stats.name).unwrap().1;
            Value::Obj(vec![
                ("name".to_string(), Value::Str(stats.name.to_string())),
                ("submitted".to_string(), Value::Num(stats.submitted as f64)),
                ("served".to_string(), Value::Num(stats.served() as f64)),
                ("sheds".to_string(), Value::Num(stats.sheds() as f64)),
                (
                    "sheds_queue_full".to_string(),
                    Value::Num(stats.shed_queue as f64),
                ),
                (
                    "sheds_priority".to_string(),
                    Value::Num(stats.shed_priority as f64),
                ),
                ("shed_rate".to_string(), Value::Num(stats.shed_rate())),
                (
                    "throughput_rps".to_string(),
                    Value::Num(snap.throughput_rps),
                ),
                ("engine_img_s".to_string(), Value::Num(snap.engine_img_s())),
                ("latency_p99_s".to_string(), Value::Num(snap.latency.p99)),
            ])
        })
        .collect();
    let doc = Value::Obj(vec![
        ("schema".to_string(), Value::Num(1.0)),
        (
            "mode".to_string(),
            Value::Str(if quick { "quick" } else { "full" }.to_string()),
        ),
        ("requests".to_string(), Value::Num(total_requests as f64)),
        ("wall_s".to_string(), Value::Num(wall_s)),
        (
            "submitted_rps".to_string(),
            Value::Num(total_requests as f64 / wall_s),
        ),
        ("models".to_string(), Value::Arr(models)),
    ]);
    std::fs::write(&out_path, format!("{doc}\n")).expect("write bench JSON");
    println!("\nwrote {out_path}");

    fleet.shutdown();
}
