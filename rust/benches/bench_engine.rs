//! Bench: the engine subsystem — images/sec per model x batch bucket,
//! plan-cache hit/miss counts, and the arena executor vs the naive
//! `nn::forward` path (allocation watermark + >= 2x throughput target
//! at batch 32 on multi-core hosts).
//!
//!   cargo bench --bench bench_engine
//!
//! The machine-readable successor of this harness is `bench_kernels`
//! (img/s + GB/s JSON per model x scheme x batch, fastpath kernel
//! speedups, and the CI regression gate) — see docs/BENCH.md.

use tcbnn::engine::{EngineExecutor, PlanCache, Planner};
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::{all_models, mnist_mlp};
use tcbnn::nn::ModelDef;
use tcbnn::sim::RTX2080TI;
use tcbnn::util::bench::Bencher;
use tcbnn::util::table::Table;
use tcbnn::util::Rng;

fn cifar_lite() -> ModelDef {
    ModelDef {
        name: "cifar-lite",
        dataset: "synthetic",
        input: Dims { hw: 16, feat: 3 },
        classes: 10,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 32,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinConv {
                c: 64,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 64, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

fn main() {
    let planner = Planner::new(&RTX2080TI);
    let buckets = [8usize, 32, 128];

    // ---- planner: predicted images/sec per Table-5 model x bucket ----
    // (simulated Turing throughput of the per-layer-optimal plan) and
    // plan-cache behaviour: a cold pass of misses, a warm pass of hits.
    let cache_dir = std::env::temp_dir()
        .join(format!("tcbnn_bench_engine_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let cache = PlanCache::open(&cache_dir).expect("plan cache dir");
    let mut t = Table::new(
        "engine planner: simulated img/s per model x bucket (RTX2080Ti)",
        &["model", "b8", "b32", "b128", "scheme mix (b128)"],
    );
    for _pass in 0..2 {
        // first pass populates (misses), second hits
        for m in all_models() {
            for &b in &buckets {
                let _ = cache.get_or_plan(&planner, &m, b);
            }
        }
    }
    for m in all_models() {
        let fps: Vec<String> = buckets
            .iter()
            .map(|&b| {
                format!("{:.0}", cache.get_or_plan(&planner, &m, b).throughput_fps())
            })
            .collect();
        let mix = cache
            .get_or_plan(&planner, &m, 128)
            .scheme_histogram()
            .iter()
            .map(|(n, c)| format!("{n}x{c}"))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(&[m.name.to_string(), fps[0].clone(), fps[1].clone(), fps[2].clone(), mix]);
    }
    println!("{}", t.render());
    println!(
        "plan cache: {} hits / {} misses ({} entries persisted under {:?})\n",
        cache.hits(),
        cache.misses(),
        all_models().len() * buckets.len(),
        cache_dir
    );
    let _ = t.write_csv("results", "bench_engine_planner");

    // ---- executor: real CPU images/sec, engine vs naive forward -----
    let b = Bencher::from_env();
    let mut exec_table = Table::new(
        "engine executor vs naive nn::forward (this machine)",
        &["model", "batch", "naive img/s", "engine img/s", "speedup"],
    );
    for model in [mnist_mlp(), cifar_lite()] {
        let mut rng = Rng::new(99);
        let weights = random_weights(&model, &mut rng);
        for &batch in &[8usize, 32] {
            let plan = planner.plan(&model, batch);
            let mut exec =
                EngineExecutor::new(model.clone(), &weights, plan).expect("executor");
            let x: Vec<f32> = (0..batch * model.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect();

            // MNIST-MLP cannot run through nn::forward (it has no
            // first-conv layer to consume fp input), so the naive
            // column only exists for conv models.
            let naive_fps = if matches!(
                model.layers.first(),
                Some(LayerSpec::FirstConv { .. })
            ) {
                let r = b.bench(
                    &format!("naive/{}/b{batch}", model.name),
                    batch as f64,
                    || {
                        std::hint::black_box(forward(&model, &weights, &x, batch));
                    },
                );
                Some(r.throughput())
            } else {
                None
            };

            // warm up, then assert the zero-allocation invariant
            let _ = exec.forward(&x, batch);
            let watermark = exec.arena_bytes();
            let r = b.bench(
                &format!("engine/{}/b{batch}", model.name),
                batch as f64,
                || {
                    std::hint::black_box(exec.forward(&x, batch));
                },
            );
            assert_eq!(
                exec.arena_bytes(),
                watermark,
                "arena grew during the bench — zero-allocation invariant broken"
            );
            let engine_fps = r.throughput();
            let (naive_s, speedup) = match naive_fps {
                Some(n) => (format!("{n:.0}"), format!("{:.2}x", engine_fps / n)),
                None => ("n/a".to_string(), "n/a".to_string()),
            };
            exec_table.row(&[
                model.name.to_string(),
                batch.to_string(),
                naive_s,
                format!("{engine_fps:.0}"),
                speedup,
            ]);
        }
    }
    println!("{}", exec_table.render());
    println!(
        "(speedup target: >= 2x at batch 32 on the conv model; achieved via \
         row-parallel scoped workers + packed word-level thresholding + \
         the allocation-free arena)"
    );
    let _ = exec_table.write_csv("results", "bench_engine_executor");
}
