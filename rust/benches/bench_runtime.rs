//! Bench: the REAL hot path — PJRT execution of the AOT artifacts and
//! the serving stack (this is wallclock on this machine, not the Turing
//! model). Requires `make artifacts`; skips cleanly otherwise.

use tcbnn::runtime::{Blob, MlpModel};
use tcbnn::util::bench::{write_csv, Bencher};

fn main() {
    let dir = tcbnn::artifact_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        println!("skipping bench_runtime: artifacts not built (make artifacts)");
        return;
    }
    let test = Blob::load(&format!("{dir}/testset")).expect("testset");
    let images = test.as_f32("images").unwrap();
    let mut model = MlpModel::load(&dir).expect("mlp artifacts");
    let b = Bencher::from_env();
    let mut results = Vec::new();
    for batch in [8usize, 32, 128] {
        let x = images[..batch * 800].to_vec();
        let r = b.bench(&format!("pjrt_mlp/batch{batch}"), batch as f64, || {
            std::hint::black_box(model.infer(&x, batch).unwrap());
        });
        println!(
            "  -> {:.0} img/s through the full L1+L2 HLO on CPU PJRT",
            r.throughput()
        );
        results.push(r);
    }
    // bit-packing hot path (the rust-side preprocessing cost)
    let mut rng = tcbnn::util::Rng::new(3);
    let row: Vec<f32> = (0..4096).map(|_| rng.next_f32() - 0.5).collect();
    let r = b.bench("pack_row/4096", 4096.0, || {
        std::hint::black_box(tcbnn::bitops::pack::pack_row(&row));
    });
    results.push(r);
    let _ = write_csv("results/bench_runtime.csv", &results);
}
