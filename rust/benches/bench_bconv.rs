//! Bench: Figs 20-23 (BConv general + specific, both GPUs) + functional
//! kernel wallclock.

use tcbnn::bitops::{BitTensor4, TensorLayout};
use tcbnn::kernels::bconv::{self, BconvProblem, BconvScheme};
use tcbnn::kernels::IoMode;
use tcbnn::sim::{RTX2080, RTX2080TI};
use tcbnn::util::bench::{write_csv, Bencher};
use tcbnn::util::Rng;

fn main() {
    for gpu in [&RTX2080TI, &RTX2080] {
        for mode in [IoMode::General, IoMode::BnnSpecific] {
            let t = tcbnn::figures::fig_bconv(gpu, mode);
            println!("{}", t.render());
            let tag = format!(
                "bench_bconv_{}_{}",
                if mode == IoMode::General { "general" } else { "specific" },
                gpu.name.to_lowercase()
            );
            let _ = t.write_csv("results", &tag);
        }
    }

    let b = Bencher::from_env();
    let mut rng = Rng::new(8);
    let p = BconvProblem { hw: 16, n: 8, c: 128, o: 32, k: 3, stride: 1, pad: 1 };
    let input = BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
    let filter = BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
    let mut results = Vec::new();
    println!("== functional BConv kernels, 16x16x8x128 -> 32 (CPU wallclock) ==");
    for s in bconv::all_schemes() {
        if !s.supports(p, IoMode::General) {
            continue;
        }
        let r = b.bench(&format!("bconv/{}", s.name()), p.ops(), || {
            std::hint::black_box(s.compute(&input, &filter, p));
        });
        results.push(r);
    }
    let _ = write_csv("results/bench_bconv_wallclock.csv", &results);
}
