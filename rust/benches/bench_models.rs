//! Bench: Tables 6-7 (all six models x six schemes x both GPUs),
//! Tables 8-9 (cross-platform) and Table 11 (ResNet depth).

use tcbnn::sim::{RTX2080, RTX2080TI};

fn main() {
    for gpu in [&RTX2080TI, &RTX2080] {
        let t = tcbnn::figures::tables_6_7(gpu);
        println!("{}", t.render());
        let _ = t.write_csv("results", &format!("bench_table6_7_{}", gpu.name.to_lowercase()));
    }
    let t89 = tcbnn::figures::tables_8_9(&RTX2080TI);
    println!("{}", t89.render());
    let _ = t89.write_csv("results", "bench_table8_9");
    let t11 = tcbnn::figures::table11_depth(&RTX2080);
    println!("{}", t11.render());
    let _ = t11.write_csv("results", "bench_table11");
    println!("{}", tcbnn::figures::table5().render());
}
