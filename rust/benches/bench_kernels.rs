//! Bench: the kernel/model throughput harness behind the CI regression
//! gate.  Measures img/s and GB/s per (model x scheme x batch) on this
//! machine, plus fastpath-vs-scalar kernel speedups on ResNet-18 block
//! shapes, and emits a machine-readable JSON document
//! (`BENCH_PR2.json`) that CI diffs against `benches/baseline.json`.
//!
//!   cargo bench --bench bench_kernels -- \
//!       [--quick]                    # CI settings (short measurements)
//!       [--out BENCH_PR2.json]      # where to write the JSON document
//!       [--check benches/baseline.json]   # regression gate (exit 1)
//!       [--write-baseline benches/baseline.json]  # refresh baseline
//!
//! Absolute img/s is machine-dependent, so the gate runs on *relative*
//! throughput: every scheme is normalized against an in-run reference
//! (the naive forward for conv models, the scalar engine for the MLP,
//! the best scalar scheme for kernel shapes).  See docs/BENCH.md.

use tcbnn::bitops::{BitMatrix, BitTensor4, Layout, TensorLayout};
use tcbnn::engine::json::Value;
use tcbnn::engine::{EngineExecutor, Planner};
use tcbnn::kernels::bconv::btc::BconvDesign1;
use tcbnn::kernels::bconv::bstc::BstcBconv;
use tcbnn::kernels::bconv::{BconvProblem, BconvScheme};
use tcbnn::kernels::bmm::btc::{Design1, Design3};
use tcbnn::kernels::bmm::{BmmProblem, BmmScheme};
use tcbnn::kernels::fastpath;
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::mnist_mlp;
use tcbnn::nn::{ModelDef, Scheme};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::bench::Bencher;
use tcbnn::util::cli::Args;
use tcbnn::util::threadpool::default_threads;
use tcbnn::util::Rng;

/// One measured cell of the model x scheme x batch grid.
struct Entry {
    name: String,
    model: String,
    scheme: String,
    batch: usize,
    img_s: f64,
    gb_s: f64,
}

fn cifar_lite() -> ModelDef {
    ModelDef {
        name: "cifar-lite",
        dataset: "synthetic",
        input: Dims { hw: 16, feat: 3 },
        classes: 10,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 32,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinConv {
                c: 64,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 64, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

/// Streamed bytes per image for the GB/s column: fp input + packed
/// weights (re-read each batch).
fn bytes_per_img(m: &ModelDef) -> f64 {
    (m.input.flat() * 4) as f64 + m.weight_bits() as f64 / 8.0
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let out_path = args.get_or("out", "BENCH_PR2.json");
    let b = if quick { Bencher::quick() } else { Bencher::from_env() };
    let threads = default_threads();
    let planner = Planner::new(&RTX2080TI);
    let batches: &[usize] = if quick { &[8] } else { &[8, 32] };

    let mut entries: Vec<Entry> = Vec::new();
    let mut ratios: Vec<(String, f64)> = Vec::new();

    // ---- model x scheme x batch: executed img/s on this machine ----
    for model in [mnist_mlp(), cifar_lite()] {
        let mut rng = Rng::new(99);
        let weights = random_weights(&model, &mut rng);
        let bpi = bytes_per_img(&model);
        for &batch in batches {
            let x: Vec<f32> = (0..batch * model.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let has_naive =
                matches!(model.layers.first(), Some(LayerSpec::FirstConv { .. }));

            let mut cell = |scheme: &str, img_s: f64| {
                entries.push(Entry {
                    name: format!("model/{}/{}/b{batch}", model.name, scheme),
                    model: model.name.to_string(),
                    scheme: scheme.to_string(),
                    batch,
                    img_s,
                    gb_s: img_s * bpi / 1e9,
                });
                img_s
            };

            let naive_fps = if has_naive {
                let r = b.bench(
                    &format!("naive/{}/b{batch}", model.name),
                    batch as f64,
                    || {
                        std::hint::black_box(forward(&model, &weights, &x, batch));
                    },
                );
                Some(cell("naive", r.throughput()))
            } else {
                None
            };

            let mut engine = EngineExecutor::new(
                model.clone(),
                &weights,
                planner.plan(&model, batch),
            )
            .expect("scalar engine executor");
            let r = b.bench(
                &format!("engine/{}/b{batch}", model.name),
                batch as f64,
                || {
                    std::hint::black_box(engine.forward(&x, batch));
                },
            );
            let engine_fps = cell("engine", r.throughput());

            let mut fast = EngineExecutor::new(
                model.clone(),
                &weights,
                planner.plan_fixed(&model, batch, Scheme::Fastpath),
            )
            .expect("fastpath engine executor");
            let r = b.bench(
                &format!("fastpath/{}/b{batch}", model.name),
                batch as f64,
                || {
                    std::hint::black_box(fast.forward(&x, batch));
                },
            );
            let fast_fps = cell("fastpath", r.throughput());

            match naive_fps {
                Some(n) => {
                    ratios.push((
                        format!("model/{}/b{batch}/engine_vs_naive", model.name),
                        engine_fps / n,
                    ));
                    ratios.push((
                        format!("model/{}/b{batch}/fastpath_vs_naive", model.name),
                        fast_fps / n,
                    ));
                }
                None => ratios.push((
                    format!("model/{}/b{batch}/fastpath_vs_engine", model.name),
                    fast_fps / engine_fps,
                )),
            }
        }
    }

    // ---- ResNet-18 block shapes: fastpath vs best scalar scheme ----
    // bconv at the paper's ResNet-18 interior stages (c=o=256 @14x14,
    // c=o=512 @7x7, 3x3/s1/p1), batch 8
    let mut rng = Rng::new(7);
    let conv_shapes =
        [("r18-bconv-c256-hw14", 14usize, 256usize), ("r18-bconv-c512-hw7", 7, 512)];
    for (tag, hw, c) in conv_shapes {
        let p = BconvProblem { hw, n: 8, c, o: c, k: 3, stride: 1, pad: 1 };
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        let op_bytes = p.input_bytes() + p.filter_bytes() + (p.out_elems() * 4) as f64;

        let mut best_scalar = 0.0f64;
        for (sname, scheme) in [
            ("bmma", &BconvDesign1 as &dyn BconvScheme),
            ("sbnn64", &BstcBconv::new(64) as &dyn BconvScheme),
        ] {
            let r = b.bench(&format!("kernel/{tag}/{sname}"), p.n as f64, || {
                std::hint::black_box(scheme.compute(&input, &filter, p));
            });
            let fps = r.throughput();
            best_scalar = best_scalar.max(fps);
            entries.push(Entry {
                name: format!("kernel/{tag}/{sname}"),
                model: tag.to_string(),
                scheme: sname.to_string(),
                batch: p.n,
                img_s: fps,
                gb_s: fps / p.n as f64 * op_bytes / 1e9,
            });
        }
        let r = b.bench(&format!("kernel/{tag}/fastpath"), p.n as f64, || {
            std::hint::black_box(fastpath::bconv::bconv(&input, &filter, p, threads));
        });
        let fast_fps = r.throughput();
        entries.push(Entry {
            name: format!("kernel/{tag}/fastpath"),
            model: tag.to_string(),
            scheme: "fastpath".to_string(),
            batch: p.n,
            img_s: fast_fps,
            gb_s: fast_fps / p.n as f64 * op_bytes / 1e9,
        });
        ratios.push((
            format!("kernel/{tag}/fastpath_vs_scalar"),
            fast_fps / best_scalar,
        ));
    }

    // bmm at the ResNet-18 FC shape (512 -> 512) over a 64-row batch
    {
        let tag = "r18-bmm-m64-n512-k512";
        let p = BmmProblem { m: 64, n: 512, k: 512 };
        let a = BitMatrix::random(p.m, p.k, Layout::RowMajor, &mut rng);
        let bm = BitMatrix::random(p.k, p.n, Layout::ColMajor, &mut rng);
        let op_bytes = p.operand_bytes() + (p.m * p.n * 4) as f64;
        let mut best_scalar = 0.0f64;
        for (sname, scheme) in [
            ("bmma", &Design1 as &dyn BmmScheme),
            ("bmmafmt", &Design3 as &dyn BmmScheme),
        ] {
            let r = b.bench(&format!("kernel/{tag}/{sname}"), p.m as f64, || {
                std::hint::black_box(scheme.compute(&a, &bm));
            });
            let fps = r.throughput();
            best_scalar = best_scalar.max(fps);
            entries.push(Entry {
                name: format!("kernel/{tag}/{sname}"),
                model: tag.to_string(),
                scheme: sname.to_string(),
                batch: p.m,
                img_s: fps,
                gb_s: fps / p.m as f64 * op_bytes / 1e9,
            });
        }
        let r = b.bench(&format!("kernel/{tag}/fastpath"), p.m as f64, || {
            std::hint::black_box(fastpath::bmm::bmm(&a, &bm, threads));
        });
        let fast_fps = r.throughput();
        entries.push(Entry {
            name: format!("kernel/{tag}/fastpath"),
            model: tag.to_string(),
            scheme: "fastpath".to_string(),
            batch: p.m,
            img_s: fast_fps,
            gb_s: fast_fps / p.m as f64 * op_bytes / 1e9,
        });
        ratios.push((
            format!("kernel/{tag}/fastpath_vs_scalar"),
            fast_fps / best_scalar,
        ));
    }

    // ---- report + JSON ----
    let min_kernel_speedup = ratios
        .iter()
        .filter(|(n, _)| n.starts_with("kernel/"))
        .map(|(_, v)| *v)
        .fold(f64::INFINITY, f64::min);
    println!("\n{:<52} {:>12} {:>10}", "entry", "img/s", "GB/s");
    for e in &entries {
        println!("{:<52} {:>12.1} {:>10.3}", e.name, e.img_s, e.gb_s);
    }
    println!("\nratios (current run):");
    for (n, v) in &ratios {
        println!("  {n:<58} {v:.2}x");
    }
    println!(
        "\nfastpath speedup over best scalar scheme on ResNet-18 shapes: \
         >= {min_kernel_speedup:.2}x (target: >= 2x)"
    );

    let doc = Value::Obj(vec![
        ("schema".to_string(), Value::Num(1.0)),
        (
            "mode".to_string(),
            Value::Str(if quick { "quick" } else { "full" }.to_string()),
        ),
        ("threads".to_string(), Value::Num(threads as f64)),
        (
            "entries".to_string(),
            Value::Arr(
                entries
                    .iter()
                    .map(|e| {
                        Value::Obj(vec![
                            ("name".to_string(), Value::Str(e.name.clone())),
                            ("model".to_string(), Value::Str(e.model.clone())),
                            ("scheme".to_string(), Value::Str(e.scheme.clone())),
                            ("batch".to_string(), Value::Num(e.batch as f64)),
                            ("img_s".to_string(), Value::Num(e.img_s)),
                            ("gb_s".to_string(), Value::Num(e.gb_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "ratios".to_string(),
            Value::Arr(
                ratios
                    .iter()
                    .map(|(n, v)| {
                        Value::Obj(vec![
                            ("name".to_string(), Value::Str(n.clone())),
                            ("value".to_string(), Value::Num(*v)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(out_path, format!("{doc}\n")).expect("write bench JSON");
    println!("\nwrote {out_path}");

    if let Some(path) = args.get("write-baseline") {
        // 0.9x headroom so run-to-run noise does not trip the gate
        let base = Value::Obj(vec![
            ("schema".to_string(), Value::Num(1.0)),
            ("threshold".to_string(), Value::Num(0.8)),
            (
                "ratios".to_string(),
                Value::Arr(
                    ratios
                        .iter()
                        .map(|(n, v)| {
                            Value::Obj(vec![
                                ("name".to_string(), Value::Str(n.clone())),
                                ("value".to_string(), Value::Num(v * 0.9)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, format!("{base}\n")).expect("write baseline");
        println!("wrote baseline {path}");
    }

    if let Some(path) = args.get("check") {
        match check_baseline(path, &ratios) {
            Ok(n) => println!("regression gate: {n} baseline ratios OK"),
            Err(msg) => {
                eprintln!("regression gate FAILED:\n{msg}");
                std::process::exit(1);
            }
        }
    }
}

/// Compare current ratios against the committed baseline.  A scheme
/// regresses when its relative throughput drops below
/// `baseline * threshold` (default 0.8, i.e. a >20% regression).
fn check_baseline(path: &str, ratios: &[(String, f64)]) -> Result<usize, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read baseline {path}: {e}"))?;
    let doc = Value::parse(&text).map_err(|e| format!("baseline {path}: {e}"))?;
    let threshold = doc
        .get("threshold")
        .and_then(Value::as_f64)
        .unwrap_or(0.8);
    let base = doc
        .get("ratios")
        .and_then(Value::as_arr)
        .ok_or_else(|| format!("baseline {path}: no \"ratios\" array"))?;
    let mut failures = Vec::new();
    let mut checked = 0usize;
    for item in base {
        let name = item
            .get("name")
            .and_then(Value::as_str)
            .ok_or("baseline ratio without name")?;
        let want = item
            .get("value")
            .and_then(Value::as_f64)
            .ok_or("baseline ratio without value")?;
        match ratios.iter().find(|(n, _)| n == name) {
            None => failures.push(format!("  {name}: missing from this run")),
            Some((_, got)) => {
                checked += 1;
                if *got < want * threshold {
                    failures.push(format!(
                        "  {name}: {got:.2}x < baseline {want:.2}x * {threshold} \
                         (>{:.0}% regression)",
                        (1.0 - threshold) * 100.0
                    ));
                }
            }
        }
    }
    if failures.is_empty() {
        Ok(checked)
    } else {
        Err(failures.join("\n"))
    }
}
