//! Bench: the kernel/model throughput harness behind the CI regression
//! gate.  Measures img/s, GB/s, and per-iteration latency percentiles
//! (p50/p95/p99) per (model x scheme x batch) on this machine, plus
//! fastpath-vs-scalar kernel speedups on ResNet-18 block shapes and
//! per-`PopcountEngine` SIMD-vs-fastpath ratios (engines actually
//! available in-run, recorded under `simd_engines`), and emits a
//! machine-readable JSON document (`BENCH_PR2.json`) that CI diffs
//! against `benches/baseline.json`.
//!
//!   cargo bench --bench bench_kernels -- \
//!       [--list-schemes]             # print BackendRegistry names, exit
//!       [--quick]                    # CI settings (short measurements)
//!       [--seed 99]                  # input-generation seed (default 99)
//!       [--out BENCH_PR2.json]      # where to write the JSON document
//!       [--check benches/baseline.json]   # regression gate (exit 1)
//!       [--write-baseline benches/baseline.json]  # refresh baseline
//!
//! Absolute img/s is machine-dependent, so the gate runs on *relative*
//! throughput: every scheme is normalized against an in-run reference
//! (the naive forward for conv models, the scalar engine for the MLP,
//! the best scalar scheme for kernel shapes).  The per-scheme section
//! runs one fixed plan per registered backend; the run aborts (failing
//! `bench-smoke`) if the emitted scheme list does not match
//! `BackendRegistry::names()`.
//!
//! Each fixed-plan cell also records the simulator-vs-execution
//! `cost_gap`: the plan's predicted total seconds next to the measured
//! p50, plus a symmetric accuracy ratio `min(pred/meas, meas/pred)` in
//! (0, 1].  Host (calibratable) schemes gate that accuracy through
//! `benches/baseline.json` — a cost-model regression in EITHER
//! direction shrinks the ratio and fails CI.  See docs/BENCH.md.

use tcbnn::bitops::{BitMatrix, BitTensor4, Layout, TensorLayout};
use tcbnn::engine::json::Value;
use tcbnn::engine::{EngineExecutor, Planner};
use tcbnn::kernels::backend::BackendRegistry;
use tcbnn::layout::{repack, LayoutDesc, LayoutKind};
use tcbnn::kernels::bconv::btc::BconvDesign1;
use tcbnn::kernels::bconv::bstc::BstcBconv;
use tcbnn::kernels::bconv::{BconvProblem, BconvScheme};
use tcbnn::kernels::bmm::btc::{Design1, Design3};
use tcbnn::kernels::bmm::{BmmProblem, BmmScheme};
use tcbnn::kernels::fastpath;
use tcbnn::kernels::simd::{self, PopcountEngine};
use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::{gcn_grid, gcn_powerlaw, mnist_mlp};
use tcbnn::nn::{ModelDef, Scheme};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::bench::{BenchResult, Bencher};
use tcbnn::util::cli::Args;
use tcbnn::util::threadpool::default_threads;
use tcbnn::util::Rng;

/// One measured cell of the model x scheme x batch grid.
struct Entry {
    name: String,
    model: String,
    scheme: String,
    batch: usize,
    img_s: f64,
    gb_s: f64,
    /// per-iteration latency percentiles (seconds)
    lat_p50_s: f64,
    lat_p95_s: f64,
    lat_p99_s: f64,
}

impl Entry {
    fn from_result(
        name: String,
        model: &str,
        scheme: &str,
        batch: usize,
        r: &BenchResult,
        bytes_per_unit: f64,
    ) -> Entry {
        let img_s = r.throughput();
        Entry {
            name,
            model: model.to_string(),
            scheme: scheme.to_string(),
            batch,
            img_s,
            gb_s: img_s * bytes_per_unit / 1e9,
            lat_p50_s: r.summary.p50,
            lat_p95_s: r.summary.p95,
            lat_p99_s: r.summary.p99,
        }
    }
}

fn cifar_lite() -> ModelDef {
    ModelDef {
        name: "cifar-lite",
        dataset: "synthetic",
        input: Dims { hw: 16, feat: 3 },
        classes: 10,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 32,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinConv {
                c: 64,
                o: 64,
                k: 3,
                stride: 1,
                pad: 1,
                pool: true,
                residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 64, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

/// Streamed bytes per image for the GB/s column: fp input + packed
/// weights (re-read each batch).
fn bytes_per_img(m: &ModelDef) -> f64 {
    (m.input.flat() * 4) as f64 + m.weight_bits() as f64 / 8.0
}

fn main() {
    let args = Args::from_env();
    let registry = BackendRegistry::global();
    if args.flag("list-schemes") {
        // the satellite CLI face of BackendRegistry::names()
        for name in registry.names() {
            println!("{name}");
        }
        return;
    }
    let quick = args.flag("quick");
    // --seed threads through ALL input generation (model weights,
    // activations, kernel operands) so any run — in particular --quick
    // CI runs — is reproducible end to end and perturbable on demand
    let seed = args.get_usize("seed", 99) as u64;
    let out_path = args.get_or("out", "BENCH_PR2.json");
    let b = if quick { Bencher::quick() } else { Bencher::from_env() };
    let threads = default_threads();
    let planner = Planner::new(&RTX2080TI);
    let batches: &[usize] = if quick { &[8] } else { &[8, 32] };

    let mut entries: Vec<Entry> = Vec::new();
    let mut ratios: Vec<(String, f64)> = Vec::new();
    // simulated-vs-executed gap per fixed-plan cell:
    // (model, scheme, batch, predicted total secs, measured p50 secs,
    // symmetric accuracy in (0, 1])
    let mut cost_gaps: Vec<(String, String, usize, f64, f64, f64)> = Vec::new();

    // ---- model x scheme x batch: executed img/s on this machine ----
    for model in [mnist_mlp(), cifar_lite()] {
        let mut rng = Rng::new(seed);
        let weights = random_weights(&model, &mut rng);
        let bpi = bytes_per_img(&model);
        for &batch in batches {
            let x: Vec<f32> = (0..batch * model.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let has_naive =
                matches!(model.layers.first(), Some(LayerSpec::FirstConv { .. }));

            let naive_fps = if has_naive {
                let r = b.bench(
                    &format!("naive/{}/b{batch}", model.name),
                    batch as f64,
                    || {
                        std::hint::black_box(forward(&model, &weights, &x, batch));
                    },
                );
                entries.push(Entry::from_result(
                    format!("model/{}/naive/b{batch}", model.name),
                    model.name,
                    "naive",
                    batch,
                    &r,
                    bpi,
                ));
                Some(r.throughput())
            } else {
                None
            };

            let mut engine = EngineExecutor::new(
                model.clone(),
                &weights,
                planner.plan(&model, batch),
            )
            .expect("searched-plan engine executor");
            let r = b.bench(
                &format!("engine/{}/b{batch}", model.name),
                batch as f64,
                || {
                    std::hint::black_box(engine.forward(&x, batch));
                },
            );
            entries.push(Entry::from_result(
                format!("model/{}/engine/b{batch}", model.name),
                model.name,
                "engine",
                batch,
                &r,
                bpi,
            ));
            let engine_fps = r.throughput();

            // one fixed plan per REGISTERED backend: per-scheme img/s +
            // latency percentiles, and the scheme-list completeness
            // check below
            let mut fast_fps = 0.0f64;
            for scheme in registry.schemes() {
                let plan = planner.plan_fixed(&model, batch, scheme);
                // capture the simulator's prediction before the plan
                // moves into the executor (cost_gap section below)
                let predicted_s = plan.total_secs;
                let mut exec = EngineExecutor::new(model.clone(), &weights, plan)
                    .unwrap_or_else(|e| {
                        panic!("{} executor for {}: {e}", scheme.name(), model.name)
                    });
                let r = b.bench(
                    &format!("scheme/{}/{}/b{batch}", model.name, scheme.name()),
                    batch as f64,
                    || {
                        std::hint::black_box(exec.forward(&x, batch));
                    },
                );
                if scheme == tcbnn::nn::Scheme::Fastpath {
                    // feeds the fastpath_vs_* gate ratios below (the
                    // baseline gate compares ratio names, not entries)
                    fast_fps = r.throughput();
                }
                entries.push(Entry::from_result(
                    format!("model/{}/scheme/{}/b{batch}", model.name, scheme.name()),
                    model.name,
                    scheme.name(),
                    batch,
                    &r,
                    bpi,
                ));
                // ROADMAP (d): simulated vs executed, per scheme.  The
                // accuracy is symmetric — min(pred/meas, meas/pred) —
                // so drifting slow OR fast both shrink it below 1.
                let measured_s = r.summary.p50;
                let accuracy = if predicted_s > 0.0 && measured_s > 0.0 {
                    (predicted_s / measured_s).min(measured_s / predicted_s)
                } else {
                    0.0
                };
                cost_gaps.push((
                    model.name.to_string(),
                    scheme.name().to_string(),
                    batch,
                    predicted_s,
                    measured_s,
                    accuracy,
                ));
                // only host backends predict THIS machine (GPU schemes
                // predict a simulated 2080 Ti — their gap is
                // informational, not gateable)
                if registry
                    .get(scheme)
                    .is_some_and(tcbnn::tuner::microbench::is_host_backend)
                {
                    ratios.push((
                        format!(
                            "cost_gap/{}/b{batch}/{}_accuracy",
                            model.name,
                            scheme.name()
                        ),
                        accuracy,
                    ));
                }
            }

            match naive_fps {
                Some(n) => {
                    ratios.push((
                        format!("model/{}/b{batch}/engine_vs_naive", model.name),
                        engine_fps / n,
                    ));
                    ratios.push((
                        format!("model/{}/b{batch}/fastpath_vs_naive", model.name),
                        fast_fps / n,
                    ));
                }
                None => ratios.push((
                    format!("model/{}/b{batch}/fastpath_vs_engine", model.name),
                    fast_fps / engine_fps,
                )),
            }
        }
    }

    // ---- GNN models: sparse schemes vs fastpath at b8 ----
    // The adjacency-density crossover the planner models (see
    // docs/ENGINE.md): the power-law graph is sparse enough for the
    // SPMM/GCN-FUSED backends to win, the denser grid graph is the
    // control.  The `model/<name>/b8/sparse_vs_fastpath` family is
    // floor-gated by the CI gnn-smoke job via benches/baseline.json.
    for model in [gcn_powerlaw(), gcn_grid()] {
        let mut rng = Rng::new(seed);
        let weights = random_weights(&model, &mut rng);
        let bpi = bytes_per_img(&model);
        let batch = 8usize; // one bucket keeps the GNN section cheap
        let x: Vec<f32> = (0..batch * model.input.flat())
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let mut fast_fps = 0.0f64;
        let mut best_sparse = 0.0f64;
        for scheme in [Scheme::Fastpath, Scheme::Spmm, Scheme::GcnFused] {
            let plan = planner.plan_fixed(&model, batch, scheme);
            let mut exec = EngineExecutor::new(model.clone(), &weights, plan)
                .unwrap_or_else(|e| {
                    panic!("{} executor for {}: {e}", scheme.name(), model.name)
                });
            let r = b.bench(
                &format!("scheme/{}/{}/b{batch}", model.name, scheme.name()),
                batch as f64,
                || {
                    std::hint::black_box(exec.forward(&x, batch));
                },
            );
            entries.push(Entry::from_result(
                format!("model/{}/scheme/{}/b{batch}", model.name, scheme.name()),
                model.name,
                scheme.name(),
                batch,
                &r,
                bpi,
            ));
            if scheme == Scheme::Fastpath {
                fast_fps = r.throughput();
            } else {
                best_sparse = best_sparse.max(r.throughput());
            }
        }
        ratios.push((
            format!("model/{}/b{batch}/sparse_vs_fastpath", model.name),
            best_sparse / fast_fps,
        ));
    }

    // the emitted per-scheme list must match the registry exactly —
    // bench-smoke runs this binary, so a drift fails CI
    {
        let mut emitted: Vec<&str> = entries
            .iter()
            .filter(|e| e.name.contains("/scheme/"))
            .map(|e| e.scheme.as_str())
            .collect();
        emitted.sort();
        emitted.dedup();
        let mut want: Vec<&str> = registry.names();
        want.sort();
        assert_eq!(
            emitted, want,
            "emitted scheme list does not match BackendRegistry::names()"
        );
    }

    // ---- ResNet-18 block shapes: fastpath vs best scalar scheme ----
    // bconv at the paper's ResNet-18 interior stages (c=o=256 @14x14,
    // c=o=512 @7x7, 3x3/s1/p1), batch 8
    let mut rng = Rng::new(seed.wrapping_mul(31).wrapping_add(7));
    let conv_shapes =
        [("r18-bconv-c256-hw14", 14usize, 256usize), ("r18-bconv-c512-hw7", 7, 512)];
    for (tag, hw, c) in conv_shapes {
        let p = BconvProblem { hw, n: 8, c, o: c, k: 3, stride: 1, pad: 1 };
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        let op_bytes = p.input_bytes() + p.filter_bytes() + (p.out_elems() * 4) as f64;

        let mut best_scalar = 0.0f64;
        for (sname, scheme) in [
            ("bmma", &BconvDesign1 as &dyn BconvScheme),
            ("sbnn64", &BstcBconv::new(64) as &dyn BconvScheme),
        ] {
            let r = b.bench(&format!("kernel/{tag}/{sname}"), p.n as f64, || {
                std::hint::black_box(scheme.compute(&input, &filter, p));
            });
            best_scalar = best_scalar.max(r.throughput());
            entries.push(Entry::from_result(
                format!("kernel/{tag}/{sname}"),
                tag,
                sname,
                p.n,
                &r,
                op_bytes / p.n as f64,
            ));
        }
        let r = b.bench(&format!("kernel/{tag}/fastpath"), p.n as f64, || {
            std::hint::black_box(fastpath::bconv::bconv(&input, &filter, p, threads));
        });
        let fast_fps = r.throughput();
        entries.push(Entry::from_result(
            format!("kernel/{tag}/fastpath"),
            tag,
            "fastpath",
            p.n,
            &r,
            op_bytes / p.n as f64,
        ));
        ratios.push((
            format!("kernel/{tag}/fastpath_vs_scalar"),
            fast_fps / best_scalar,
        ));
        // SIMD backend per popcount engine available on THIS runner.
        // Only the portable engine carries a committed baseline floor —
        // CI runners are heterogeneous, so the wider-vector ratios are
        // informational unless a floor is added deliberately.
        for engine in PopcountEngine::available() {
            let ename = engine.name();
            let r = b.bench(&format!("kernel/{tag}/simd-{ename}"), p.n as f64, || {
                std::hint::black_box(simd::bconv(&input, &filter, p, threads, engine));
            });
            entries.push(Entry::from_result(
                format!("kernel/{tag}/simd-{ename}"),
                tag,
                &format!("simd-{ename}"),
                p.n,
                &r,
                op_bytes / p.n as f64,
            ));
            ratios.push((
                format!("kernel/{tag}/simd_{ename}_vs_fastpath"),
                r.throughput() / fast_fps,
            ));
        }
    }

    // bmm at the ResNet-18 FC shape (512 -> 512) over a 64-row batch
    {
        let tag = "r18-bmm-m64-n512-k512";
        let p = BmmProblem { m: 64, n: 512, k: 512 };
        let a = BitMatrix::random(p.m, p.k, Layout::RowMajor, &mut rng);
        let bm = BitMatrix::random(p.k, p.n, Layout::ColMajor, &mut rng);
        let op_bytes = p.operand_bytes() + (p.m * p.n * 4) as f64;
        let mut best_scalar = 0.0f64;
        for (sname, scheme) in [
            ("bmma", &Design1 as &dyn BmmScheme),
            ("bmmafmt", &Design3 as &dyn BmmScheme),
        ] {
            let r = b.bench(&format!("kernel/{tag}/{sname}"), p.m as f64, || {
                std::hint::black_box(scheme.compute(&a, &bm));
            });
            best_scalar = best_scalar.max(r.throughput());
            entries.push(Entry::from_result(
                format!("kernel/{tag}/{sname}"),
                tag,
                sname,
                p.m,
                &r,
                op_bytes / p.m as f64,
            ));
        }
        let r = b.bench(&format!("kernel/{tag}/fastpath"), p.m as f64, || {
            std::hint::black_box(fastpath::bmm::bmm(&a, &bm, threads));
        });
        let fast_fps = r.throughput();
        entries.push(Entry::from_result(
            format!("kernel/{tag}/fastpath"),
            tag,
            "fastpath",
            p.m,
            &r,
            op_bytes / p.m as f64,
        ));
        ratios.push((
            format!("kernel/{tag}/fastpath_vs_scalar"),
            fast_fps / best_scalar,
        ));
        for engine in PopcountEngine::available() {
            let ename = engine.name();
            let r = b.bench(&format!("kernel/{tag}/simd-{ename}"), p.m as f64, || {
                std::hint::black_box(simd::bmm(&a, &bm, threads, engine));
            });
            entries.push(Entry::from_result(
                format!("kernel/{tag}/simd-{ename}"),
                tag,
                &format!("simd-{ename}"),
                p.m,
                &r,
                op_bytes / p.m as f64,
            ));
            ratios.push((
                format!("kernel/{tag}/simd_{ename}_vs_fastpath"),
                r.throughput() / fast_fps,
            ));
        }
    }

    // ---- layout repack bandwidth (GB/s per pair) ----
    // every registered conversion pair over one mid-size image, plus an
    // in-run u32 copy reference so the gate runs on a *relative* ratio
    // (repack bandwidth vs plain copy bandwidth transfers across hosts)
    let mut repack_cells: Vec<(String, f64)> = Vec::new();
    {
        let (lines, bits) = (128usize, 4096usize);
        let m = BitMatrix::random(lines, bits, Layout::RowMajor, &mut rng);
        let base = repack::BitImage::from_rows32(lines, bits, m.data);
        let base_words = match &base.words {
            tcbnn::layout::Words::W32(v) => v.clone(),
            _ => unreachable!("Row32 is u32-worded"),
        };
        let copy_bytes = 2.0 * (base_words.len() * 4) as f64; // read + write
        let r = b.bench("repack/copy-row32", 1.0, || {
            std::hint::black_box(base_words.to_vec());
        });
        let copy_gbs = copy_bytes / r.summary.p50 / 1e9;
        for (src, dst) in repack::all_pairs() {
            let src_img = repack::convert(&base, src);
            let pair = repack::pair_name(src, dst);
            let r = b.bench(&format!("repack/{pair}"), 1.0, || {
                std::hint::black_box(repack::convert(&src_img, dst));
            });
            let bytes = (src_img.desc.storage_bytes()
                + LayoutDesc::new(dst, lines, bits).storage_bytes())
                as f64;
            let gbs = bytes / r.summary.p50 / 1e9;
            entries.push(Entry {
                name: format!("repack/{pair}"),
                model: "repack".to_string(),
                scheme: pair.clone(),
                batch: lines,
                img_s: 1.0 / r.summary.p50,
                gb_s: gbs,
                lat_p50_s: r.summary.p50,
                lat_p95_s: r.summary.p95,
                lat_p99_s: r.summary.p99,
            });
            repack_cells.push((pair.clone(), gbs));
            // gate only the hot executor pairs (word pairing should run
            // near copy speed; the tiled FSB paths are informational)
            if matches!(
                (src, dst),
                (LayoutKind::Row32, LayoutKind::Blocked64)
                    | (LayoutKind::Blocked64, LayoutKind::Row32)
            ) {
                ratios.push((format!("repack/{pair}_vs_copy"), gbs / copy_gbs));
            }
        }
    }

    // ---- report + JSON ----
    let min_kernel_speedup = ratios
        .iter()
        .filter(|(n, _)| n.starts_with("kernel/") && n.ends_with("_vs_scalar"))
        .map(|(_, v)| *v)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\n{:<52} {:>12} {:>10} {:>11} {:>11}",
        "entry", "img/s", "GB/s", "p50 (us)", "p99 (us)"
    );
    for e in &entries {
        println!(
            "{:<52} {:>12.1} {:>10.3} {:>11.1} {:>11.1}",
            e.name,
            e.img_s,
            e.gb_s,
            e.lat_p50_s * 1e6,
            e.lat_p99_s * 1e6
        );
    }
    println!("\ncost gap (simulated vs executed, per fixed-scheme plan):");
    for (model, scheme, batch, pred, meas, acc) in &cost_gaps {
        println!(
            "  {model:<12} {scheme:<10} b{batch:<4} pred {:>9.1} us  \
             p50 {:>9.1} us  accuracy {acc:.3}",
            pred * 1e6,
            meas * 1e6
        );
    }
    println!("\nratios (current run):");
    for (n, v) in &ratios {
        println!("  {n:<58} {v:.2}x");
    }
    println!(
        "\nfastpath speedup over best scalar scheme on ResNet-18 shapes: \
         >= {min_kernel_speedup:.2}x (target: >= 2x)"
    );

    let doc = Value::Obj(vec![
        ("schema".to_string(), Value::Num(4.0)),
        (
            "mode".to_string(),
            Value::Str(if quick { "quick" } else { "full" }.to_string()),
        ),
        ("threads".to_string(), Value::Num(threads as f64)),
        ("seed".to_string(), Value::Num(seed as f64)),
        (
            "schemes".to_string(),
            Value::Arr(
                registry
                    .names()
                    .iter()
                    .map(|n| Value::Str(n.to_string()))
                    .collect(),
            ),
        ),
        (
            // popcount engines actually exercised by this run's
            // kernel/<tag>/simd_* ratios (host-dependent)
            "simd_engines".to_string(),
            Value::Arr(
                PopcountEngine::available()
                    .into_iter()
                    .map(|e| Value::Str(e.name().to_string()))
                    .collect(),
            ),
        ),
        (
            "entries".to_string(),
            Value::Arr(
                entries
                    .iter()
                    .map(|e| {
                        Value::Obj(vec![
                            ("name".to_string(), Value::Str(e.name.clone())),
                            ("model".to_string(), Value::Str(e.model.clone())),
                            ("scheme".to_string(), Value::Str(e.scheme.clone())),
                            ("batch".to_string(), Value::Num(e.batch as f64)),
                            ("img_s".to_string(), Value::Num(e.img_s)),
                            ("gb_s".to_string(), Value::Num(e.gb_s)),
                            ("lat_p50_s".to_string(), Value::Num(e.lat_p50_s)),
                            ("lat_p95_s".to_string(), Value::Num(e.lat_p95_s)),
                            ("lat_p99_s".to_string(), Value::Num(e.lat_p99_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "repacks".to_string(),
            Value::Arr(
                repack_cells
                    .iter()
                    .map(|(pair, gbs)| {
                        Value::Obj(vec![
                            ("pair".to_string(), Value::Str(pair.clone())),
                            ("gb_s".to_string(), Value::Num(*gbs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "cost_gap".to_string(),
            Value::Arr(
                cost_gaps
                    .iter()
                    .map(|(model, scheme, batch, pred, meas, acc)| {
                        Value::Obj(vec![
                            ("model".to_string(), Value::Str(model.clone())),
                            ("scheme".to_string(), Value::Str(scheme.clone())),
                            ("batch".to_string(), Value::Num(*batch as f64)),
                            ("predicted_s".to_string(), Value::Num(*pred)),
                            ("measured_p50_s".to_string(), Value::Num(*meas)),
                            ("accuracy".to_string(), Value::Num(*acc)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "ratios".to_string(),
            Value::Arr(
                ratios
                    .iter()
                    .map(|(n, v)| {
                        Value::Obj(vec![
                            ("name".to_string(), Value::Str(n.clone())),
                            ("value".to_string(), Value::Num(*v)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(out_path, format!("{doc}\n")).expect("write bench JSON");
    println!("\nwrote {out_path}");

    if let Some(path) = args.get("write-baseline") {
        // 0.9x headroom so run-to-run noise does not trip the gate
        let base = Value::Obj(vec![
            ("schema".to_string(), Value::Num(1.0)),
            ("threshold".to_string(), Value::Num(0.8)),
            (
                "note".to_string(),
                Value::Str(
                    "Relative-throughput baseline for the bench_kernels CI \
                     gate; a run fails when any ratio drops below \
                     value*threshold. Refresh: cargo bench --bench \
                     bench_kernels -- --quick --write-baseline \
                     benches/baseline.json (0.9x headroom applied); review \
                     the diff before committing. See docs/BENCH.md."
                        .to_string(),
                ),
            ),
            (
                "ratios".to_string(),
                Value::Arr(
                    ratios
                        .iter()
                        .map(|(n, v)| {
                            Value::Obj(vec![
                                ("name".to_string(), Value::Str(n.clone())),
                                ("value".to_string(), Value::Num(v * 0.9)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, format!("{base}\n")).expect("write baseline");
        println!("wrote baseline {path}");
    }

    if let Some(path) = args.get("check") {
        match check_baseline(path, &ratios) {
            Ok(n) => println!("regression gate: {n} baseline ratios OK"),
            Err(msg) => {
                eprintln!("regression gate FAILED:\n{msg}");
                std::process::exit(1);
            }
        }
    }
}

/// Compare current ratios against the committed baseline.  A scheme
/// regresses when its relative throughput drops below
/// `baseline * threshold` (default 0.8, i.e. a >20% regression).
fn check_baseline(path: &str, ratios: &[(String, f64)]) -> Result<usize, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read baseline {path}: {e}"))?;
    let doc = Value::parse(&text).map_err(|e| format!("baseline {path}: {e}"))?;
    let threshold = doc
        .get("threshold")
        .and_then(Value::as_f64)
        .unwrap_or(0.8);
    let base = doc
        .get("ratios")
        .and_then(Value::as_arr)
        .ok_or_else(|| format!("baseline {path}: no \"ratios\" array"))?;
    let mut failures = Vec::new();
    let mut checked = 0usize;
    for item in base {
        let name = item
            .get("name")
            .and_then(Value::as_str)
            .ok_or("baseline ratio without name")?;
        let want = item
            .get("value")
            .and_then(Value::as_f64)
            .ok_or("baseline ratio without value")?;
        match ratios.iter().find(|(n, _)| n == name) {
            None => failures.push(format!("  {name}: missing from this run")),
            Some((_, got)) => {
                checked += 1;
                if *got < want * threshold {
                    failures.push(format!(
                        "  {name}: {got:.2}x < baseline {want:.2}x * {threshold} \
                         (>{:.0}% regression)",
                        (1.0 - threshold) * 100.0
                    ));
                } else if *got > want * 2.0 {
                    // the gate passed with >2x slack: the committed
                    // floor is stale.  Print the floor a
                    // --write-baseline refresh would record (0.9x
                    // headroom) so the slack is visible in CI logs.
                    println!(
                        "  slack: {name} at {got:.2}x is >2x its baseline \
                         {want:.2}x; suggested floor {:.2}",
                        got * 0.9
                    );
                }
            }
        }
    }
    // run ratios with no committed floor: print the floor a
    // --write-baseline refresh would record (0.9x headroom), so a
    // newly added ratio family (e.g. sparse_vs_fastpath) can be seeded
    // into benches/baseline.json deliberately instead of guessed
    for (name, got) in ratios {
        let in_baseline = base.iter().any(|item| {
            item.get("name").and_then(Value::as_str) == Some(name.as_str())
        });
        if !in_baseline {
            println!(
                "  unbaselined: {name} at {got:.2}x; suggested floor {:.2}",
                got * 0.9
            );
        }
    }
    if failures.is_empty() {
        Ok(checked)
    } else {
        Err(failures.join("\n"))
    }
}
