//! Bench: Figs 16-19 (BMM, general + BNN-specific, both GPUs) — prints
//! the paper-style TOPS tables from the Turing model and measures the
//! wallclock of the functional rust kernels on mid sizes.

use tcbnn::bitops::{BitMatrix, Layout};
use tcbnn::kernels::bmm::{self, BmmProblem, BmmScheme};
use tcbnn::kernels::IoMode;
use tcbnn::sim::{RTX2080, RTX2080TI};
use tcbnn::util::bench::{write_csv, Bencher};
use tcbnn::util::Rng;

fn main() {
    // --- paper series (simulated) ---------------------------------------
    for gpu in [&RTX2080TI, &RTX2080] {
        for mode in [IoMode::General, IoMode::BnnSpecific] {
            let t = tcbnn::figures::fig_bmm(gpu, mode);
            println!("{}", t.render());
            let tag = format!(
                "bench_bmm_{}_{}",
                if mode == IoMode::General { "general" } else { "specific" },
                gpu.name.to_lowercase()
            );
            let _ = t.write_csv("results", &tag);
        }
    }

    // --- functional kernel wallclock (this machine) ----------------------
    let b = Bencher::from_env();
    let mut rng = Rng::new(7);
    let p = BmmProblem { m: 256, n: 512, k: 1024 };
    let a = BitMatrix::random(p.m, p.k, Layout::RowMajor, &mut rng);
    let bm = BitMatrix::random(p.k, p.n, Layout::ColMajor, &mut rng);
    let mut results = Vec::new();
    println!("== functional BMM kernels, {}x{}x{} (CPU wallclock) ==", p.m, p.n, p.k);
    for s in bmm::all_schemes() {
        if !s.supports(p, IoMode::General) {
            continue;
        }
        let r = b.bench(&format!("bmm/{}", s.name()), p.ops(), || {
            std::hint::black_box(s.compute(&a, &bm));
        });
        results.push(r);
    }
    let _ = write_csv("results/bench_bmm_wallclock.csv", &results);
}
