//! Binary sparse tensors and the BitGNN workload.
//!
//! The storage format lives in `bitops::sparse` ([`SparseBitMatrix`],
//! CSR over 64-bit column blocks); this module holds everything built
//! on top of it:
//!
//! * [`AdjSpec`] / [`AdjKind`] — compact, all-integer descriptions of
//!   synthetic graph adjacencies.  A `LayerSpec::BinGcn` carries the
//!   spec (not the matrix): adjacency is regenerated deterministically
//!   from it wherever weights are materialized, so plans and weight
//!   blobs never serialize edge lists.
//! * [`generate`] — the two deterministic generators (power-law
//!   hub graphs and 2-D grid neighborhoods) whose *block* densities
//!   bracket the planner's sparse-vs-dense crossover.
//! * [`gcn_dense_reference`] — the word-level exact reference for one
//!   binary GCN layer (combine, binarize, aggregate), used by
//!   `nn::forward` and by equivalence tests.
//! * [`sparse_pm1_dot`] — the sparse-operand Eq-2 dot the SPMM backend
//!   runs: work proportional to *present* weight blocks only.
//!
//! ## GCN layer semantics (exact integers)
//!
//! Features are +/-1 packed bits; adjacency is a 0/1 *mask* with
//! self-loops.  For one batch item with per-node input rows `x_j`
//! (`d_in` bits), weight rows `w_f` (`d_out` rows of `d_in` bits):
//!
//! 1. combine:  `c[j][f] = pm1_dot(x_j, w_f)`            (Eq 2)
//! 2. binarize: `h[j][f] = sign(c[j][f]) = (c >= 0)`
//! 3. aggregate over neighbours (BitGNN, arXiv 2305.02522):
//!    `out[i][f] = sum_{j in N(i)} h[j][f]
//!               = 2*popc(adj_row_i AND h_col_f) - degree(i)`
//!
//! Step 3 is where sparsity pays: with `h` transposed into packed
//! node-bit lines (`d_out` lines of `nodes` bits), each output is one
//! AND+POPC sweep over only the adjacency row's stored blocks.

use crate::bitops::pack;
use crate::bitops::{BitMatrix, SparseBitMatrix};
use crate::util::Rng;

/// Synthetic adjacency family.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum AdjKind {
    /// Power-law hub graph: every node links to `degree` hub nodes
    /// drawn with quadratic bias from a small hub set confined to the
    /// *first column block*, plus a self-loop.  Column clustering is
    /// the point — stored blocks per row stay at ~2 (the hub block and
    /// the node's own block) no matter how many nodes, so the *block*
    /// density is low and the sparse schemes win.
    PowerLaw,
    /// 2-D grid neighborhood: nodes tile a 16-wide grid and link to
    /// every node within Chebyshev distance `degree` (self included).
    /// Deterministic — the seed is ignored.  Neighbor columns are
    /// near-diagonal and touch most blocks of short rows, so the block
    /// density is high and the dense fastpath wins.
    Grid,
}

impl AdjKind {
    pub fn name(&self) -> &'static str {
        match self {
            AdjKind::PowerLaw => "powerlaw",
            AdjKind::Grid => "grid",
        }
    }
}

/// Deterministic adjacency description carried by `LayerSpec::BinGcn`.
/// All-integer and `Copy` so layer specs stay `Eq + Hash`; the matrix
/// itself is regenerated from this via [`generate`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct AdjSpec {
    pub kind: AdjKind,
    /// PowerLaw: hub links per node.  Grid: Chebyshev radius.
    pub degree: usize,
    /// PowerLaw draw seed (ignored by Grid).
    pub seed: u64,
}

impl AdjSpec {
    /// Stable text form for plan fingerprints: `powerlaw-d6-s1`.
    pub fn tag(&self) -> String {
        format!("{}-d{}-s{}", self.kind.name(), self.degree, self.seed)
    }
}

/// Hub-set size of the power-law generator.  Kept <= 64 so every hub
/// lands in column block 0 (see [`AdjKind::PowerLaw`]).
pub const POWERLAW_HUBS: usize = 48;

/// Generate the `nodes x nodes` adjacency mask for `spec`.  Always
/// includes self-loops (every row is nonempty), always deterministic
/// in (`spec`, `nodes`).
pub fn generate(spec: AdjSpec, nodes: usize) -> SparseBitMatrix {
    assert!(nodes > 0, "empty graph");
    match spec.kind {
        AdjKind::PowerLaw => {
            let hubs = POWERLAW_HUBS.min(nodes);
            let mut rng = Rng::new(spec.seed ^ 0x9c3_17b1);
            let mut edges: Vec<(usize, usize)> =
                Vec::with_capacity(nodes * (spec.degree + 1));
            for i in 0..nodes {
                edges.push((i, i));
                for _ in 0..spec.degree {
                    // quadratic bias toward low-index hubs: h = floor(H*r^2)
                    let r = rng.next_f64();
                    let h = ((hubs as f64) * r * r) as usize;
                    edges.push((i, h.min(hubs - 1)));
                }
            }
            SparseBitMatrix::from_edges(nodes, nodes, edges)
        }
        AdjKind::Grid => {
            let width = (1..=nodes.min(16)).rev().find(|w| nodes % w == 0).unwrap_or(1);
            let height = nodes / width;
            let r = spec.degree as isize;
            let mut edges = Vec::new();
            for i in 0..nodes {
                let (xi, yi) = ((i % width) as isize, (i / width) as isize);
                for dy in -r..=r {
                    for dx in -r..=r {
                        let (x, y) = (xi + dx, yi + dy);
                        if x >= 0 && x < width as isize && y >= 0 && y < height as isize
                        {
                            edges.push((i, (y as usize) * width + x as usize));
                        }
                    }
                }
            }
            SparseBitMatrix::from_edges(nodes, nodes, edges)
        }
    }
}

/// Plan-schema sparsity fingerprint for one GCN layer: the adjacency
/// spec plus the *realized* block count, so a density change (different
/// spec, different generator output) changes the fingerprint even at
/// equal shapes.
pub fn layer_fingerprint(spec: AdjSpec, nodes: usize, nnz_blocks: usize) -> String {
    format!("{}:{}n:{}b", spec.tag(), nodes, nnz_blocks)
}

/// Sparse-operand Eq-2 dot: dense packed input `x64` (`n` logical
/// bits, pad zero) against a sparse +/-1 weight row whose *absent*
/// blocks are all -1 (bit 0).
///
/// With `px = popc(x)` over all blocks (hoistable per input row) and
/// `delta = sum over present blocks of popc(x_b XOR w_b) - popc(x_b)`:
///
/// `dot = n - 2*popc(x XOR w) = n - 2*(px + delta)`
///
/// because an absent block contributes `popc(x_b XOR 0) = popc(x_b)`.
/// Work is proportional to present blocks only; exact at any sparsity.
#[inline]
pub fn sparse_pm1_dot(
    n: usize,
    px_total: u32,
    x64: &[u64],
    block_cols: &[u32],
    block_bits: &[u64],
) -> i32 {
    let mut delta = 0i32;
    for (&b, &wb) in block_cols.iter().zip(block_bits) {
        let xb = x64[b as usize];
        delta += (xb ^ wb).count_ones() as i32 - xb.count_ones() as i32;
    }
    n as i32 - 2 * (px_total as i32 + delta)
}

/// Word-level exact reference for one binary GCN layer over a batch.
///
/// `x` holds one row per batch item of `nodes * d_in` bits (node rows
/// concatenated); `w` is `d_out x d_in`.  Returns the aggregated
/// integers, `batch * nodes * d_out`, item-major then node-major.
/// Requires `d_in % 64 == 0` and `d_out % 64 == 0` (the BinGcn layer
/// contract: node rows stay u64-aligned inside the flat packed row).
pub fn gcn_dense_reference(
    adj: &SparseBitMatrix,
    w: &BitMatrix,
    x: &BitMatrix,
) -> Vec<i32> {
    let nodes = adj.rows;
    assert_eq!(adj.cols, nodes, "adjacency is square");
    let (d_out, d_in) = (w.rows, w.cols);
    assert_eq!(d_in % 64, 0, "BinGcn d_in must be a multiple of 64");
    assert_eq!(d_out % 64, 0, "BinGcn d_out must be a multiple of 64");
    assert_eq!(x.cols, nodes * d_in, "input row width");
    let batch = x.rows;
    let wpl_node = d_in / 32;
    let words_n = nodes.div_ceil(64);
    let adj64 = adj.to_bitmatrix64();
    let mut ht = vec![0u64; d_out * words_n];
    let mut out = vec![0i32; batch * nodes * d_out];
    for item in 0..batch {
        let line = x.line(item);
        ht.fill(0);
        for j in 0..nodes {
            let a = &line[j * wpl_node..(j + 1) * wpl_node];
            for f in 0..d_out {
                if pack::pm1_dot(a, w.line(f), d_in) >= 0 {
                    ht[f * words_n + j / 64] |= 1u64 << (j % 64);
                }
            }
        }
        let dst = &mut out[item * nodes * d_out..(item + 1) * nodes * d_out];
        for i in 0..nodes {
            let arow = adj64.line(i);
            let deg = arow.iter().map(|w| w.count_ones()).sum::<u32>() as i32;
            for f in 0..d_out {
                let h = &ht[f * words_n..(f + 1) * words_n];
                let pc: u32 =
                    arow.iter().zip(h).map(|(a, b)| (a & b).count_ones()).sum();
                dst[i * d_out + f] = 2 * pc as i32 - deg;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::Layout;

    const PL: AdjSpec = AdjSpec { kind: AdjKind::PowerLaw, degree: 6, seed: 1 };
    const GRID: AdjSpec = AdjSpec { kind: AdjKind::Grid, degree: 3, seed: 0 };

    #[test]
    fn powerlaw_is_deterministic_block_sparse_with_self_loops() {
        let a = generate(PL, 512);
        assert_eq!(a, generate(PL, 512), "same spec, same graph");
        for i in 0..512 {
            assert!(a.get(i, i), "self-loop at {i}");
        }
        // hubs confined to block 0 + own block: <= 2 blocks per row
        for r in 0..512 {
            let (bc, _) = a.row_blocks(r);
            assert!(bc.len() <= 2, "row {r} has {} blocks", bc.len());
        }
        assert!(
            a.block_density() < 0.3,
            "power-law block density {} not sparse",
            a.block_density()
        );
        // a different seed moves edges
        let b = generate(AdjSpec { seed: 2, ..PL }, 512);
        assert_ne!(a, b);
    }

    #[test]
    fn grid_is_dense_deterministic_and_symmetric() {
        let a = generate(GRID, 128);
        // seed is ignored: identical graph under any seed
        assert_eq!(a, generate(AdjSpec { seed: 99, ..GRID }, 128));
        for i in 0..128 {
            assert!(a.get(i, i), "self-loop at {i}");
            for j in 0..128 {
                assert_eq!(a.get(i, j), a.get(j, i), "asymmetric at ({i},{j})");
            }
        }
        assert!(
            a.block_density() > 0.6,
            "grid block density {} not dense",
            a.block_density()
        );
    }

    #[test]
    fn generator_densities_bracket_the_crossover() {
        // the planner-facing invariant: the two shipped model graphs
        // sit on opposite sides of a wide density gap
        let pl = generate(PL, 512).block_density();
        let gr = generate(GRID, 128).block_density();
        assert!(pl < 0.3 && gr > 0.6, "powerlaw={pl} grid={gr}");
    }

    #[test]
    fn sparse_dot_matches_dense_eq2_at_every_sparsity() {
        use crate::bitops::pack64;
        let mut rng = Rng::new(811);
        for density_pct in [0usize, 5, 30, 70, 100] {
            let n = 256; // 4 blocks
            let x = BitMatrix::random(1, n, Layout::RowMajor, &mut rng);
            let mut w = BitMatrix::zeros(1, n, Layout::RowMajor);
            for c in 0..n {
                if rng.gen_range(100) < density_pct {
                    w.set(0, c, true);
                }
            }
            let want = pack::pm1_dot(x.line(0), w.line(0), n);
            let sw = SparseBitMatrix::from_bitmatrix(&w);
            let mut x64 = vec![0u64; pack64::words64(x.words_per_line)];
            pack64::repack64_into(x.line(0), &mut x64);
            let px: u32 = x64.iter().map(|v| v.count_ones()).sum();
            let (bc, bb) = sw.row_blocks(0);
            assert_eq!(
                sparse_pm1_dot(n, px, &x64, bc, bb),
                want,
                "density {density_pct}%"
            );
        }
    }

    #[test]
    fn dense_reference_matches_per_bit_naive() {
        let mut rng = Rng::new(812);
        let (nodes, d_in, d_out, batch) = (24, 64, 64, 3);
        let adj = generate(AdjSpec { kind: AdjKind::Grid, degree: 2, seed: 0 }, nodes);
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, &mut rng);
        let x = BitMatrix::random(batch, nodes * d_in, Layout::RowMajor, &mut rng);
        let got = gcn_dense_reference(&adj, &w, &x);
        for item in 0..batch {
            // per-bit combine + binarize
            let mut h = vec![false; nodes * d_out];
            for j in 0..nodes {
                for f in 0..d_out {
                    let mut dot = 0i32;
                    for c in 0..d_in {
                        let xb = x.get(item, j * d_in + c);
                        let wb = w.get(f, c);
                        dot += if xb == wb { 1 } else { -1 };
                    }
                    h[j * d_out + f] = dot >= 0;
                }
            }
            // per-bit aggregate
            for i in 0..nodes {
                for f in 0..d_out {
                    let mut sum = 0i32;
                    for j in 0..nodes {
                        if adj.get(i, j) {
                            sum += if h[j * d_out + f] { 1 } else { -1 };
                        }
                    }
                    assert_eq!(
                        got[(item * nodes + i) * d_out + f],
                        sum,
                        "item {item} node {i} feat {f}"
                    );
                }
            }
        }
    }

    #[test]
    fn fingerprint_tracks_density() {
        let a = generate(PL, 512);
        let fp = layer_fingerprint(PL, 512, a.nnz_blocks());
        assert!(fp.starts_with("powerlaw-d6-s1:512n:"), "{fp}");
        let b = generate(AdjSpec { seed: 2, ..PL }, 512);
        if a.nnz_blocks() != b.nnz_blocks() {
            assert_ne!(fp, layer_fingerprint(PL, 512, b.nnz_blocks()));
        }
    }
}
