//! Fixed-size thread pool over std::sync::mpsc (tokio is unavailable
//! offline; the coordinator's event loop is thread-based, which is also
//! closer to the one-worker-per-GPU process topology of the paper's BENN
//! deployment).

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed closures.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("tcbnn-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run a closure over 0..n in parallel and collect results in order.
    pub fn map<T: Send + 'static, F: Fn(usize) -> T + Send + Sync + 'static>(
        &self,
        n: usize,
        f: F,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let v = f(i);
                let _ = tx.send((i, v));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|o| o.expect("worker completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }
}
