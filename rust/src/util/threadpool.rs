//! Fixed-size thread pool over std::sync::mpsc (tokio is unavailable
//! offline; the coordinator's event loop is thread-based, which is also
//! closer to the one-worker-per-GPU process topology of the paper's BENN
//! deployment), plus the NUMA-aware scoped-parallelism primitives the
//! host kernels dispatch through.
//!
//! NUMA sharding: on a multi-socket host, a worker streaming an operand
//! band that lives on the other socket's memory pays the interconnect
//! on every cache miss.  [`NumaTopology`] probes the node -> cpu map
//! from sysfs (single-node fallback everywhere else), and
//! [`scoped_chunks_numa`] / [`scoped_bands_numa`] split the work
//! proportionally to each node's CPU count, pinning every worker to its
//! node's cpuset (best-effort `sched_setaffinity` — the dependency tree
//! has no libc, so the syscall is issued directly) so the bands a node
//! first-touches are the bands its workers keep streaming.  On a
//! single-node topology both helpers degrade to exactly the
//! [`scoped_chunks`] banding with no pinning at all.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed closures.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("tcbnn-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run a closure over 0..n in parallel and collect results in order.
    pub fn map<T: Send + 'static, F: Fn(usize) -> T + Send + Sync + 'static>(
        &self,
        n: usize,
        f: F,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let v = f(i);
                let _ = tx.send((i, v));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|o| o.expect("worker completed")).collect()
    }
}

/// Run `f(chunk_index, chunk)` over consecutive `chunk`-sized pieces of
/// `data`, spread across up to `threads` scoped worker threads.
///
/// This is the engine executor's row-parallelism primitive: unlike
/// `ThreadPool::map` it borrows (no `'static` bound, no per-job boxing,
/// no channel traffic), so the hot path stays allocation-free — each
/// worker writes its disjoint `&mut` slice of a pre-allocated arena
/// buffer in place.  `data.len()` must be a multiple of `chunk`.
pub fn scoped_chunks<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    assert_eq!(data.len() % chunk, 0, "data must split into whole chunks");
    let n_chunks = data.len() / chunk;
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    // contiguous bands of whole chunks per worker
    let band = n_chunks.div_ceil(threads) * chunk;
    let chunks_per_band = band / chunk;
    std::thread::scope(|s| {
        for (b, band_slice) in data.chunks_mut(band).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, c) in band_slice.chunks_mut(chunk).enumerate() {
                    f(b * chunks_per_band + j, c);
                }
            });
        }
    });
}

/// One NUMA node: its id and the CPUs local to it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    pub id: usize,
    pub cpus: Vec<usize>,
}

/// The host's NUMA node -> CPU map.
///
/// Probed once from sysfs on Linux (`/sys/devices/system/node/node*/
/// cpulist`); everywhere else — and on probe failure — it degrades to a
/// single node holding `available_parallelism` CPUs, under which the
/// NUMA-aware helpers below behave exactly like their flat
/// counterparts.
#[derive(Clone, Debug)]
pub struct NumaTopology {
    nodes: Vec<NumaNode>,
}

impl NumaTopology {
    /// The probed topology of this host, cached for the process
    /// lifetime (topology cannot change under a running process).
    pub fn global() -> &'static NumaTopology {
        static TOPO: OnceLock<NumaTopology> = OnceLock::new();
        TOPO.get_or_init(NumaTopology::probe)
    }

    /// Probe sysfs, falling back to a single synthetic node.
    pub fn probe() -> NumaTopology {
        NumaTopology::probe_sysfs().unwrap_or_else(NumaTopology::single_node)
    }

    /// A synthetic one-node topology covering `available_parallelism`
    /// CPUs — the portable fallback, and the neutral element of the
    /// NUMA helpers (no pinning, flat banding).
    pub fn single_node() -> NumaTopology {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NumaTopology { nodes: vec![NumaNode { id: 0, cpus: (0..n).collect() }] }
    }

    /// Build a topology from explicit per-node CPU lists (tests and
    /// experiments; empty node lists are dropped, an empty input yields
    /// the single-node fallback).
    pub fn from_nodes(cpu_lists: Vec<Vec<usize>>) -> NumaTopology {
        let nodes: Vec<NumaNode> = cpu_lists
            .into_iter()
            .enumerate()
            .filter(|(_, cpus)| !cpus.is_empty())
            .map(|(id, cpus)| NumaNode { id, cpus })
            .collect();
        if nodes.is_empty() {
            NumaTopology::single_node()
        } else {
            NumaTopology { nodes }
        }
    }

    pub fn nodes(&self) -> &[NumaNode] {
        &self.nodes
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn total_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    fn probe_sysfs() -> Option<NumaTopology> {
        if !cfg!(target_os = "linux") {
            return None;
        }
        let mut nodes = Vec::new();
        // Node ids are dense in practice but need not be; scan a sane
        // range rather than parsing the directory listing's names.
        for id in 0..256 {
            let path = format!("/sys/devices/system/node/node{id}/cpulist");
            match std::fs::read_to_string(&path) {
                Ok(list) => {
                    let cpus = parse_cpulist(list.trim())?;
                    if !cpus.is_empty() {
                        nodes.push(NumaNode { id, cpus });
                    }
                }
                Err(_) => {
                    if id > 0 {
                        break; // past the last node
                    }
                    return None; // no node0 => no sysfs NUMA info
                }
            }
        }
        if nodes.is_empty() {
            None
        } else {
            Some(NumaTopology { nodes })
        }
    }
}

/// Parse a sysfs cpulist string like `"0-3,8-11,16"` into CPU indices.
fn parse_cpulist(s: &str) -> Option<Vec<usize>> {
    let mut cpus = Vec::new();
    if s.is_empty() {
        return Some(cpus); // memory-only node
    }
    for part in s.split(',') {
        let part = part.trim();
        match part.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().ok()?;
                let hi: usize = hi.trim().parse().ok()?;
                if hi < lo || hi - lo > 4096 {
                    return None;
                }
                cpus.extend(lo..=hi);
            }
            None => cpus.push(part.parse().ok()?),
        }
    }
    Some(cpus)
}

/// One contiguous span of work units assigned to a NUMA node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct NumaSpan {
    /// First work-unit index of the span.
    start: usize,
    /// Units in the span.
    len: usize,
    /// Index into `topo.nodes()` whose CPUs serve this span.
    node: usize,
    /// Worker threads for this span.
    workers: usize,
}

/// Split `units` work units into per-node contiguous spans proportional
/// to each node's CPU share, with `threads` total workers distributed
/// the same way.  Every span is non-empty and the spans tile
/// `0..units` exactly.
fn plan_numa_spans(units: usize, threads: usize, topo: &NumaTopology) -> Vec<NumaSpan> {
    let total_cpus = topo.total_cpus().max(1);
    let threads = threads.max(1);
    let mut spans = Vec::with_capacity(topo.n_nodes());
    let mut acc_cpus = 0usize;
    let mut start = 0usize;
    for (ni, node) in topo.nodes().iter().enumerate() {
        acc_cpus += node.cpus.len();
        // cumulative proportional cut: rounding never loses units
        let end = units * acc_cpus / total_cpus;
        let len = end - start;
        if len == 0 {
            continue;
        }
        let workers = ((threads * node.cpus.len()).div_ceil(total_cpus)).max(1).min(len);
        spans.push(NumaSpan { start, len, node: ni, workers });
        start = end;
    }
    // Guard against an all-zero-CPU pathology leaving a tail.
    if start < units {
        match spans.last_mut() {
            Some(s) => s.len += units - start,
            None => spans.push(NumaSpan {
                start: 0,
                len: units,
                node: 0,
                workers: threads.min(units).max(1),
            }),
        }
    }
    spans
}

/// Pin the calling thread to `cpus` (best-effort; failures and
/// unsupported platforms are silently ignored — pinning is a locality
/// hint, never a correctness requirement).
#[allow(unused_variables)]
fn pin_current_thread(cpus: &[usize]) {
    #[cfg(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64"),
        not(miri)
    ))]
    {
        // No libc in the dependency tree: issue sched_setaffinity(2)
        // directly.  1024-bit mask matches the kernel's default cpuset
        // width; out-of-range CPUs are skipped.
        let mut mask = [0u64; 16];
        let mut any = false;
        for &c in cpus {
            if c < 1024 {
                mask[c / 64] |= 1u64 << (c % 64);
                any = true;
            }
        }
        if !any {
            return;
        }
        unsafe {
            let pid: usize = 0; // current thread
            let size = std::mem::size_of_val(&mask);
            let ptr = mask.as_ptr();
            let _ret: usize;
            #[cfg(target_arch = "x86_64")]
            std::arch::asm!(
                "syscall",
                inlateout("rax") 203usize => _ret, // __NR_sched_setaffinity
                in("rdi") pid,
                in("rsi") size,
                in("rdx") ptr,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
            #[cfg(target_arch = "aarch64")]
            std::arch::asm!(
                "svc 0",
                in("x8") 122usize, // __NR_sched_setaffinity
                inlateout("x0") pid => _ret,
                in("x1") size,
                in("x2") ptr,
                options(nostack)
            );
        }
    }
}

/// NUMA-aware [`scoped_chunks`]: identical contract and identical
/// chunk-index -> data mapping, but chunks are banded per NUMA node in
/// proportion to CPU counts and each worker is pinned to its node's
/// cpuset before touching its band.  On a single-node topology this is
/// `scoped_chunks` with no pinning.
pub fn scoped_chunks_numa<T, F>(
    data: &mut [T],
    chunk: usize,
    threads: usize,
    topo: &NumaTopology,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    assert_eq!(data.len() % chunk, 0, "data must split into whole chunks");
    let n_chunks = data.len() / chunk;
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads <= 1 || topo.n_nodes() <= 1 {
        scoped_chunks(data, chunk, threads, f);
        return;
    }
    let spans = plan_numa_spans(n_chunks, threads, topo);
    std::thread::scope(|s| {
        let mut rest = data;
        for span in &spans {
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(span.len * chunk);
            rest = tail;
            let band = span.len.div_ceil(span.workers) * chunk;
            for (b, band_slice) in mine.chunks_mut(band).enumerate() {
                let f = &f;
                let cpus = &topo.nodes()[span.node].cpus;
                let first = span.start + b * (band / chunk);
                s.spawn(move || {
                    pin_current_thread(cpus);
                    for (j, c) in band_slice.chunks_mut(chunk).enumerate() {
                        f(first + j, c);
                    }
                });
            }
        }
    });
}

/// NUMA-aware banded dispatch: split `data` (whose length is a multiple
/// of `unit`) into one contiguous multi-unit band per worker, node-
/// proportionally, and call `f(first_unit_index, band)` once per band
/// from a worker pinned to the band's node.
///
/// This is the BMM row-band shape: the callee walks its whole band with
/// its own cache blocking, so handing out single chunks (as
/// `scoped_chunks_numa` does) would defeat the B-panel reuse across
/// rows.
pub fn scoped_bands_numa<T, F>(
    data: &mut [T],
    unit: usize,
    threads: usize,
    topo: &NumaTopology,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(unit > 0, "unit size must be positive");
    assert_eq!(data.len() % unit, 0, "data must split into whole units");
    let n_units = data.len() / unit;
    let threads = threads.max(1).min(n_units.max(1));
    if threads <= 1 {
        f(0, data);
        return;
    }
    let spans = plan_numa_spans(n_units, threads, topo);
    std::thread::scope(|s| {
        let mut rest = data;
        for span in &spans {
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(span.len * unit);
            rest = tail;
            let band_units = span.len.div_ceil(span.workers);
            for (b, band_slice) in mine.chunks_mut(band_units * unit).enumerate() {
                let f = &f;
                let cpus = &topo.nodes()[span.node].cpus;
                let pin = topo.n_nodes() > 1;
                let first = span.start + b * band_units;
                s.spawn(move || {
                    if pin {
                        pin_current_thread(cpus);
                    }
                    f(first, band_slice);
                });
            }
        }
    });
}

/// Default worker count for scoped parallel sections: the machine's
/// available parallelism, capped to keep thread-spawn overhead sane.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_chunks_covers_all_chunks() {
        let mut data = vec![0u32; 12 * 5];
        scoped_chunks(&mut data, 5, 3, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        for i in 0..12 {
            assert!(data[i * 5..(i + 1) * 5].iter().all(|&v| v == i as u32 + 1));
        }
    }

    #[test]
    fn scoped_chunks_single_thread_and_oversubscribed() {
        let mut a = vec![0usize; 8];
        scoped_chunks(&mut a, 1, 1, |i, c| c[0] = i * i);
        let mut b = vec![0usize; 8];
        scoped_chunks(&mut b, 1, 64, |i, c| c[0] = i * i);
        assert_eq!(a, b);
        assert_eq!(a[7], 49);
    }

    #[test]
    fn scoped_chunks_reads_shared_state() {
        let src: Vec<u32> = (0..64).collect();
        let mut dst = vec![0u32; 64];
        scoped_chunks(&mut dst, 8, 4, |i, c| {
            for (j, v) in c.iter_mut().enumerate() {
                *v = src[i * 8 + j] * 2;
            }
        });
        assert_eq!(dst[63], 126);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }

    #[test]
    fn parse_cpulist_handles_ranges_and_singletons() {
        assert_eq!(parse_cpulist("0-3,8-11,16"), Some(vec![0, 1, 2, 3, 8, 9, 10, 11, 16]));
        assert_eq!(parse_cpulist("5"), Some(vec![5]));
        assert_eq!(parse_cpulist(""), Some(vec![]));
        assert_eq!(parse_cpulist("3-1"), None);
        assert_eq!(parse_cpulist("a-b"), None);
    }

    #[test]
    fn probe_always_yields_a_usable_topology() {
        let topo = NumaTopology::probe();
        assert!(topo.n_nodes() >= 1);
        assert!(topo.total_cpus() >= 1);
        // global() is the same probe, cached
        assert!(NumaTopology::global().n_nodes() >= 1);
    }

    #[test]
    fn from_nodes_drops_empty_lists_and_falls_back() {
        let t = NumaTopology::from_nodes(vec![vec![0, 1], vec![], vec![2]]);
        assert_eq!(t.n_nodes(), 2);
        assert_eq!(t.total_cpus(), 3);
        assert!(NumaTopology::from_nodes(vec![]).n_nodes() >= 1);
    }

    #[test]
    fn numa_spans_tile_the_unit_range_proportionally() {
        let topo = NumaTopology::from_nodes(vec![vec![0, 1, 2], vec![3]]);
        let spans = plan_numa_spans(16, 4, &topo);
        // spans tile 0..16 exactly, in order
        let mut next = 0;
        for s in &spans {
            assert_eq!(s.start, next);
            assert!(s.len > 0);
            assert!(s.workers >= 1 && s.workers <= s.len);
            next += s.len;
        }
        assert_eq!(next, 16);
        // 3:1 CPU split -> 12:4 unit split
        assert_eq!(spans[0].len, 12);
        assert_eq!(spans[1].len, 4);
    }

    #[test]
    fn numa_spans_survive_fewer_units_than_nodes() {
        let topo = NumaTopology::from_nodes(vec![vec![0], vec![1], vec![2], vec![3]]);
        let spans = plan_numa_spans(2, 4, &topo);
        let total: usize = spans.iter().map(|s| s.len).sum();
        assert_eq!(total, 2);
        for s in &spans {
            assert!(s.workers >= 1);
        }
    }

    /// Satellite contract: on a single-node topology, scoped_chunks_numa
    /// is byte-identical to scoped_chunks (same index -> chunk mapping,
    /// same coverage).
    #[test]
    fn scoped_chunks_numa_matches_scoped_chunks_on_single_node() {
        fn fill(i: usize, c: &mut [u64]) {
            for (j, v) in c.iter_mut().enumerate() {
                *v = (i * 1000 + j) as u64;
            }
        }
        let mut flat = vec![0u64; 24 * 7];
        scoped_chunks(&mut flat, 7, 3, fill);
        let single = NumaTopology::single_node();
        let mut numa = vec![0u64; 24 * 7];
        scoped_chunks_numa(&mut numa, 7, 3, &single, fill);
        assert_eq!(flat, numa);
    }

    #[test]
    fn scoped_chunks_numa_matches_on_synthetic_multi_node() {
        // Pinning to fake CPUs is best-effort and may silently fail on
        // the runner; the index -> chunk mapping must hold regardless.
        let topo = NumaTopology::from_nodes(vec![vec![0, 1], vec![2, 3]]);
        let mut flat = vec![0u32; 30 * 4];
        scoped_chunks(&mut flat, 4, 4, |i, c| c.fill(i as u32 + 1));
        let mut numa = vec![0u32; 30 * 4];
        scoped_chunks_numa(&mut numa, 4, 4, &topo, |i, c| c.fill(i as u32 + 1));
        assert_eq!(flat, numa);
    }

    #[test]
    fn scoped_bands_numa_covers_every_unit_once() {
        for topo in [
            NumaTopology::single_node(),
            NumaTopology::from_nodes(vec![vec![0, 1, 2], vec![3, 4]]),
        ] {
            let mut data = vec![0u32; 20 * 3];
            scoped_bands_numa(&mut data, 3, 4, &topo, |first, band| {
                assert_eq!(band.len() % 3, 0);
                for (u, unit) in band.chunks_mut(3).enumerate() {
                    unit.fill((first + u) as u32 + 1);
                }
            });
            for u in 0..20 {
                assert!(
                    data[u * 3..(u + 1) * 3].iter().all(|&v| v == u as u32 + 1),
                    "unit {u} miswritten under {topo:?}"
                );
            }
        }
    }

    #[test]
    fn scoped_bands_numa_serial_path_hands_out_one_band() {
        let mut data = vec![0u8; 12];
        let single = NumaTopology::single_node();
        scoped_bands_numa(&mut data, 4, 1, &single, |first, band| {
            assert_eq!(first, 0);
            assert_eq!(band.len(), 12);
            band.fill(9);
        });
        assert!(data.iter().all(|&v| v == 9));
    }

    #[test]
    fn pin_current_thread_is_best_effort_and_harmless() {
        // Real CPUs, an out-of-range CPU, and an empty set must all be
        // absorbed without panicking or poisoning the thread.
        pin_current_thread(&[0]);
        pin_current_thread(&[100_000]);
        pin_current_thread(&[]);
        // restore a permissive mask so later tests are not confined
        let all: Vec<usize> = (0..NumaTopology::global().total_cpus().max(1)).collect();
        pin_current_thread(&all);
    }
}
