//! Fixed-size thread pool over std::sync::mpsc (tokio is unavailable
//! offline; the coordinator's event loop is thread-based, which is also
//! closer to the one-worker-per-GPU process topology of the paper's BENN
//! deployment).

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed closures.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("tcbnn-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run a closure over 0..n in parallel and collect results in order.
    pub fn map<T: Send + 'static, F: Fn(usize) -> T + Send + Sync + 'static>(
        &self,
        n: usize,
        f: F,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let v = f(i);
                let _ = tx.send((i, v));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|o| o.expect("worker completed")).collect()
    }
}

/// Run `f(chunk_index, chunk)` over consecutive `chunk`-sized pieces of
/// `data`, spread across up to `threads` scoped worker threads.
///
/// This is the engine executor's row-parallelism primitive: unlike
/// `ThreadPool::map` it borrows (no `'static` bound, no per-job boxing,
/// no channel traffic), so the hot path stays allocation-free — each
/// worker writes its disjoint `&mut` slice of a pre-allocated arena
/// buffer in place.  `data.len()` must be a multiple of `chunk`.
pub fn scoped_chunks<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    assert_eq!(data.len() % chunk, 0, "data must split into whole chunks");
    let n_chunks = data.len() / chunk;
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    // contiguous bands of whole chunks per worker
    let band = n_chunks.div_ceil(threads) * chunk;
    let chunks_per_band = band / chunk;
    std::thread::scope(|s| {
        for (b, band_slice) in data.chunks_mut(band).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, c) in band_slice.chunks_mut(chunk).enumerate() {
                    f(b * chunks_per_band + j, c);
                }
            });
        }
    });
}

/// Default worker count for scoped parallel sections: the machine's
/// available parallelism, capped to keep thread-spawn overhead sane.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_chunks_covers_all_chunks() {
        let mut data = vec![0u32; 12 * 5];
        scoped_chunks(&mut data, 5, 3, |i, c| {
            for v in c.iter_mut() {
                *v = i as u32 + 1;
            }
        });
        for i in 0..12 {
            assert!(data[i * 5..(i + 1) * 5].iter().all(|&v| v == i as u32 + 1));
        }
    }

    #[test]
    fn scoped_chunks_single_thread_and_oversubscribed() {
        let mut a = vec![0usize; 8];
        scoped_chunks(&mut a, 1, 1, |i, c| c[0] = i * i);
        let mut b = vec![0usize; 8];
        scoped_chunks(&mut b, 1, 64, |i, c| c[0] = i * i);
        assert_eq!(a, b);
        assert_eq!(a[7], 49);
    }

    #[test]
    fn scoped_chunks_reads_shared_state() {
        let src: Vec<u32> = (0..64).collect();
        let mut dst = vec![0u32; 64];
        scoped_chunks(&mut dst, 8, 4, |i, c| {
            for (j, v) in c.iter_mut().enumerate() {
                *v = src[i * 8 + j] * 2;
            }
        });
        assert_eq!(dst[63], 126);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }
}
