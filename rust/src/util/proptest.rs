//! Tiny property-testing helper (proptest is unavailable offline).
//!
//! `run_cases(seed, n, |rng| ...)` executes n randomized cases with a
//! per-case seeded RNG; on failure the panic message includes the case
//! seed so the exact input can be replayed in isolation.

use super::rng::Rng;

/// Run `n` property cases.  `body` receives a fresh deterministic RNG per
/// case; panic inside the body fails the test with the replay seed.
pub fn run_cases<F: Fn(&mut Rng)>(seed: u64, n: usize, body: F) {
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64 + 1);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property case {case}/{n} failed (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Pick a random element of a slice.
pub fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.gen_range(xs.len())]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        run_cases(1, 50, |rng| {
            let x = rng.gen_range(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_replay_seed() {
        // silence the expected panic's backtrace noise
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            run_cases(2, 10, |rng| {
                assert!(rng.gen_range(4) != 1, "hit the bad value");
            });
        });
        std::panic::set_hook(prev);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn pick_in_range() {
        let mut rng = Rng::new(3);
        let xs = [10, 20, 30];
        for _ in 0..100 {
            assert!(xs.contains(pick(&mut rng, &xs)));
        }
    }
}
