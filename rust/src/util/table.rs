//! ASCII table + CSV emitters for paper-style result tables.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn row_str(&mut self, cells: &[&str]) {
        self.rows.push(cells.iter().map(|s| s.to_string()).collect());
    }

    fn widths(&self) -> Vec<usize> {
        let ncol = self.header.len().max(
            self.rows.iter().map(|r| r.len()).max().unwrap_or(0),
        );
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV to `dir/name.csv`, creating `dir` if needed.
    pub fn write_csv(&self, dir: &str, name: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{}/{}.csv", dir, name);
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row_str(&["a", "1"]);
        t.row_str(&["longer-name", "22"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer-name"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a,b", "c"]);
        t.row_str(&["x\"y", "z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"x\"\"y\""));
    }
}
