//! In-tree replacements for crates unavailable in this offline environment
//! (clap, criterion, rand, proptest, serde — see the Cargo.toml note).
//!
//! Everything here is deliberately small and dependency-free: a xorshift
//! PRNG, a CLI argument parser, a criterion-style bench harness, summary
//! statistics, ASCII/CSV table printers, and a thread pool.

pub mod bench;
pub mod cli;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;

pub use rng::Rng;
