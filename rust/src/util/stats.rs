//! Summary statistics over latency/throughput samples.

/// Summary of a sample set (durations in seconds or any unit).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut s: Vec<f64> = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }

    /// Assemble a `Summary` from already-computed statistics, for
    /// sources that never hold the raw samples (`obs::hist`'s bounded
    /// histogram, deserialized snapshots).
    #[allow(clippy::too_many_arguments)]
    pub fn from_quantiles(
        n: usize,
        mean: f64,
        stddev: f64,
        min: f64,
        max: f64,
        p50: f64,
        p90: f64,
        p95: f64,
        p99: f64,
    ) -> Summary {
        Summary { n, mean, stddev, min, max, p50, p90, p95, p99 }
    }
}

/// Percentile of an ascending-sorted slice (nearest-rank with interpolation).
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pretty-print a duration in seconds with an adaptive unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Pretty-print an ops/sec rate.
pub fn fmt_rate(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e12 {
        format!("{:.2} T/s", ops_per_sec / 1e12)
    } else if ops_per_sec >= 1e9 {
        format!("{:.2} G/s", ops_per_sec / 1e9)
    } else if ops_per_sec >= 1e6 {
        format!("{:.2} M/s", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.2} K/s", ops_per_sec / 1e3)
    } else {
        format!("{:.2} /s", ops_per_sec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile_sorted(&s, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn from_quantiles_round_trips_fields() {
        let a = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let b = Summary::from_quantiles(
            a.n, a.mean, a.stddev, a.min, a.max, a.p50, a.p90, a.p95, a.p99,
        );
        assert_eq!(a, b);
    }

    #[test]
    fn empty_is_default() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" us"));
        assert!(fmt_rate(2e12).ends_with(" T/s"));
    }
}
