//! Deterministic xorshift64* PRNG — the project's only randomness source.
//!
//! Used by tests (property generators), benches (workload synthesis) and
//! examples.  Deterministic seeding keeps every experiment reproducible.

/// xorshift64* generator (Vigna 2016).  Not cryptographic; plenty for
/// workload generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn next_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Vector of uniform +/-1 floats.
    pub fn pm1_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| if self.next_bool() { 1.0 } else { -1.0 }).collect()
    }

    /// Vector of standard-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() as f32).collect()
    }

    /// Vector of random u32 words (packed-bit payloads).
    pub fn u32_vec(&mut self, n: usize) -> Vec<u32> {
        (0..n).map(|_| self.next_u32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let n = 1 + r.gen_range(1000);
            assert!(r.gen_range(n) < n);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..100_000).map(|_| r.next_f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..100_000).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
