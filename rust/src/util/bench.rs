//! Criterion-style micro-bench harness (criterion itself is unavailable
//! offline — see Cargo.toml).  Auto-calibrates iteration counts, warms up,
//! reports mean/p50/stddev, and can emit CSV rows for EXPERIMENTS.md.

use std::time::Instant;

use super::stats::{fmt_duration, Summary};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub summary: Summary,
    /// optional user-supplied work units per iteration (ops, images, ...)
    pub work_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.summary.mean > 0.0 {
            self.work_per_iter / self.summary.mean
        } else {
            0.0
        }
    }
}

/// Bench harness configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// target wall time spent measuring each case
    pub measure_secs: f64,
    /// target wall time for warmup
    pub warmup_secs: f64,
    /// max samples collected
    pub max_samples: usize,
    pub quiet: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { measure_secs: 1.0, warmup_secs: 0.3, max_samples: 200, quiet: false }
    }
}

impl Bencher {
    /// Fast settings for CI / `cargo test`.
    pub fn quick() -> Self {
        Bencher { measure_secs: 0.15, warmup_secs: 0.05, max_samples: 50, quiet: true }
    }

    /// Honour TCBNN_BENCH_SECS if set (used by `cargo bench` wrappers).
    pub fn from_env() -> Self {
        let mut b = Bencher::default();
        if let Ok(v) = std::env::var("TCBNN_BENCH_SECS") {
            if let Ok(secs) = v.parse::<f64>() {
                b.measure_secs = secs;
                b.warmup_secs = (secs * 0.25).min(1.0);
            }
        }
        b
    }

    /// Measure `f`, auto-scaling iterations; `work_per_iter` feeds the
    /// throughput column (use 1.0 when meaningless).
    pub fn bench<F: FnMut()>(&self, name: &str, work_per_iter: f64, mut f: F) -> BenchResult {
        // Estimate a single-shot duration.
        let t0 = Instant::now();
        f();
        let single = t0.elapsed().as_secs_f64().max(1e-9);

        // Warmup.
        let wi = ((self.warmup_secs / single).ceil() as u64).clamp(1, 1_000_000);
        let tw = Instant::now();
        for _ in 0..wi {
            f();
            if tw.elapsed().as_secs_f64() > self.warmup_secs * 2.0 {
                break;
            }
        }

        // Decide batch size per sample so each sample is >= ~50us.
        let per_sample = (50e-6 / single).ceil().max(1.0) as u64;
        let n_samples = ((self.measure_secs / (single * per_sample as f64)).ceil()
            as usize)
            .clamp(5, self.max_samples);

        let mut samples = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / per_sample as f64);
        }
        let summary = Summary::from(&samples);
        let res = BenchResult {
            name: name.to_string(),
            iters: per_sample * n_samples as u64,
            summary,
            work_per_iter,
        };
        if !self.quiet {
            println!(
                "{:<44} mean {:>12}  p50 {:>12}  sd {:>10}  ({} iters)",
                res.name,
                fmt_duration(res.summary.mean),
                fmt_duration(res.summary.p50),
                fmt_duration(res.summary.stddev),
                res.iters
            );
        }
        res
    }
}

/// Write bench results as CSV (name,mean_s,p50_s,stddev_s,throughput).
pub fn write_csv(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "name,mean_s,p50_s,stddev_s,throughput")?;
    for r in results {
        writeln!(
            f,
            "{},{:.9},{:.9},{:.9},{:.3}",
            r.name, r.summary.mean, r.summary.p50, r.summary.stddev, r.throughput()
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::quick();
        let mut x = 0u64;
        let r = b.bench("spin", 1000.0, || {
            for i in 0..1000u64 {
                x = x.wrapping_add(i * i);
            }
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.throughput() > 0.0);
        assert!(x > 0);
    }

    #[test]
    fn csv_roundtrip() {
        let b = Bencher::quick();
        let r = b.bench("noop", 1.0, || {
            std::hint::black_box(1 + 1);
        });
        let path = std::env::temp_dir().join("tcbnn_bench_test.csv");
        write_csv(path.to_str().unwrap(), &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,"));
        assert!(text.contains("noop"));
    }
}
