//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// First positional = subcommand, remaining positionals shift down.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn flags_and_values() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--gpu=2080ti"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get_usize("port", 0), 8080);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("gpu"), Some("2080ti"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("r", 1.5), 1.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["x", "--fast"]);
        assert!(a.flag("fast"));
    }
}
