//! tuner — measured calibration of the planner's host cost models.
//!
//! Runs every registered *host* backend's `bmm`/`bconv` kernels over a
//! fixed grid of layer shapes, measures layout-conversion bandwidth
//! for every registered repack pair (`layout::repack::all_pairs()`),
//! least-squares-fits the backend's cost-model coefficients plus the
//! per-pair repack rates, and emits a schema-versioned
//! `CalibrationProfile` JSON artifact keyed by this host's
//! fingerprint.  The emitted profile is validated by re-loading it, it
//! must contain repack coefficients for EVERY registered layout pair
//! (so a backend adding a layout fails the run until the pair is
//! measurable), and planner choices under `CostSource::Calibrated` are
//! checked against the analytic baseline on every unambiguous (>3x
//! margin) layer of the Table-5 models — a mismatch there means the
//! fit is broken, not that the host is interesting, so the run fails.
//!
//!   cargo run --release --bin tuner -- \
//!       [--quick]                 # CI settings (short measurements)
//!       [--out tuner-profile.json]
//!       [--cache-dir <dir>]       # also persist next to a PlanCache
//!       [--seed 42]               # input-generation seed
//!       [--margin 3.0]            # consistency-check margin
//!       [--skip-consistency]
//!
//! CI runs `tuner --quick` in the `tuner-smoke` job and uploads the
//! profile artifact.  See docs/ENGINE.md ("Calibration & CostSource").

use std::process::ExitCode;
use std::sync::Arc;

use tcbnn::engine::PlanCache;
use tcbnn::kernels::backend::BackendRegistry;
use tcbnn::nn::model::all_models;
use tcbnn::sim::RTX2080TI;
use tcbnn::tuner::{
    consistency_vs_analytic, fit_profile, microbench, CalibrationProfile, CostSource,
    HostFingerprint, MicrobenchConfig,
};
use tcbnn::util::cli::Args;
use tcbnn::util::stats::fmt_rate;
use tcbnn::util::threadpool::default_threads;

fn main() -> ExitCode {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let out = args.get_or("out", "tuner-profile.json");
    let cfg = MicrobenchConfig {
        quick,
        seed: args.get_usize("seed", 42) as u64,
        threads: default_threads(),
    };
    let registry = BackendRegistry::global();
    // fingerprint the parallelism the measurements actually run with
    let fingerprint = HostFingerprint::detect_with_cores(registry, cfg.threads);
    println!(
        "tuner: host fingerprint cores={} cache_line={} schemes={:?}",
        fingerprint.cores, fingerprint.cache_line, fingerprint.schemes
    );
    let host_backends: Vec<&str> = registry
        .backends()
        .filter(|b| microbench::is_host_backend(*b))
        .map(|b| b.name())
        .collect();
    println!(
        "calibratable host backends: {host_backends:?} (GPU schemes keep their \
         simulated cost faces)"
    );

    // ---- measure + fit --------------------------------------------------
    let measurements = microbench::run(registry, &cfg);
    if measurements.is_empty() {
        eprintln!("tuner: no host backend produced measurements");
        return ExitCode::FAILURE;
    }
    println!("measured {} grid cells ({} mode)", measurements.len(), mode(quick));
    for m in &measurements {
        println!(
            "  {:<10} {:<6} batch {:<3} {:<28} p50 {:>10.1} us",
            m.scheme.name(),
            m.kind,
            m.batch,
            m.layer.tag(),
            m.secs * 1e6
        );
    }
    // layout-conversion bandwidth per registered repack pair
    let repack_measurements = microbench::run_repacks(&cfg);
    println!(
        "measured {} repack grid cells over {} layout pairs",
        repack_measurements.len(),
        tcbnn::layout::repack::all_pairs().len()
    );
    let profile = fit_profile(fingerprint, &measurements, &repack_measurements);
    if profile.schemes.is_empty() {
        eprintln!("tuner: fit produced no scheme coefficients");
        return ExitCode::FAILURE;
    }
    println!("\nfitted coefficients (vs analytic constants):");
    let analytic = tcbnn::tuner::SchemeCoeffs::analytic();
    for (name, c) in &profile.schemes {
        println!(
            "  {name}: word {} (analytic {}), bytes {}, dispatch {:.2} us, \
             rel RMSE {:.1}% over {} cells",
            fmt_rate(recip(c.secs_per_word_op)),
            fmt_rate(recip(analytic.secs_per_word_op)),
            fmt_rate(recip(c.secs_per_byte)),
            c.dispatch_secs * 1e6,
            c.rel_rmse * 100.0,
            c.samples
        );
        if c.gcn_samples > 0 {
            println!(
                "    sparse aggregation: {:.2} ns/stored block over {} GCN cells",
                c.secs_per_sparse_block * 1e9,
                c.gcn_samples
            );
        }
    }
    println!("\nfitted repack bandwidth per layout pair:");
    for (pair, c) in &profile.repacks {
        println!(
            "  {pair:<28} {}/s, dispatch {:.2} us, rel RMSE {:.1}% over {} cells",
            fmt_rate(recip(c.secs_per_byte)),
            c.dispatch_secs * 1e6,
            c.rel_rmse * 100.0,
            c.samples
        );
    }
    // coverage gate (CI tuner-smoke): the profile must price EVERY
    // registered layout pair — when a backend adds a LayoutKind, the
    // pair set widens and this fails until the microbench covers it
    let missing: Vec<String> = tcbnn::layout::repack::all_pairs()
        .into_iter()
        .filter(|(s, d)| profile.repack_coeffs(*s, *d).is_none())
        .map(|(s, d)| tcbnn::layout::repack::pair_name(s, d))
        .collect();
    if !missing.is_empty() {
        eprintln!(
            "tuner: emitted profile is missing repack coefficients for \
             registered layout pairs: {missing:?}"
        );
        return ExitCode::FAILURE;
    }

    // ---- persist + validate the artifact --------------------------------
    if let Err(e) = profile.save(out) {
        eprintln!("tuner: cannot write profile {out}: {e}");
        return ExitCode::FAILURE;
    }
    let reloaded = match CalibrationProfile::load(out) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("tuner: emitted profile does not re-load: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    if reloaded != profile || reloaded.id() != profile.id() {
        eprintln!("tuner: emitted profile does not round-trip bit-exactly");
        return ExitCode::FAILURE;
    }
    println!("\nwrote {out} (profile id {})", profile.id());
    if let Some(dir) = args.get("cache-dir") {
        match PlanCache::open(dir) {
            Ok(cache) => {
                let path = cache.profile_path();
                if let Err(e) = profile.save(&path) {
                    eprintln!("tuner: cannot persist profile in {path:?}: {e}");
                    return ExitCode::FAILURE;
                }
                println!(
                    "persisted next to the plan cache: {path:?} (cached plans \
                     under other profiles are now stale)"
                );
            }
            Err(e) => {
                eprintln!("tuner: cannot open plan cache {dir}: {e}");
                return ExitCode::FAILURE;
            }
        }
    }

    // ---- consistency: Calibrated vs Analytic on unambiguous layers ------
    if args.flag("skip-consistency") {
        println!("consistency check skipped (--skip-consistency)");
        return ExitCode::SUCCESS;
    }
    let margin = args.get_f64("margin", 3.0);
    let source = CostSource::Calibrated(Arc::new(profile));
    let models = all_models();
    let report =
        consistency_vs_analytic(registry, &RTX2080TI, &source, &models, 8, margin);
    println!(
        "consistency: {} layers, {} unambiguous (> {margin}x analytic margin), \
         {} mismatches",
        report.layers,
        report.unambiguous,
        report.mismatches.len()
    );
    if !report.ok() {
        for m in &report.mismatches {
            eprintln!("  MISMATCH {m}");
        }
        eprintln!(
            "tuner: calibrated planner disagrees with the analytic baseline on \
             unambiguous layers — the fit is not trustworthy"
        );
        return ExitCode::FAILURE;
    }
    println!("tuner: OK");
    ExitCode::SUCCESS
}

fn mode(quick: bool) -> &'static str {
    if quick {
        "quick"
    } else {
        "full"
    }
}

/// 1/x with 0 mapping to 0 (a clamped coefficient prints as a 0 rate,
/// not inf).
fn recip(x: f64) -> f64 {
    if x > 0.0 {
        1.0 / x
    } else {
        0.0
    }
}
