//! The inference engine: planning + execution for high-throughput
//! packed-bit serving.
//!
//! This subsystem turns the repo's functional pieces (bit formats,
//! the `kernels::backend::KernelBackend` providers, the calibrated
//! Turing cost model, the coordinator) into a servable engine:
//!
//! * `planner` — for a `ModelDef` and batch bucket, runs a dynamic
//!   program over per-layer (scheme, layout) pairs: every backend in a
//!   `BackendRegistry` contributes its `layer_secs` cost face — the
//!   six Tables-6/7 rows plus the host `FASTPATH` backend, or any
//!   custom registration — plus its layout face
//!   (`preferred_input_layout`), and edges whose activation layouts
//!   disagree are charged a modeled repack cost
//!   (`tuner::CostSource::repack_secs` over `crate::layout`).  This is
//!   the paper's central lesson operationalized: scheme AND
//!   data-format choice is a per-layer-shape decision, not a global
//!   one.  `Planner::plan_fixed` pins one scheme everywhere (how a
//!   GPU-less host serves `kernels::fastpath` — with its FC layers
//!   chained in `Blocked64`); `Planner::with_layout_search(false)`
//!   keeps the historical scheme-only search as the DP's regression
//!   baseline.
//! * `plan` / `plan_cache` — plans serialize to JSON (schema-versioned,
//!   embedding the searched scheme set and the cost-profile id they
//!   were ranked under) and persist in a directory cache keyed by
//!   (model, batch shape, gpu), with hit/miss counters surfaced
//!   through the served model's `Metrics`.  Entries from an older
//!   schema, a different backend set, or a different calibration
//!   profile are stale → re-planned.  The planner's costs come from a
//!   `tuner::CostSource` (analytic, calibrated per-host profile, or
//!   live executor feedback — see the `tuner` module).
//! * `arena` / `executor` — the execution side: each plan layer holds
//!   an opaque prepared-weight handle from its backend
//!   (`Box<dyn PreparedFc>` / `Box<dyn PreparedConv>` owning u64
//!   lines, im2row filter images, ...), every buffer — including
//!   backend-reported u64 scratch — is allocated once up front, and
//!   the packed-bit forward pass then runs with zero heap allocation
//!   per request, parallelized across output rows via
//!   `util::threadpool::scoped_chunks`.  Results are bit-identical to
//!   the `nn::forward` reference for every backend.
//! * `weights` — weight persistence through the runtime's flat blob
//!   format (`*.bin` + `*.meta`).
//! * `batch_model` — [`EngineModel`] implements the coordinator's
//!   `BatchModel`; built through [`EngineModel::builder`] with a
//!   [`PlanPolicy`] (`Search` | `Fixed(scheme)` | `Cached`), so
//!   `coordinator::server`/`router` can serve any Table-5 model end to
//!   end, with engine images/sec exposed through
//!   `coordinator::metrics`.
//!
//! See `docs/ENGINE.md` for the backend -> planner -> plan cache ->
//! arena executor flow (and the "Adding a backend" walkthrough) and
//! `examples/serve_bnn.rs` for an end-to-end serving demo.

pub mod arena;
pub mod batch_model;
pub mod executor;
pub mod json;
pub mod plan;
pub mod plan_cache;
pub mod planner;
pub mod weights;

pub use arena::Arena;
pub use batch_model::{EngineModel, EngineModelBuilder, PlanPolicy};
pub use executor::EngineExecutor;
pub use plan::{LayerPlan, ModelPlan, PlanRepack, PLAN_SCHEMA};
pub use plan_cache::PlanCache;
pub use planner::Planner;
pub use weights::{weights_from_blob, weights_to_blob};
