//! The inference engine: planning + execution for high-throughput
//! packed-bit serving.
//!
//! This subsystem turns the repo's functional pieces (bit formats,
//! scheme implementations, the calibrated Turing cost model, the
//! coordinator) into a servable engine:
//!
//! * `planner` — for a `ModelDef` and batch bucket, simulates every
//!   scheme per layer with `nn::cost::layer_secs` (the exact machinery
//!   behind `model_cost`) — the six Tables-6/7 rows plus the host
//!   `FASTPATH` backend — and picks the cheapest, emitting an
//!   executable [`plan::ModelPlan`].  This is the paper's central lesson
//!   operationalized: scheme and data-format choice is a per-layer-shape
//!   decision, not a global one.  `Planner::plan_fixed` pins one scheme
//!   everywhere (how a GPU-less host serves `kernels::fastpath`).
//! * `plan` / `plan_cache` — plans serialize to JSON and persist in a
//!   directory cache keyed by (model, batch shape, gpu), with hit/miss
//!   counters for observability.
//! * `arena` / `executor` — the execution side: every buffer is
//!   allocated once up front from the model shape, and the packed-bit
//!   forward pass then runs with zero heap allocation per request,
//!   parallelized across output rows via
//!   `util::threadpool::scoped_chunks`.  Results are bit-identical to
//!   the naive `nn::forward` path.
//! * `weights` — weight persistence through the runtime's flat blob
//!   format (`*.bin` + `*.meta`).
//! * `batch_model` — [`EngineModel`] implements the coordinator's
//!   `BatchModel`, so `coordinator::server`/`router` can serve any
//!   Table-5 model end to end (not just the PJRT MLP), with engine
//!   images/sec exposed through `coordinator::metrics`.
//!
//! See `docs/ENGINE.md` for the planner -> plan cache -> arena executor
//! flow and `examples/serve_bnn.rs` for an end-to-end serving demo.

pub mod arena;
pub mod batch_model;
pub mod executor;
pub mod json;
pub mod plan;
pub mod plan_cache;
pub mod planner;
pub mod weights;

pub use arena::Arena;
pub use batch_model::EngineModel;
pub use executor::EngineExecutor;
pub use plan::{LayerPlan, ModelPlan};
pub use plan_cache::PlanCache;
pub use planner::Planner;
pub use weights::{weights_from_blob, weights_to_blob};
