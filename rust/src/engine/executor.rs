//! The arena executor: a packed-bit forward pass with zero heap
//! allocation on the request path.
//!
//! Construction takes a `ModelDef`, its weights, and a `ModelPlan`
//! (validated against the definition), then asks the
//! [`BackendRegistry`] for each plan layer's backend to *prepare* the
//! weights: every binarized layer holds an opaque prepared-layer
//! handle (`Box<dyn PreparedFc>` / `Box<dyn PreparedConv>`) that owns
//! its scheme-specific packed weight image — u64 lines and im2row
//! filter images for the fastpath, plain packed clones for the scalar
//! schemes.  The arena (including each backend's reported u64 scratch)
//! is sized once from the plan's batch capacity; `forward` then runs
//! every layer in place over the arena's ping-pong buffers,
//! parallelized across output rows with
//! `util::threadpool::scoped_chunks`.  There is no `match` on `Scheme`
//! anywhere in this module — backend dispatch is entirely
//! registry-driven.
//!
//! Semantics are bit-identical to `nn::forward::forward` (the
//! reference path): the same tap ordering for the first layer's f32
//! accumulation, the same Eq-2 integer math for binarized layers, the
//! same threshold comparisons.  The plan's per-layer scheme selection
//! affects the *cost/serving* decisions (and on a Turing GPU would
//! select the kernel); the CPU functional semantics of every scheme
//! are identical, which is exactly what the kernels-equivalence tests
//! guarantee.
//!
//! Since the layout co-design subsystem (`crate::layout`) the plan
//! also carries explicit layout edges: flat FC activations may ride
//! `Blocked64` u64 words (the fastpath's native operand form) through
//! the arena's `flat64` buffer — packed directly by `pack_fc_ints64`
//! on chained edges, or materialized by an explicit repack op
//! (`layout::repack::rows32_to_rows64` / `rows64_to_rows32`) through
//! pre-sized scratch when an edge's layouts disagree.  Every explicit
//! repack is counted per scheme ([`EngineExecutor::repack_stats`]) and
//! surfaced through coordinator `Metrics`.  Layout never changes a
//! bit: the u64 packing is exactly the `bitops::pack64` pairing of the
//! u32 words, asserted end to end in `rust/tests/layout_equivalence.rs`.
//!
//! Every layer is wall-timed on every pass — one timing source with
//! two consumers: the optional `tuner::LiveCosts` sink (per-scheme
//! EWMA driving re-planning) and the always-on per-layer attribution
//! ([`EngineExecutor::layer_attribution`]: cumulative calls, measured
//! seconds, predicted seconds per plan layer) plus per-edge repack
//! attribution ([`EngineExecutor::repack_edges`]) that `obs::export`
//! snapshots report.  [`EngineExecutor::last_pass_spans`] renders the
//! most recent pass as `obs::trace` spans for the serving trace ring.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::bitops::pack;
use crate::kernels::backend::{
    BackendRegistry, ExecCtx, PreparedConv, PreparedFc, PreparedGcn,
};
use crate::kernels::bconv::BconvProblem;
use crate::layout::{repack, LayoutKind};
use crate::nn::forward::{LayerWeights, ModelWeights};
use crate::nn::layer::LayerSpec;
use crate::nn::ModelDef;
use crate::tuner::LiveCosts;
use crate::util::threadpool::scoped_chunks;

use super::arena::Arena;
use super::plan::ModelPlan;

/// Cumulative explicit repack traffic on one plan edge, keyed by the
/// consuming layer's index and the `src -> dst` layout pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RepackEdgeStat {
    /// index of the consuming plan layer
    pub layer: usize,
    pub src: LayoutKind,
    pub dst: LayoutKind,
    pub ops: u64,
    pub bytes: u64,
    /// wall seconds spent inside the repack converters on this edge
    pub secs: f64,
}

/// Execution-ready per-layer state: structural weights for the
/// scheme-independent layers, opaque backend handles for the binarized
/// ones.
enum PreparedLayer {
    FirstConv {
        /// +/-1 filter transposed to one contiguous row per output
        /// channel: `w[oi][(r*k + s)*c + ci]`
        w_t: Vec<f32>,
        thresh: Vec<f32>,
    },
    BinConv {
        conv: Box<dyn PreparedConv>,
        thresh: Vec<f32>,
    },
    BinFc {
        fc: Box<dyn PreparedFc>,
        thresh: Vec<f32>,
    },
    BinGcn {
        /// backend-staged adjacency + combine weights — the adjacency
        /// is staged exactly once per executor, off the request path
        gcn: Box<dyn PreparedGcn>,
        thresh: Vec<f32>,
    },
    FinalFc {
        fc: Box<dyn PreparedFc>,
        gamma: Vec<f32>,
        beta: Vec<f32>,
    },
    Pool,
}

/// Activation representation between layers.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Repr {
    /// caller-provided fp32 input, not yet binarized
    Fp,
    /// HWNC packed bits in the current arena buffer
    Bits { hw: usize, c: usize },
    /// row-packed bits (batch x feat) in the current arena buffer
    Flat { feat: usize },
    /// row-packed bits in `Blocked64` u64 words, living in the arena's
    /// `flat64` buffer (a planned layout edge — see `crate::layout`)
    Flat64 { feat: usize },
}

/// The arena executor.
pub struct EngineExecutor {
    model: ModelDef,
    plan: ModelPlan,
    prepared: Vec<PreparedLayer>,
    arena: Arena,
    batch_cap: usize,
    threads: usize,
    /// optional tuner feedback: per-backend-layer measured latencies
    /// recorded against per-layer baseline predictions (see
    /// `tuner::LiveCosts`)
    latency_sink: Option<Arc<LiveCosts>>,
    /// per-layer baseline seconds at batch capacity the sink records
    /// ratios against; `None` = the plan's own secs.  Callers planning
    /// under `CostSource::Live` MUST override with the ratio-free prior
    /// (`CostSource::prior_layer_secs`), or the EWMA feeds on itself.
    latency_baselines: Option<Vec<f64>>,
    /// cumulative explicit repack ops materialized on layout edges,
    /// keyed by (consuming layer, src layout, dst layout)
    repack_edges: Vec<RepackEdgeStat>,
    /// per-layer cumulative attribution: (calls, measured secs,
    /// predicted secs scaled to each executed batch)
    layer_stats: Vec<(u64, f64, f64)>,
    /// per-layer wall seconds of the most recent pass
    last_layer_secs: Vec<f64>,
    /// per-layer output activation bytes per row (f32 logits for the
    /// classifier head, packed bits otherwise) — sized at build time
    layer_row_bytes: Vec<u64>,
    /// rows of the most recent pass
    last_batch: usize,
    /// explicit repacks of the most recent pass:
    /// (layer, src, dst, bytes, secs)
    last_repacks: Vec<(usize, LayoutKind, LayoutKind, u64, f64)>,
}

impl EngineExecutor {
    /// Build an executor for `plan.batch` rows at a time, dispatching
    /// through the global builtin registry.
    pub fn new(model: ModelDef, weights: &ModelWeights, plan: ModelPlan) -> Result<Self> {
        EngineExecutor::with_registry(model, weights, plan, BackendRegistry::global())
    }

    /// Build against an explicit registry (custom/test backends).  The
    /// registry is only consulted at build time — the prepared handles
    /// own everything the request path needs.
    pub fn with_registry(
        model: ModelDef,
        weights: &ModelWeights,
        plan: ModelPlan,
        registry: &BackendRegistry,
    ) -> Result<Self> {
        ensure!(
            plan.layers.len() == model.layers.len(),
            "plan has {} layers, model {} has {}",
            plan.layers.len(),
            model.name,
            model.layers.len()
        );
        for (lp, l) in plan.layers.iter().zip(&model.layers) {
            ensure!(
                lp.tag == l.tag(),
                "plan layer {:?} does not match model layer {:?}",
                lp.tag,
                l.tag()
            );
        }
        ensure!(
            weights.layers.len() == model.layers.len(),
            "weights have {} layers, model has {}",
            weights.layers.len(),
            model.layers.len()
        );
        if let Some(LayerSpec::FinalFc { d_out, .. }) = model.layers.last() {
            ensure!(*d_out == model.classes, "classifier head width mismatch");
        } else {
            bail!("model must end with a FinalFc classifier head");
        }
        let batch_cap = plan.batch;
        validate_layouts(&model, &plan)?;
        let (prepared, scratch_words) =
            prepare_weights(&model, weights, &plan, registry, batch_cap)?;
        let arena = Arena::for_model(&model, batch_cap)
            .with_scratch_words(scratch_words)
            .with_flat64_words(plan_flat64_words(&model, &plan, batch_cap));
        let n_layers = model.layers.len();
        // per-layer output payload per row, for trace span bytes: the
        // classifier emits f32 logits, everything else packed bits
        let mut layer_row_bytes = Vec::with_capacity(n_layers);
        let mut dims = model.input;
        for (li, l) in model.layers.iter().enumerate() {
            dims = dims.after(l);
            layer_row_bytes.push(if li + 1 == n_layers {
                (dims.flat() * std::mem::size_of::<f32>()) as u64
            } else {
                dims.flat().div_ceil(8) as u64
            });
        }
        Ok(EngineExecutor {
            model,
            plan,
            prepared,
            arena,
            batch_cap,
            threads: crate::util::threadpool::default_threads(),
            latency_sink: None,
            latency_baselines: None,
            repack_edges: Vec::new(),
            layer_stats: vec![(0, 0.0, 0.0); n_layers],
            last_layer_secs: vec![0.0; n_layers],
            layer_row_bytes,
            last_batch: 0,
            last_repacks: Vec::new(),
        })
    }

    /// Override the scoped-worker count (1 = fully serial).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Record each backend layer's measured wall seconds (against a
    /// per-layer baseline prediction, scaled to the executing batch)
    /// into a lock-free [`LiveCosts`] sink — the executor side of the
    /// tuner's live feedback loop.  Scheme-independent layers (first
    /// conv, pooling) are not recorded; they never drive a scheme
    /// choice.
    ///
    /// The default baseline is the plan's own per-layer secs — correct
    /// when the plan was ranked by an `Analytic`/`Calibrated` source.
    /// A plan ranked under `CostSource::Live` embeds ratio-*scaled*
    /// secs; recording against those would feed the EWMA its own
    /// output (fixed point `sqrt(true drift)`), so such callers must
    /// also set [`EngineExecutor::with_latency_baselines`] to the
    /// ratio-free prior predictions.
    pub fn with_latency_sink(mut self, sink: Arc<LiveCosts>) -> Self {
        self.latency_sink = Some(sink);
        self
    }

    /// Override the per-layer baseline seconds (at batch capacity) the
    /// latency sink records ratios against — one entry per model layer,
    /// typically `CostSource::prior_layer_secs` of each planned layer.
    ///
    /// Panics if the length does not match the model's layer count.
    pub fn with_latency_baselines(mut self, baselines: Vec<f64>) -> Self {
        assert_eq!(
            baselines.len(),
            self.model.layers.len(),
            "one baseline per model layer"
        );
        self.latency_baselines = Some(baselines);
        self
    }

    pub fn plan(&self) -> &ModelPlan {
        &self.plan
    }

    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    /// Arena bytes (constant after construction — the zero-allocation
    /// invariant benches assert on).
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Cumulative explicit repack ops this executor has materialized on
    /// planned layout edges: `(consuming layer's scheme name, ops,
    /// streamed bytes)`.  Zero-cost chained edges (layouts already
    /// agreeing) are not counted — nothing moved.  Aggregated by scheme
    /// from the per-edge stats ([`EngineExecutor::repack_edges`]).
    pub fn repack_stats(&self) -> Vec<(&'static str, u64, u64)> {
        let mut out: Vec<(&'static str, u64, u64)> = Vec::new();
        for e in &self.repack_edges {
            let name = self.plan.layers[e.layer].scheme.name();
            match out.iter_mut().find(|(n, _, _)| *n == name) {
                Some((_, ops, bytes)) => {
                    *ops += e.ops;
                    *bytes += e.bytes;
                }
                None => out.push((name, e.ops, e.bytes)),
            }
        }
        out
    }

    /// Cumulative explicit repack traffic per plan edge — the per-edge
    /// attribution `obs::export` snapshots carry.
    pub fn repack_edges(&self) -> &[RepackEdgeStat] {
        &self.repack_edges
    }

    /// Cumulative per-layer attribution: how often each plan layer ran,
    /// measured wall seconds, and the plan's predicted seconds scaled
    /// to each executed batch — the per-layer drift feed.
    pub fn layer_attribution(&self) -> Vec<crate::obs::LayerAttr> {
        self.plan
            .layers
            .iter()
            .zip(&self.layer_stats)
            .map(|(lp, &(calls, secs, predicted_s))| crate::obs::LayerAttr {
                index: lp.index,
                tag: lp.tag.clone(),
                scheme: lp.scheme.name().to_string(),
                calls,
                secs,
                predicted_s,
            })
            .collect()
    }

    /// The most recent pass rendered as `obs::trace` spans: one `Layer`
    /// span per plan layer ("L<i>/<tag>/<scheme>", measured seconds,
    /// output activation bytes), with a `Repack` span interleaved
    /// before each consuming layer that materialized an explicit
    /// layout conversion.  Empty layer timings (never ran) render as
    /// zero-second spans.
    pub fn last_pass_spans(&self) -> Vec<crate::obs::Span> {
        let mut spans =
            Vec::with_capacity(self.plan.layers.len() + self.last_repacks.len());
        for (li, lp) in self.plan.layers.iter().enumerate() {
            for &(rl, rsrc, rdst, bytes, secs) in &self.last_repacks {
                if rl == li {
                    spans.push(crate::obs::Span::repack(
                        format!("L{li}/{rsrc}->{rdst}"),
                        secs,
                        bytes,
                    ));
                }
            }
            spans.push(crate::obs::Span::layer(
                format!("L{li}/{}/{}", lp.tag, lp.scheme.name()),
                self.last_layer_secs[li],
                self.layer_row_bytes[li] * self.last_batch as u64,
            ));
        }
        spans
    }

    /// Run `batch` rows of fp32 input (NHWC for conv models, flat rows
    /// otherwise); returns the logits slice (batch x classes).
    pub fn forward(&mut self, input: &[f32], batch: usize) -> &[f32] {
        assert!(batch > 0 && batch <= self.batch_cap, "batch {batch} over capacity");
        assert_eq!(
            input.len(),
            batch * self.model.input.flat(),
            "input payload size"
        );
        let mut repr = Repr::Fp;
        let mut cur_in_a = true;
        let threads = self.threads;
        let n_layers = self.model.layers.len();
        self.last_batch = batch;
        // explicit repack ops materialized this pass (merged into the
        // cumulative per-edge counters after the layer loop, when the
        // arena borrows have ended): (layer, src, dst, bytes, secs)
        let mut repack_log: Vec<(usize, LayoutKind, LayoutKind, u64, f64)> =
            Vec::new();
        for li in 0..n_layers {
            let layer = self.model.layers[li].clone();
            // every layer is wall-timed: the per-layer attribution is
            // always on, the live-feedback sink (below) consumes the
            // same measurement for backend-dispatched layers
            let t0 = Instant::now();
            let plan_scheme = self.plan.layers[li].scheme;
            let baseline_secs = self
                .latency_baselines
                .as_ref()
                .map_or(self.plan.layers[li].secs, |b| b[li]);
            let pw = &self.prepared[li];
            let Arena { bits_a, bits_b, ints, words64, flat64, logits } =
                &mut self.arena;
            let (src, dst): (&mut Vec<u32>, &mut Vec<u32>) = if cur_in_a {
                (bits_a, bits_b)
            } else {
                (bits_b, bits_a)
            };
            match (&layer, pw) {
                (
                    LayerSpec::FirstConv { c, o, k, stride, pad },
                    PreparedLayer::FirstConv { w_t, thresh },
                ) => {
                    assert_eq!(repr, Repr::Fp, "FirstConv must be the first layer");
                    let h = self.model.input.hw;
                    let ohw = (h + 2 * pad - k) / stride + 1;
                    let wio = o.div_ceil(32);
                    let chunk = ohw * batch * wio;
                    let t = par_threads(threads, ohw * chunk);
                    first_conv_rows(
                        input,
                        &mut dst[..ohw * chunk],
                        chunk,
                        t,
                        FirstConvParams {
                            h,
                            c: *c,
                            o: *o,
                            k: *k,
                            stride: *stride,
                            pad: *pad,
                            batch,
                            ohw,
                            wio,
                        },
                        w_t,
                        thresh,
                    );
                    repr = Repr::Bits { hw: ohw, c: *o };
                    cur_in_a = !cur_in_a;
                }
                (
                    LayerSpec::BinConv { o, k, stride, pad, pool, .. },
                    PreparedLayer::BinConv { conv, thresh },
                ) => {
                    let Repr::Bits { hw, c } = repr else {
                        panic!("BinConv needs packed HWNC input");
                    };
                    let wi = c.div_ceil(32);
                    let wio = o.div_ceil(32);
                    let ohw = (hw + 2 * pad - k) / stride + 1;
                    let p = BconvProblem {
                        hw,
                        n: batch,
                        c,
                        o: *o,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                    };
                    let int_chunk = ohw * batch * o;
                    let t1 = par_threads(threads, ohw * int_chunk);
                    {
                        // backend-opaque Eq-2 accumulator pass into the
                        // shared i32 staging (exact integer math, so the
                        // packed bits below are identical for every
                        // registered backend)
                        let scratch = conv.scratch_words(p);
                        let mut ctx =
                            ExecCtx { words64: &mut words64[..scratch], threads: t1 };
                        conv.bconv(
                            &src[..hw * hw * batch * wi],
                            p,
                            &mut ints[..ohw * int_chunk],
                            &mut ctx,
                        );
                    }
                    let pp = PackConvParams { ohw, batch, o: *o, wio };
                    let bit_chunk = ohw * batch * wio;
                    pack_conv_ints(
                        &ints[..ohw * int_chunk],
                        &mut dst[..ohw * bit_chunk],
                        bit_chunk,
                        t1,
                        pp,
                        thresh,
                    );
                    if *pool {
                        let poh = ohw / 2;
                        let pool_chunk = poh * batch * wio;
                        or_pool_rows(
                            &dst[..ohw * bit_chunk],
                            &mut src[..poh * pool_chunk],
                            pool_chunk,
                            par_threads(threads, poh * pool_chunk),
                            ohw,
                            batch,
                            wio,
                        );
                        repr = Repr::Bits { hw: poh, c: *o };
                        // pooled result landed back in the src buffer
                    } else {
                        repr = Repr::Bits { hw: ohw, c: *o };
                        cur_in_a = !cur_in_a;
                    }
                }
                (LayerSpec::Pool, PreparedLayer::Pool) => {
                    let Repr::Bits { hw, c } = repr else {
                        panic!("Pool needs packed HWNC input");
                    };
                    let wi = c.div_ceil(32);
                    let poh = hw / 2;
                    let chunk = poh * batch * wi;
                    or_pool_rows(
                        &src[..hw * hw * batch * wi],
                        &mut dst[..poh * chunk],
                        chunk,
                        par_threads(threads, poh * chunk),
                        hw,
                        batch,
                        wi,
                    );
                    repr = Repr::Bits { hw: poh, c };
                    cur_in_a = !cur_in_a;
                }
                (
                    LayerSpec::BinFc { d_in, d_out },
                    PreparedLayer::BinFc { fc, thresh },
                ) => {
                    let in_l = self.plan.layers[li].in_layout;
                    let out_l = self.plan.layers[li].out_layout;
                    let wpl_in = d_in.div_ceil(32);
                    let wpl_out = d_out.div_ceil(32);
                    let w64_out = d_out.div_ceil(64);
                    let t = par_threads(threads, batch * d_out * wpl_in / 8);
                    // 1. materialize the input in the planned layout and
                    //    run the backend dot pass into the i32 staging
                    let scratch = fc.scratch_words(batch);
                    if let Some((rs, rd, bytes, secs)) = fc_input_and_dot(
                        fc.as_ref(),
                        in_l,
                        repr,
                        input,
                        batch,
                        *d_in,
                        src,
                        dst,
                        flat64,
                        &mut words64[..scratch],
                        &mut ints[..batch * d_out],
                        t,
                        threads,
                    ) {
                        repack_log.push((li, rs, rd, bytes, secs));
                    }
                    // 2. threshold-pack into the planned output layout —
                    //    the same comparison rule either way, so the bits
                    //    are identical across layouts
                    if out_l == LayoutKind::Blocked64 {
                        pack_fc_ints64(
                            &ints[..batch * d_out],
                            &mut flat64[..batch * w64_out],
                            w64_out,
                            t,
                            *d_out,
                            thresh,
                        );
                        repr = Repr::Flat64 { feat: *d_out };
                    } else {
                        pack_fc_ints(
                            &ints[..batch * d_out],
                            &mut src[..batch * wpl_out],
                            wpl_out,
                            t,
                            *d_out,
                            thresh,
                        );
                        repr = Repr::Flat { feat: *d_out };
                        // two hops: result is back in the original buffer
                    }
                }
                (
                    LayerSpec::BinGcn { nodes, d_in, d_out, .. },
                    PreparedLayer::BinGcn { gcn, thresh },
                ) => {
                    // GCN activations are flat Row32 node-feature rows
                    // (validate_layouts rejects anything else), so the
                    // input ladder is a plain flatten/copy into `dst`
                    let din_total = nodes * d_in;
                    let dout_total = nodes * d_out;
                    let wpl_out = dout_total.div_ceil(32);
                    let t = par_threads(threads, batch * dout_total / 8);
                    let feat =
                        flatten_into(input, repr, batch, src, dst, din_total, threads);
                    assert_eq!(feat, din_total, "gcn input width");
                    {
                        let scratch = gcn.scratch_words(batch);
                        let mut ctx =
                            ExecCtx { words64: &mut words64[..scratch], threads: t };
                        gcn.gcn(
                            &dst[..batch * din_total.div_ceil(32)],
                            batch,
                            &mut ints[..batch * dout_total],
                            &mut ctx,
                        );
                    }
                    pack_gcn_ints(
                        &ints[..batch * dout_total],
                        &mut src[..batch * wpl_out],
                        wpl_out,
                        t,
                        *d_out,
                        dout_total,
                        thresh,
                    );
                    repr = Repr::Flat { feat: dout_total };
                    // two hops: result is back in the original buffer
                }
                (
                    LayerSpec::FinalFc { d_in, d_out },
                    PreparedLayer::FinalFc { fc, gamma, beta },
                ) => {
                    let in_l = self.plan.layers[li].in_layout;
                    let wpl_in = d_in.div_ceil(32);
                    let t = par_threads(threads, batch * d_out * wpl_in / 8);
                    let scratch = fc.scratch_words(batch);
                    if let Some((rs, rd, bytes, secs)) = fc_input_and_dot(
                        fc.as_ref(),
                        in_l,
                        repr,
                        input,
                        batch,
                        *d_in,
                        src,
                        dst,
                        flat64,
                        &mut words64[..scratch],
                        &mut ints[..batch * d_out],
                        t,
                        threads,
                    ) {
                        repack_log.push((li, rs, rd, bytes, secs));
                    }
                    let seg = &ints[..batch * d_out];
                    scoped_chunks(&mut logits[..batch * d_out], *d_out, t, |ni, row| {
                        for (j, out) in row.iter_mut().enumerate() {
                            *out = seg[ni * d_out + j] as f32 * gamma[j] + beta[j];
                        }
                    });
                    repr = Repr::Flat { feat: *d_out };
                }
                _ => panic!("layer/weight kind mismatch at layer {li}"),
            }
            let dt = t0.elapsed().as_secs_f64();
            // live-feedback recording covers only backend-dispatched
            // layers (scheme-independent ones never drive a choice)
            if let Some(sink) = self.latency_sink.as_deref() {
                if matches!(
                    layer,
                    LayerSpec::BinConv { .. }
                        | LayerSpec::BinFc { .. }
                        | LayerSpec::BinGcn { .. }
                        | LayerSpec::FinalFc { .. }
                ) {
                    // baselines are at batch capacity; scale linearly to
                    // the executing batch (exact for the word-ops term,
                    // within EWMA tolerance for the fixed dispatch term)
                    let predicted =
                        baseline_secs * batch as f64 / self.batch_cap as f64;
                    sink.record(plan_scheme, predicted, dt);
                }
            }
            // per-layer attribution is always on; predicted seconds use
            // the plan's own secs (never the live-overridden baselines,
            // so drift reads measured-vs-plan)
            let plan_predicted =
                self.plan.layers[li].secs * batch as f64 / self.batch_cap as f64;
            let ls = &mut self.layer_stats[li];
            ls.0 += 1;
            ls.1 += dt;
            ls.2 += plan_predicted;
            self.last_layer_secs[li] = dt;
        }
        self.last_repacks.clear();
        for (li, rsrc, rdst, bytes, secs) in repack_log {
            self.last_repacks.push((li, rsrc, rdst, bytes, secs));
            match self
                .repack_edges
                .iter_mut()
                .find(|e| e.layer == li && e.src == rsrc && e.dst == rdst)
            {
                Some(e) => {
                    e.ops += 1;
                    e.bytes += bytes;
                    e.secs += secs;
                }
                None => self.repack_edges.push(RepackEdgeStat {
                    layer: li,
                    src: rsrc,
                    dst: rdst,
                    ops: 1,
                    bytes,
                    secs,
                }),
            }
        }
        let classes = self.model.classes;
        &self.arena.logits[..batch * classes]
    }
}

/// Serial cutoff shared by all parallel sections.
fn par_threads(threads: usize, work_words: usize) -> usize {
    if work_words < 4096 {
        1
    } else {
        threads
    }
}

/// The shared FC/classifier input ladder: materialize the planned
/// input layout (zero-cost chained edge, explicit repack through the
/// pre-sized `flat64` buffer, or a plain flatten) and run the
/// backend's dot pass into `ints`.  Returns `(src layout, dst layout,
/// streamed bytes, converter wall seconds)` when an explicit repack
/// op was materialized (the caller attributes it to the consuming
/// layer's edge).
#[allow(clippy::too_many_arguments)]
fn fc_input_and_dot(
    fc: &dyn PreparedFc,
    in_l: LayoutKind,
    repr: Repr,
    input: &[f32],
    batch: usize,
    d_in: usize,
    src: &[u32],
    dst: &mut [u32],
    flat64: &mut [u64],
    scratch: &mut [u64],
    ints: &mut [i32],
    t: usize,
    threads: usize,
) -> Option<(LayoutKind, LayoutKind, u64, f64)> {
    let wpl_in = d_in.div_ceil(32);
    let w64_in = d_in.div_ceil(64);
    let edge_bytes = (batch * (wpl_in * 4 + w64_in * 8)) as u64;
    let mut repacked = None;
    if in_l == LayoutKind::Blocked64 {
        match repr {
            Repr::Flat64 { feat } => {
                // chained edge: the previous layer already packed
                // Blocked64 — nothing moves
                assert_eq!(feat, d_in, "fc input width");
            }
            Repr::Flat { feat } => {
                // explicit planned repack op straight from the packed
                // rows the previous layer left in `src` — no staging
                // copy through `dst`
                assert_eq!(feat, d_in, "fc input width");
                let t_rp = Instant::now();
                repack::rows32_to_rows64(
                    &src[..batch * wpl_in],
                    wpl_in,
                    &mut flat64[..batch * w64_in],
                );
                repacked = Some((
                    LayoutKind::Row32,
                    LayoutKind::Blocked64,
                    edge_bytes,
                    t_rp.elapsed().as_secs_f64(),
                ));
            }
            _ => {
                let feat = flatten_into(input, repr, batch, src, dst, d_in, threads);
                assert_eq!(feat, d_in, "fc input width");
                // explicit planned repack op, through the flat64 buffer
                let t_rp = Instant::now();
                repack::rows32_to_rows64(
                    &dst[..batch * wpl_in],
                    wpl_in,
                    &mut flat64[..batch * w64_in],
                );
                repacked = Some((
                    LayoutKind::Row32,
                    LayoutKind::Blocked64,
                    edge_bytes,
                    t_rp.elapsed().as_secs_f64(),
                ));
            }
        }
        let mut ctx = ExecCtx { words64: scratch, threads: t };
        fc.bmm64(&flat64[..batch * w64_in], batch, ints, &mut ctx);
    } else {
        if let Repr::Flat64 { feat } = repr {
            // explicit back-conversion for a Row32-native consumer of
            // a Blocked64 activation
            assert_eq!(feat, d_in, "fc input width");
            let t_rp = Instant::now();
            repack::rows64_to_rows32(
                &flat64[..batch * w64_in],
                wpl_in,
                &mut dst[..batch * wpl_in],
            );
            repacked = Some((
                LayoutKind::Blocked64,
                LayoutKind::Row32,
                edge_bytes,
                t_rp.elapsed().as_secs_f64(),
            ));
        } else {
            let feat = flatten_into(input, repr, batch, src, dst, d_in, threads);
            assert_eq!(feat, d_in, "fc input width");
        }
        let mut ctx = ExecCtx { words64: scratch, threads: t };
        fc.bmm(&dst[..batch * wpl_in], batch, ints, &mut ctx);
    }
    repacked
}

/// Validate the plan's layout edges against what this executor can
/// materialize: HWNC (conv/pool) activations are `Row32`-only, flat FC
/// activations may ride `Row32` or `Blocked64`, and the classifier
/// emits logits (`Row32` nominal).  Anything else is a plan from a
/// foreign executor — rejected at build time, not mid-request.
fn validate_layouts(model: &ModelDef, plan: &ModelPlan) -> Result<()> {
    let mut prev_out = LayoutKind::Row32;
    for (li, (l, lp)) in model.layers.iter().zip(&plan.layers).enumerate() {
        if matches!(l, LayerSpec::BinGcn { .. }) {
            // GCN activations are flat but Row32-only: the aggregation
            // kernels consume/emit row-packed node-feature lines, and
            // the executor materializes no Blocked64 edge around them
            ensure!(
                prev_out == LayoutKind::Row32,
                "layer {li} ({}): GCN layer cannot consume a {} activation",
                lp.tag,
                prev_out
            );
            ensure!(
                lp.in_layout == LayoutKind::Row32 && lp.out_layout == LayoutKind::Row32,
                "layer {li} ({}): GCN layers are Row32-only, plan says {} -> {}",
                lp.tag,
                lp.in_layout,
                lp.out_layout
            );
            prev_out = lp.out_layout;
            continue;
        }
        let flat = matches!(l, LayerSpec::BinFc { .. } | LayerSpec::FinalFc { .. });
        if !flat {
            // HWNC layers can neither consume nor emit a non-Row32
            // activation — and nothing upstream may hand them one (the
            // executor has no flat64 -> HWNC conversion to materialize)
            ensure!(
                prev_out == LayoutKind::Row32,
                "layer {li} ({}): HWNC layer cannot consume a {} activation",
                lp.tag,
                prev_out
            );
            ensure!(
                lp.in_layout == LayoutKind::Row32 && lp.out_layout == LayoutKind::Row32,
                "layer {li} ({}): HWNC layers are Row32-only, plan says {} -> {}",
                lp.tag,
                lp.in_layout,
                lp.out_layout
            );
            prev_out = lp.out_layout;
            continue;
        }
        ensure!(
            matches!(lp.in_layout, LayoutKind::Row32 | LayoutKind::Blocked64),
            "layer {li} ({}): unsupported planned input layout {}",
            lp.tag,
            lp.in_layout
        );
        let out_ok = match l {
            LayerSpec::BinFc { .. } => {
                matches!(lp.out_layout, LayoutKind::Row32 | LayoutKind::Blocked64)
            }
            _ => lp.out_layout == LayoutKind::Row32,
        };
        ensure!(
            out_ok,
            "layer {li} ({}): unsupported planned output layout {}",
            lp.tag,
            lp.out_layout
        );
        prev_out = lp.out_layout;
    }
    Ok(())
}

/// u64 words of `Blocked64` flat-activation buffer the plan's layout
/// edges need at batch capacity (0 for all-`Row32` plans).
fn plan_flat64_words(model: &ModelDef, plan: &ModelPlan, batch_cap: usize) -> usize {
    let mut words = 0usize;
    let mut prev_out = LayoutKind::Row32;
    for (l, lp) in model.layers.iter().zip(&plan.layers) {
        if let LayerSpec::BinFc { d_in, d_out } | LayerSpec::FinalFc { d_in, d_out } = l
        {
            if lp.in_layout == LayoutKind::Blocked64 || prev_out == LayoutKind::Blocked64
            {
                words = words.max(batch_cap * d_in.div_ceil(64));
            }
            if lp.out_layout == LayoutKind::Blocked64 {
                words = words.max(batch_cap * d_out.div_ceil(64));
            }
        }
        prev_out = lp.out_layout;
    }
    words
}

/// Convert `nn::forward::ModelWeights` into execution state: validate
/// shapes, transpose the first-conv filter, and ask each plan layer's
/// registered backend to prepare its scheme-specific weight image —
/// once, off the request path.  Returns the prepared layers plus the
/// largest u64 scratch any of them needs at batch capacity (which
/// sizes the arena's `words64` buffer).
fn prepare_weights(
    model: &ModelDef,
    weights: &ModelWeights,
    plan: &ModelPlan,
    registry: &BackendRegistry,
    batch_cap: usize,
) -> Result<(Vec<PreparedLayer>, usize)> {
    let mut out = Vec::with_capacity(model.layers.len());
    let mut scratch_words = 0usize;
    let mut dims = model.input;
    for (li, (l, w)) in model.layers.iter().zip(&weights.layers).enumerate() {
        let backend = |scheme: crate::nn::Scheme| {
            registry.get(scheme).ok_or_else(|| {
                anyhow!(
                    "layer {li}: plan scheme {} has no registered backend",
                    scheme.name()
                )
            })
        };
        out.push(match (l, w) {
            (
                LayerSpec::FirstConv { c, o, k, .. },
                LayerWeights::FirstConv { w_pm1, thresh },
            ) => {
                ensure!(
                    w_pm1.len() == k * k * c * o,
                    "layer {li}: first-conv filter size"
                );
                ensure!(thresh.len() == *o, "layer {li}: threshold table size");
                // [((r*k+s)*c + ci)*o + oi] -> [oi][(r*k+s)*c + ci]
                let taps = k * k * c;
                let mut w_t = vec![0f32; o * taps];
                for t in 0..taps {
                    for oi in 0..*o {
                        w_t[oi * taps + t] = w_pm1[t * o + oi];
                    }
                }
                PreparedLayer::FirstConv { w_t, thresh: thresh.clone() }
            }
            (
                LayerSpec::BinConv { c, o, k, stride, pad, .. },
                LayerWeights::BinConv { filter, thresh },
            ) => {
                ensure!(
                    filter.dims == [*k, *k, *o, *c],
                    "layer {li}: filter dims {:?}",
                    filter.dims
                );
                ensure!(thresh.len() == *o, "layer {li}: threshold table size");
                ensure!(dims.feat == *c, "layer {li}: input channel walk mismatch");
                // the problem at batch capacity: scratch needs are
                // monotone in batch, so this covers every request
                let p = BconvProblem {
                    hw: dims.hw,
                    n: batch_cap,
                    c: *c,
                    o: *o,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                };
                let conv = backend(plan.layers[li].scheme)?
                    .prepare_conv(filter, p)
                    .map_err(|e| anyhow!("layer {li}: {e}"))?;
                scratch_words = scratch_words.max(conv.scratch_words(p));
                PreparedLayer::BinConv { conv, thresh: thresh.clone() }
            }
            (LayerSpec::BinFc { d_in, d_out }, LayerWeights::BinFc { w, thresh }) => {
                ensure!(
                    w.rows == *d_out && w.cols == *d_in,
                    "layer {li}: fc weight shape {}x{}",
                    w.rows,
                    w.cols
                );
                ensure!(thresh.len() == *d_out, "layer {li}: threshold table size");
                let fc = backend(plan.layers[li].scheme)?
                    .prepare_fc(w)
                    .map_err(|e| anyhow!("layer {li}: {e}"))?;
                ensure!(
                    fc.supports_input_layout(plan.layers[li].in_layout),
                    "layer {li}: backend {} cannot execute planned input layout {}",
                    plan.layers[li].scheme.name(),
                    plan.layers[li].in_layout
                );
                scratch_words = scratch_words.max(fc.scratch_words(batch_cap));
                PreparedLayer::BinFc { fc, thresh: thresh.clone() }
            }
            (
                LayerSpec::BinGcn { nodes, d_in, d_out, .. },
                LayerWeights::BinGcn { adj, w, thresh },
            ) => {
                ensure!(
                    w.rows == *d_out && w.cols == *d_in,
                    "layer {li}: gcn combine weight shape {}x{}",
                    w.rows,
                    w.cols
                );
                ensure!(thresh.len() == *d_out, "layer {li}: threshold table size");
                ensure!(
                    dims.feat == nodes * d_in,
                    "layer {li}: input feature walk mismatch"
                );
                let gcn = backend(plan.layers[li].scheme)?
                    .prepare_gcn(adj, w)
                    .map_err(|e| anyhow!("layer {li}: {e}"))?;
                scratch_words = scratch_words.max(gcn.scratch_words(batch_cap));
                PreparedLayer::BinGcn { gcn, thresh: thresh.clone() }
            }
            (
                LayerSpec::FinalFc { d_in, d_out },
                LayerWeights::FinalFc { w, gamma, beta },
            ) => {
                ensure!(
                    w.rows == *d_out && w.cols == *d_in,
                    "layer {li}: classifier weight shape"
                );
                ensure!(
                    gamma.len() == *d_out && beta.len() == *d_out,
                    "layer {li}: bn table size"
                );
                let fc = backend(plan.layers[li].scheme)?
                    .prepare_fc(w)
                    .map_err(|e| anyhow!("layer {li}: {e}"))?;
                ensure!(
                    fc.supports_input_layout(plan.layers[li].in_layout),
                    "layer {li}: backend {} cannot execute planned input layout {}",
                    plan.layers[li].scheme.name(),
                    plan.layers[li].in_layout
                );
                scratch_words = scratch_words.max(fc.scratch_words(batch_cap));
                PreparedLayer::FinalFc {
                    fc,
                    gamma: gamma.clone(),
                    beta: beta.clone(),
                }
            }
            (LayerSpec::Pool, LayerWeights::Pool) => PreparedLayer::Pool,
            _ => bail!("layer {li}: weight kind does not match layer spec"),
        });
        dims = dims.after(l);
    }
    Ok((out, scratch_words))
}

#[derive(Clone, Copy)]
struct FirstConvParams {
    h: usize,
    c: usize,
    o: usize,
    k: usize,
    stride: usize,
    pad: usize,
    batch: usize,
    ohw: usize,
    wio: usize,
}

/// First layer: fp32 NHWC x +/-1 filter -> thresholded HWNC bits.
/// Accumulation order (r, s, ci) matches `nn::forward` exactly, so the
/// f32 rounding — and therefore every output bit — is identical.
#[allow(clippy::too_many_arguments)]
fn first_conv_rows(
    input: &[f32],
    dst: &mut [u32],
    chunk: usize,
    threads: usize,
    p: FirstConvParams,
    w_t: &[f32],
    thresh: &[f32],
) {
    let taps = p.k * p.k * p.c;
    scoped_chunks(dst, chunk, threads, |op, row| {
        for oq in 0..p.ohw {
            for ni in 0..p.batch {
                for wo in 0..p.wio {
                    let mut word = 0u32;
                    for bit in 0..32 {
                        let oi = wo * 32 + bit;
                        if oi >= p.o {
                            break;
                        }
                        let wrow = &w_t[oi * taps..(oi + 1) * taps];
                        let mut acc = 0.0f32;
                        for r in 0..p.k {
                            for s in 0..p.k {
                                let i = (op * p.stride + r) as isize - p.pad as isize;
                                let j = (oq * p.stride + s) as isize - p.pad as isize;
                                if i < 0
                                    || i >= p.h as isize
                                    || j < 0
                                    || j >= p.h as isize
                                {
                                    continue;
                                }
                                let xbase =
                                    ((ni * p.h + i as usize) * p.h + j as usize) * p.c;
                                let wbase = (r * p.k + s) * p.c;
                                for ci in 0..p.c {
                                    acc += input[xbase + ci] * wrow[wbase + ci];
                                }
                            }
                        }
                        if acc >= thresh[oi] {
                            word |= 1 << bit;
                        }
                    }
                    row[(oq * p.batch + ni) * p.wio + wo] = word;
                }
            }
        }
    });
}

/// Shape of one conv threshold-packing pass.
#[derive(Clone, Copy)]
struct PackConvParams {
    ohw: usize,
    batch: usize,
    o: usize,
    wio: usize,
}

/// Threshold + repack the conv accumulators into HWNC bits.
fn pack_conv_ints(
    ints: &[i32],
    dst: &mut [u32],
    chunk: usize,
    threads: usize,
    p: PackConvParams,
    thresh: &[f32],
) {
    scoped_chunks(dst, chunk, threads, |op, row| {
        for oq in 0..p.ohw {
            for ni in 0..p.batch {
                let ibase = ((op * p.ohw + oq) * p.batch + ni) * p.o;
                for wo in 0..p.wio {
                    let mut word = 0u32;
                    for bit in 0..32 {
                        let oi = wo * 32 + bit;
                        if oi >= p.o {
                            break;
                        }
                        if (ints[ibase + oi] as f32) >= thresh[oi] {
                            word |= 1 << bit;
                        }
                    }
                    row[(oq * p.batch + ni) * p.wio + wo] = word;
                }
            }
        }
    });
}

/// 2x2 OR pool over an HWNC bit buffer (`ihw` is the input extent).
fn or_pool_rows(
    src: &[u32],
    dst: &mut [u32],
    chunk: usize,
    threads: usize,
    ihw: usize,
    batch: usize,
    wi: usize,
) {
    let ohw = ihw / 2;
    scoped_chunks(dst, chunk, threads, |hi, row| {
        for wj in 0..ohw {
            for ni in 0..batch {
                let base = |a: usize, b: usize| ((a * ihw + b) * batch + ni) * wi;
                let s00 = base(2 * hi, 2 * wj);
                let s01 = base(2 * hi, 2 * wj + 1);
                let s10 = base(2 * hi + 1, 2 * wj);
                let s11 = base(2 * hi + 1, 2 * wj + 1);
                let out = &mut row[(wj * batch + ni) * wi..(wj * batch + ni + 1) * wi];
                for t in 0..wi {
                    out[t] = src[s00 + t] | src[s01 + t] | src[s10 + t] | src[s11 + t];
                }
            }
        }
    });
}

/// Materialize the current activation as row-packed bits in `dst`
/// (batch x ceil(d_in/32) words); returns the logical feature count.
///
/// * `Fp`   — binarize the caller's flat fp input (first-layer MLPs)
/// * `Bits` — flatten HWNC in (h, w, c) feature order, word-aligned
///   copies when the channel count is a word multiple
/// * `Flat` — copy the rows across (the previous FC left them in `src`)
fn flatten_into(
    input: &[f32],
    repr: Repr,
    batch: usize,
    src: &[u32],
    dst: &mut [u32],
    d_in: usize,
    threads: usize,
) -> usize {
    let wpl = d_in.div_ceil(32);
    match repr {
        Repr::Fp => {
            scoped_chunks(
                &mut dst[..batch * wpl],
                wpl,
                par_threads(threads, batch * wpl),
                |ni, row| {
                    for (wo, out) in row.iter_mut().enumerate() {
                        let mut word = 0u32;
                        for bit in 0..32 {
                            let idx = wo * 32 + bit;
                            if idx >= d_in {
                                break;
                            }
                            if input[ni * d_in + idx] >= 0.0 {
                                word |= 1 << bit;
                            }
                        }
                        *out = word;
                    }
                },
            );
            d_in
        }
        Repr::Bits { hw, c } => {
            let wi = c.div_ceil(32);
            let feat = hw * hw * c;
            if c % 32 == 0 {
                scoped_chunks(
                    &mut dst[..batch * wpl],
                    wpl,
                    par_threads(threads, batch * wpl),
                    |ni, row| {
                        for pix in 0..hw * hw {
                            let sbase = (pix * batch + ni) * wi;
                            let dbase = pix * wi;
                            row[dbase..dbase + wi]
                                .copy_from_slice(&src[sbase..sbase + wi]);
                        }
                    },
                );
            } else {
                scoped_chunks(
                    &mut dst[..batch * wpl],
                    wpl,
                    par_threads(threads, batch * wpl),
                    |ni, row| {
                        row.fill(0);
                        let mut idx = 0usize;
                        for pix in 0..hw * hw {
                            let sbase = (pix * batch + ni) * wi;
                            for ci in 0..c {
                                if pack::get_bit(&src[sbase..sbase + wi], ci) {
                                    row[idx / 32] |= 1 << (idx % 32);
                                }
                                idx += 1;
                            }
                        }
                    },
                );
            }
            feat
        }
        Repr::Flat { feat } => {
            dst[..batch * wpl].copy_from_slice(&src[..batch * wpl]);
            feat
        }
        // Blocked64 activations are converted by the caller through the
        // explicit repack path, never flattened here
        Repr::Flat64 { .. } => unreachable!("Flat64 repacks through layout::repack"),
    }
}

/// Threshold + pack FC dots straight into `Blocked64` u64 rows — the
/// layout-chained twin of [`pack_fc_ints`].  Bit `j` lands at u64 word
/// `j/64`, bit `j%64`: exactly the `bitops::pack64` pairing of the u32
/// packing, so a chained consumer sees bit-identical activations.
fn pack_fc_ints64(
    ints: &[i32],
    dst: &mut [u64],
    wpl64_out: usize,
    threads: usize,
    d_out: usize,
    thresh: &[f32],
) {
    scoped_chunks(dst, wpl64_out, threads, |ni, row| {
        for (wo, out) in row.iter_mut().enumerate() {
            let mut word = 0u64;
            for bit in 0..64 {
                let j = wo * 64 + bit;
                if j >= d_out {
                    break;
                }
                if (ints[ni * d_out + j] as f32) >= thresh[j] {
                    word |= 1 << bit;
                }
            }
            *out = word;
        }
    });
}

/// Threshold + pack GCN aggregates into flat packed rows.  The
/// threshold table holds one entry per output *feature* and repeats
/// every `d_out` columns (shared across nodes) — the same comparison
/// `nn::forward` applies, so the bits are identical.
fn pack_gcn_ints(
    ints: &[i32],
    dst: &mut [u32],
    wpl_out: usize,
    threads: usize,
    d_out: usize,
    dout_total: usize,
    thresh: &[f32],
) {
    scoped_chunks(dst, wpl_out, threads, |ni, row| {
        for (wo, out) in row.iter_mut().enumerate() {
            let mut word = 0u32;
            for bit in 0..32 {
                let j = wo * 32 + bit;
                if j >= dout_total {
                    break;
                }
                if (ints[ni * dout_total + j] as f32) >= thresh[j % d_out] {
                    word |= 1 << bit;
                }
            }
            *out = word;
        }
    });
}

/// Threshold + repack FC dots into packed output rows — bitwise the
/// same rule for every backend.
fn pack_fc_ints(
    ints: &[i32],
    dst: &mut [u32],
    wpl_out: usize,
    threads: usize,
    d_out: usize,
    thresh: &[f32],
) {
    scoped_chunks(dst, wpl_out, threads, |ni, row| {
        for (wo, out) in row.iter_mut().enumerate() {
            let mut word = 0u32;
            for bit in 0..32 {
                let j = wo * 32 + bit;
                if j >= d_out {
                    break;
                }
                if (ints[ni * d_out + j] as f32) >= thresh[j] {
                    word |= 1 << bit;
                }
            }
            *out = word;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::planner::Planner;
    use crate::nn::forward::{forward, random_weights};
    use crate::nn::layer::Dims;
    use crate::nn::Scheme;
    use crate::sim::RTX2080TI;
    use crate::util::Rng;

    fn conv_model() -> ModelDef {
        ModelDef {
            name: "engine-conv-test",
            dataset: "synthetic",
            input: Dims { hw: 8, feat: 3 },
            classes: 4,
            layers: vec![
                LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
                LayerSpec::BinConv {
                    c: 32,
                    o: 32,
                    k: 3,
                    stride: 1,
                    pad: 1,
                    pool: true,
                    residual: false,
                },
                LayerSpec::BinFc { d_in: 4 * 4 * 32, d_out: 64 },
                LayerSpec::FinalFc { d_in: 64, d_out: 4 },
            ],
            residual_blocks: 0,
        }
    }

    fn pool_model() -> ModelDef {
        ModelDef {
            name: "engine-pool-test",
            dataset: "synthetic",
            input: Dims { hw: 8, feat: 3 },
            classes: 4,
            layers: vec![
                LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
                LayerSpec::Pool,
                LayerSpec::BinConv {
                    c: 32,
                    o: 32,
                    k: 3,
                    stride: 2,
                    pad: 1,
                    pool: false,
                    residual: false,
                },
                LayerSpec::BinFc { d_in: 2 * 2 * 32, d_out: 32 },
                LayerSpec::FinalFc { d_in: 32, d_out: 4 },
            ],
            residual_blocks: 0,
        }
    }

    fn gcn_model() -> ModelDef {
        let spec = crate::sparse::AdjSpec {
            kind: crate::sparse::AdjKind::PowerLaw,
            degree: 3,
            seed: 5,
        };
        let nodes = 32;
        let nnz_blocks = crate::sparse::generate(spec, nodes).nnz_blocks();
        ModelDef {
            name: "engine-gcn-test",
            dataset: "synthetic-graph",
            input: Dims { hw: 0, feat: nodes * 64 },
            classes: 4,
            layers: vec![
                LayerSpec::BinGcn { nodes, d_in: 64, d_out: 64, adj: spec, nnz_blocks },
                LayerSpec::BinFc { d_in: nodes * 64, d_out: 64 },
                LayerSpec::FinalFc { d_in: 64, d_out: 4 },
            ],
            residual_blocks: 0,
        }
    }

    fn build(model: ModelDef, seed: u64, batch: usize) -> (EngineExecutor, ModelWeights) {
        let mut rng = Rng::new(seed);
        let weights = random_weights(&model, &mut rng);
        let plan = Planner::new(&RTX2080TI).plan(&model, batch);
        let exec = EngineExecutor::new(model, &weights, plan).unwrap();
        (exec, weights)
    }

    #[test]
    fn matches_naive_forward_bit_for_bit() {
        for (m, seed) in [(conv_model(), 5u64), (pool_model(), 9u64)] {
            let batch = 8;
            let (mut exec, weights) = build(m.clone(), seed, batch);
            let mut rng = Rng::new(seed + 100);
            let x: Vec<f32> = (0..batch * m.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let want = forward(&m, &weights, &x, batch);
            let got = exec.forward(&x, batch);
            assert_eq!(got, &want[..], "{}", m.name);
        }
    }

    #[test]
    fn every_scheme_plan_matches_naive_forward_bit_for_bit() {
        // one fixed plan per registered scheme: all backends must
        // produce identical bits through the executor
        for (m, seed) in [(conv_model(), 15u64), (pool_model(), 19u64)] {
            let batch = 8;
            let mut rng = Rng::new(seed);
            let weights = random_weights(&m, &mut rng);
            let x: Vec<f32> = (0..batch * m.input.flat())
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let want = forward(&m, &weights, &x, batch);
            for scheme in BackendRegistry::global().schemes() {
                let plan = Planner::new(&RTX2080TI).plan_fixed(&m, batch, scheme);
                let mut exec =
                    EngineExecutor::new(m.clone(), &weights, plan).unwrap();
                assert_eq!(
                    exec.forward(&x, batch),
                    &want[..],
                    "{} under {}",
                    m.name,
                    scheme.name()
                );
                // the scratch was sized at build time and never grows
                let watermark = exec.arena_bytes();
                let _ = exec.forward(&x, batch);
                assert_eq!(exec.arena_bytes(), watermark);
            }
        }
    }

    #[test]
    fn every_scheme_plan_matches_naive_forward_on_gcn() {
        // the GCN layer runs under every registered scheme — the sparse
        // backends stage block-sparse adjacency, everything else the
        // DenseGcn default — and all of them are bit-identical to the
        // reference forward
        let m = gcn_model();
        let batch = 4;
        let mut rng = Rng::new(91);
        let weights = random_weights(&m, &mut rng);
        let x: Vec<f32> = (0..batch * m.input.flat())
            .map(|_| rng.next_f32() - 0.5)
            .collect();
        let want = forward(&m, &weights, &x, batch);
        for scheme in BackendRegistry::global().schemes() {
            let plan = Planner::new(&RTX2080TI).plan_fixed(&m, batch, scheme);
            let mut exec = EngineExecutor::new(m.clone(), &weights, plan).unwrap();
            assert_eq!(
                exec.forward(&x, batch),
                &want[..],
                "{} under {}",
                m.name,
                scheme.name()
            );
            // arena stays constant across passes (zero-allocation path)
            let watermark = exec.arena_bytes();
            let _ = exec.forward(&x, batch);
            assert_eq!(exec.arena_bytes(), watermark);
        }
    }

    #[test]
    fn fastpath_mlp_matches_scalar_engine() {
        let m = crate::nn::model::mnist_mlp();
        let batch = 8;
        let mut rng = Rng::new(23);
        let weights = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let mut scalar = EngineExecutor::new(
            m.clone(),
            &weights,
            planner.plan(&m, batch),
        )
        .unwrap();
        let mut fast = EngineExecutor::new(
            m.clone(),
            &weights,
            planner.plan_fixed(&m, batch, Scheme::Fastpath),
        )
        .unwrap();
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32() - 0.5).collect();
        assert_eq!(scalar.forward(&x, batch), fast.forward(&x, batch));
    }

    #[test]
    fn serial_and_parallel_agree() {
        let m = conv_model();
        let batch = 8;
        let (exec, weights) = build(m.clone(), 7, batch);
        let mut serial = EngineExecutor::new(
            m.clone(),
            &weights,
            Planner::new(&RTX2080TI).plan(&m, batch),
        )
        .unwrap()
        .with_threads(1);
        let mut parallel = exec.with_threads(4);
        let mut rng = Rng::new(77);
        let x: Vec<f32> =
            (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
        assert_eq!(serial.forward(&x, batch), parallel.forward(&x, batch));
    }

    #[test]
    fn smaller_batches_on_same_arena() {
        let m = conv_model();
        let (mut exec, weights) = build(m.clone(), 11, 8);
        let mut rng = Rng::new(13);
        let x8: Vec<f32> =
            (0..8 * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
        let want8 = forward(&m, &weights, &x8, 8);
        // run batch 3 (subset rows) on the batch-8 arena; rows are
        // independent in both paths, so the batch-8 prefix is ground
        // truth for the shared rows
        let x3 = x8[..3 * m.input.flat()].to_vec();
        let got3 = exec.forward(&x3, 3).to_vec();
        assert_eq!(got3.len(), 3 * 4);
        assert_eq!(&got3[..], &want8[..3 * 4]);
        // and the arena never grew
        let before = exec.arena_bytes();
        let _ = exec.forward(&x8, 8);
        assert_eq!(exec.arena_bytes(), before);
    }

    #[test]
    fn mlp_from_fp_input_is_deterministic() {
        let m = crate::nn::model::mnist_mlp();
        let batch = 8;
        let mut rng = Rng::new(21);
        let weights = random_weights(&m, &mut rng);
        let plan = Planner::new(&RTX2080TI).plan(&m, batch);
        let mut exec = EngineExecutor::new(m.clone(), &weights, plan).unwrap();
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32() - 0.5).collect();
        let a = exec.forward(&x, batch).to_vec();
        let b = exec.forward(&x, batch).to_vec();
        assert_eq!(a, b);
        assert_eq!(a.len(), batch * 10);
        assert!(a.iter().all(|v| v.is_finite()));
        // different rows should (almost surely) differ
        assert_ne!(a[..10], a[10..20]);
    }

    #[test]
    fn latency_sink_records_backend_layers_only() {
        let m = conv_model();
        let batch = 8;
        let (exec, _weights) = build(m.clone(), 41, batch);
        let sink = Arc::new(LiveCosts::new());
        let mut exec = exec.with_latency_sink(Arc::clone(&sink));
        let mut rng = Rng::new(42);
        let x: Vec<f32> =
            (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
        let _ = exec.forward(&x, batch);
        // conv_model has 3 backend-dispatched layers (BinConv, BinFc,
        // FinalFc); FirstConv is scheme-independent and never recorded
        let total: u64 = Scheme::all().iter().map(|s| sink.samples(*s)).sum();
        assert_eq!(total, 3);
        let _ = exec.forward(&x, batch);
        let total: u64 = Scheme::all().iter().map(|s| sink.samples(*s)).sum();
        assert_eq!(total, 6);
        // the recorded schemes are exactly the plan's backend-layer ones
        for lp in &exec.plan().layers[1..] {
            assert!(sink.samples(lp.scheme) > 0, "{:?}", lp.scheme);
        }
    }

    #[test]
    fn layer_attribution_and_spans_cover_the_plan() {
        let m = conv_model();
        let batch = 8;
        let (mut exec, _weights) = build(m.clone(), 51, batch);
        let mut rng = Rng::new(52);
        let x: Vec<f32> =
            (0..batch * m.input.flat()).map(|_| rng.next_f32() - 0.5).collect();
        let _ = exec.forward(&x, batch);
        let attr = exec.layer_attribution();
        assert_eq!(attr.len(), m.layers.len(), "one entry per plan layer");
        assert!(attr.iter().all(|a| a.calls == 1));
        assert!(attr.iter().all(|a| a.secs >= 0.0 && a.predicted_s >= 0.0));
        assert!(attr.iter().map(|a| a.predicted_s).sum::<f64>() > 0.0);
        let spans = exec.last_pass_spans();
        use crate::obs::SpanKind;
        let layer_spans: Vec<_> =
            spans.iter().filter(|s| s.kind == SpanKind::Layer).collect();
        assert_eq!(layer_spans.len(), m.layers.len());
        assert!(layer_spans[0].label.contains("C3"), "{}", layer_spans[0].label);
        assert!(layer_spans.iter().all(|s| s.bytes > 0), "payload bytes set");
        // single pass: span seconds equal the cumulative attribution
        let span_total: f64 = layer_spans.iter().map(|s| s.secs).sum();
        let attr_total: f64 = attr.iter().map(|a| a.secs).sum();
        assert!((span_total - attr_total).abs() < 1e-12);
        // attribution accumulates across passes
        let _ = exec.forward(&x, batch);
        assert!(exec.layer_attribution().iter().all(|a| a.calls == 2));
    }

    #[test]
    fn repack_edges_attribute_layer_and_layout_pair() {
        let m = crate::nn::model::mnist_mlp();
        let batch = 8;
        let mut rng = Rng::new(61);
        let weights = random_weights(&m, &mut rng);
        let plan =
            Planner::new(&RTX2080TI).plan_fixed(&m, batch, Scheme::Fastpath);
        let mut exec = EngineExecutor::new(m.clone(), &weights, plan).unwrap();
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32() - 0.5).collect();
        let _ = exec.forward(&x, batch);
        // per-scheme aggregation is exactly the per-edge stats summed
        let edges = exec.repack_edges().to_vec();
        let stats = exec.repack_stats();
        let edge_ops: u64 = edges.iter().map(|e| e.ops).sum();
        let edge_bytes: u64 = edges.iter().map(|e| e.bytes).sum();
        let stat_ops: u64 = stats.iter().map(|(_, o, _)| o).sum();
        let stat_bytes: u64 = stats.iter().map(|(_, _, b)| b).sum();
        assert_eq!(edge_ops, stat_ops);
        assert_eq!(edge_bytes, stat_bytes);
        for e in &edges {
            assert!(e.layer < exec.plan().layers.len());
            assert_ne!(e.src, e.dst, "a repack moves between layouts");
            assert!(e.bytes > 0 && e.secs >= 0.0);
        }
        // edges accumulate pass over pass, and the trace interleaves a
        // Repack span before each consuming layer
        if !edges.is_empty() {
            let spans = exec.last_pass_spans();
            use crate::obs::SpanKind;
            let n_repack =
                spans.iter().filter(|s| s.kind == SpanKind::Repack).count();
            assert_eq!(n_repack as u64, edge_ops);
            let _ = exec.forward(&x, batch);
            let after: u64 = exec.repack_edges().iter().map(|e| e.ops).sum();
            assert_eq!(after, 2 * edge_ops);
        }
    }

    #[test]
    fn rejects_mismatched_plan() {
        let m = conv_model();
        let mut rng = Rng::new(31);
        let weights = random_weights(&m, &mut rng);
        let other = pool_model();
        let plan = Planner::new(&RTX2080TI).plan(&other, 8);
        assert!(EngineExecutor::new(m, &weights, plan).is_err());
    }

    #[test]
    fn rejects_plan_scheme_missing_from_registry() {
        let m = conv_model();
        let mut rng = Rng::new(33);
        let weights = random_weights(&m, &mut rng);
        let plan = Planner::new(&RTX2080TI).plan(&m, 8);
        let empty = BackendRegistry::empty();
        let err = EngineExecutor::with_registry(m, &weights, plan, &empty)
            .err()
            .expect("empty registry cannot prepare");
        assert!(err.to_string().contains("no registered backend"), "{err}");
    }
}
