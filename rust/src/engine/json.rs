//! Minimal JSON reader/writer for the plan cache (serde is unavailable
//! offline; this is the same in-tree-replacement policy as `util`).
//!
//! Supports the full JSON value grammar with the escapes the plan files
//! use.  Numbers are f64; `f64`'s shortest-roundtrip `Display` is used
//! on the write side, so a serialize -> parse cycle reproduces every
//! finite value bit-exactly.

use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document (must consume the whole input).
    pub fn parse(text: &str) -> Result<Value, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            // shortest-roundtrip decimal.  JSON has no NaN/inf tokens:
            // emitting `{x}` for them would produce invalid documents
            // ("NaN", "inf"), so non-finite values serialize as null —
            // and the parser below rejects them on the way back in.
            Value::Num(x) if !x.is_finite() => write!(f, "null"),
            Value::Num(x) => write!(f, "{x}"),
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(fields) => {
                write!(f, "{{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') if self.eat_lit("null") => Ok(Value::Null),
            Some(b't') if self.eat_lit("true") => Ok(Value::Bool(true)),
            Some(b'f') if self.eat_lit("false") => Ok(Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err("unterminated string".to_string());
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".to_string());
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            // (surrogate pairs are not needed by plan files)
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| "bad \\u codepoint".to_string())?,
                            );
                        }
                        other => {
                            return Err(format!("bad escape \\{}", other as char))
                        }
                    }
                }
                _ => {
                    // re-decode utf8: step back and take the full char
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = text
            .parse()
            .map_err(|_| format!("bad number {text:?} at byte {start}"))?;
        // overflow literals like 1e999 parse to inf; cost fields must
        // stay finite, so reject instead of smuggling inf through
        if !x.is_finite() {
            return Err(format!("non-finite number {text:?} at byte {start}"));
        }
        Ok(Value::Num(x))
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key_at = self.pos;
            let key = self.string()?;
            // duplicate keys are ambiguous (readers keep whichever they
            // find first) — all our writers emit each key once, so a
            // duplicate means a corrupt or hand-edited document
            if fields.iter().any(|(k, _)| *k == key) {
                return Err(format!("duplicate object key {key:?} at byte {key_at}"));
            }
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let v = Value::Obj(vec![
            ("name".to_string(), Value::Str("MNIST-MLP \"v2\"\n".to_string())),
            ("batch".to_string(), Value::Num(32.0)),
            ("secs".to_string(), Value::Num(1.2345678912345e-5)),
            (
                "layers".to_string(),
                Value::Arr(vec![Value::Null, Value::Bool(true), Value::Num(-3.5)]),
            ),
        ]);
        let text = v.to_string();
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn f64_roundtrip_is_exact() {
        for x in [1.0 / 3.0, 2.2250738585072014e-308, 123456789.123456789, -0.0] {
            let text = Value::Num(x).to_string();
            let back = Value::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} via {text}");
        }
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let v = Value::parse(
            " { \"a\\u0041\" : [ 1 , 2.5e2 , \"x\\ty\" ] , \"b\" : null } ",
        )
        .unwrap();
        assert_eq!(v.get("aA").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b"), Some(&Value::Null));
    }

    #[test]
    fn non_finite_serializes_as_null_and_parse_rejects() {
        // NaN/inf would otherwise print as "NaN"/"inf" — invalid JSON
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Value::Num(x).to_string(), "null");
        }
        let doc = Value::Obj(vec![
            ("ok".to_string(), Value::Num(1.5)),
            ("bad".to_string(), Value::Num(f64::NAN)),
        ]);
        let text = doc.to_string();
        assert_eq!(text, "{\"ok\":1.5,\"bad\":null}");
        // the document stays parseable; the NaN degraded to null
        let back = Value::parse(&text).unwrap();
        assert_eq!(back.get("bad"), Some(&Value::Null));
        // numeric literals that overflow to inf are rejected outright
        assert!(Value::parse("1e999").is_err());
        assert!(Value::parse("[-1e999]").is_err());
        assert!(Value::parse("{\"x\":1e999}").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"abc").is_err());
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        let err = Value::parse("{\"a\":1,\"a\":2}").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        // escape-equivalent keys decode to the same string: rejected too
        assert!(Value::parse("{\"a\":1,\"\\u0061\":2}").is_err());
        // duplicates nested anywhere fail the whole document
        assert!(Value::parse("[{\"x\":{\"k\":1,\"k\":1}}]").is_err());
        // same key in *different* objects is fine
        let ok = Value::parse("[{\"k\":1},{\"k\":2}]").unwrap();
        assert_eq!(ok.as_arr().unwrap().len(), 2);
    }

    /// Characters the writer must escape (or pass through) correctly.
    fn tricky_char(rng: &mut crate::util::Rng) -> char {
        match rng.gen_range(10) {
            0 => '"',
            1 => '\\',
            2 => '\n',
            3 => '\r',
            4 => '\t',
            5 => char::from_u32(rng.gen_range(0x20) as u32).unwrap(), // control
            6 => 'é',
            7 => '線',
            8 => '🦀',
            _ => (b'a' + rng.gen_range(26) as u8) as char,
        }
    }

    #[test]
    fn prop_escaped_strings_roundtrip() {
        crate::util::proptest::run_cases(71, 200, |rng| {
            let len = rng.gen_range(24);
            let s: String = (0..len).map(|_| tricky_char(rng)).collect();
            let v = Value::Obj(vec![
                (s.clone(), Value::Str(s.clone())),
                ("plain".to_string(), Value::Num(1.0)),
            ]);
            let text = v.to_string();
            let back = Value::parse(&text)
                .unwrap_or_else(|e| panic!("{e} parsing {text:?}"));
            assert_eq!(back, v, "via {text:?}");
        });
    }

    /// Random value tree: arrays/objects down to `depth`, scalar leaves.
    fn random_value(rng: &mut crate::util::Rng, depth: usize) -> Value {
        match if depth == 0 { rng.gen_range(4) } else { 4 + rng.gen_range(2) } {
            0 => Value::Null,
            1 => Value::Bool(rng.next_bool()),
            2 => Value::Num((rng.next_f64() - 0.5) * 1e6),
            3 => Value::Str((0..rng.gen_range(8)).map(|_| tricky_char(rng)).collect()),
            4 => Value::Arr(
                (0..rng.gen_range(4)).map(|_| random_value(rng, depth - 1)).collect(),
            ),
            _ => Value::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_deeply_nested_documents_roundtrip() {
        crate::util::proptest::run_cases(72, 100, |rng| {
            let v = random_value(rng, 5);
            assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
        });
        // and a pathological 300-deep chain parses without issue
        let mut v = Value::Num(1.0);
        for i in 0..300 {
            v = if i % 2 == 0 {
                Value::Arr(vec![v])
            } else {
                Value::Obj(vec![("d".to_string(), v)])
            };
        }
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Value::parse("{\"n\":8,\"s\":\"hi\"}").unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert_eq!(v.get("missing"), None);
    }
}
