//! The execution arena: every buffer the packed-bit forward pass ever
//! touches, allocated once from the model shape at build time so the
//! request hot path performs zero heap allocation.

use crate::nn::layer::LayerSpec;
use crate::nn::ModelDef;

/// Words needed for an HWNC packed activation.
fn bits_words(hw: usize, n: usize, c: usize) -> usize {
    hw * hw * n * c.div_ceil(32)
}

/// Words needed for a row-packed flat activation.
fn flat_words(n: usize, feat: usize) -> usize {
    n * feat.div_ceil(32)
}

/// Pre-allocated buffers for one executor.
///
/// * `bits_a` / `bits_b` — ping-pong packed activations.  Each is large
///   enough for the biggest intermediate in either representation
///   (HWNC bit tensor before pooling, or row-packed flat rows).
/// * `ints` — i32 staging for every binarized layer's Eq-2
///   accumulators: the conv accumulator pass and the FC dot pass both
///   stage here before threshold packing.
/// * `words64` — u64 operand scratch for backends that need it (the
///   fastpath's im2row image / repacked input rows); sized by the
///   executor from the prepared layers' reported `scratch_words`, so
///   the arena stays backend-agnostic.  Empty when no prepared layer
///   asks for scratch.
/// * `flat64` — the `Blocked64` flat-activation buffer: planned layout
///   edges materialize explicit repacks here, and `Blocked64`-chained
///   FC layers ping through it without touching the u32 buffers.
///   Sized by the executor from the plan's layout edges; empty for
///   all-`Row32` plans.
/// * `logits` — the classifier output.
pub struct Arena {
    pub bits_a: Vec<u32>,
    pub bits_b: Vec<u32>,
    pub ints: Vec<i32>,
    pub words64: Vec<u64>,
    pub flat64: Vec<u64>,
    pub logits: Vec<f32>,
}

impl Arena {
    /// Size the structural buffers for `model` at batch capacity
    /// `batch` (no u64 scratch — chain [`Arena::with_scratch_words`]
    /// for backends that need it).
    pub fn for_model(model: &ModelDef, batch: usize) -> Arena {
        let mut dims = model.input;
        let mut max_words = 0usize;
        let mut max_ints = 0usize;
        // the first binarization of a flat fp input also lands in a buffer
        if dims.hw == 0 {
            max_words = max_words.max(flat_words(batch, dims.feat));
        }
        for l in &model.layers {
            match *l {
                LayerSpec::FirstConv { o, k, stride, pad, .. } => {
                    let ohw = (dims.hw + 2 * pad - k) / stride + 1;
                    max_words = max_words.max(bits_words(ohw, batch, o));
                }
                LayerSpec::BinConv { o, k, stride, pad, .. } => {
                    // pre-pool extent (the conv writes this; pooling shrinks)
                    let opre = (dims.hw + 2 * pad - k) / stride + 1;
                    max_words = max_words.max(bits_words(opre, batch, o));
                    max_ints = max_ints.max(opre * opre * batch * o);
                }
                LayerSpec::BinFc { d_in, d_out } => {
                    // flatten staging + the packed output rows + dots
                    max_words = max_words.max(flat_words(batch, d_in));
                    max_words = max_words.max(flat_words(batch, d_out));
                    max_ints = max_ints.max(batch * d_out);
                }
                LayerSpec::FinalFc { d_in, d_out } => {
                    max_words = max_words.max(flat_words(batch, d_in));
                    max_ints = max_ints.max(batch * d_out);
                }
                LayerSpec::BinGcn { nodes, d_in, d_out, .. } => {
                    // flat node-feature rows in and out, plus the
                    // per-node-feature Eq-2 accumulators
                    max_words = max_words.max(flat_words(batch, nodes * d_in));
                    max_words = max_words.max(flat_words(batch, nodes * d_out));
                    max_ints = max_ints.max(batch * nodes * d_out);
                }
                LayerSpec::Pool => {
                    max_words = max_words.max(bits_words(dims.hw, batch, dims.feat));
                }
            }
            dims = dims.after(l);
        }
        Arena {
            bits_a: vec![0u32; max_words],
            bits_b: vec![0u32; max_words],
            ints: vec![0i32; max_ints],
            words64: Vec::new(),
            flat64: Vec::new(),
            logits: vec![0f32; batch * model.classes],
        }
    }

    /// Attach `words` u64 words of backend scratch (the maximum any
    /// prepared layer reported for the batch capacity).
    pub fn with_scratch_words(mut self, words: usize) -> Arena {
        self.words64 = vec![0u64; words];
        self
    }

    /// Attach `words` u64 words of `Blocked64` flat-activation buffer
    /// (the maximum any planned layout edge needs at batch capacity).
    pub fn with_flat64_words(mut self, words: usize) -> Arena {
        self.flat64 = vec![0u64; words];
        self
    }

    /// Total allocated bytes — the arena's high-water mark.  Constant
    /// after construction; benches assert it never grows across requests.
    pub fn bytes(&self) -> usize {
        self.bits_a.len() * 4
            + self.bits_b.len() * 4
            + self.ints.len() * 4
            + self.words64.len() * 8
            + self.flat64.len() * 8
            + self.logits.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{cifar_vgg, mnist_mlp};

    #[test]
    fn mlp_arena_sizes_fc_dot_staging() {
        let a = Arena::for_model(&mnist_mlp(), 32);
        // biggest flat activation: 32 rows x 1024 bits
        assert!(a.bits_a.len() >= 32 * (1024 / 32));
        // dot staging covers the widest FC layer
        assert!(a.ints.len() >= 32 * 1024);
        assert_eq!(a.logits.len(), 32 * 10);
        // no backend asked for u64 scratch
        assert!(a.words64.is_empty());
    }

    #[test]
    fn conv_arena_covers_prepool_extent() {
        let m = cifar_vgg();
        let a = Arena::for_model(&m, 8);
        // layer 2 (first BinConv) pre-pool: 32x32 x 8 x 128ch packed
        assert!(a.bits_a.len() >= 32 * 32 * 8 * (128 / 32));
        assert!(a.ints.len() >= 32 * 32 * 8 * 128);
        assert_eq!(a.bits_a.len(), a.bits_b.len());
    }

    #[test]
    fn scratch_words_attach_u64_buffer() {
        let a = Arena::for_model(&mnist_mlp(), 8).with_scratch_words(1024);
        assert_eq!(a.words64.len(), 1024);
        let plain = Arena::for_model(&mnist_mlp(), 8);
        assert!(plain.words64.is_empty());
        assert!(plain.flat64.is_empty());
    }

    #[test]
    fn flat64_words_attach_layout_buffer() {
        let a = Arena::for_model(&mnist_mlp(), 8).with_flat64_words(8 * 16);
        assert_eq!(a.flat64.len(), 128);
        assert!(a.words64.is_empty());
    }

    #[test]
    fn bytes_reports_total() {
        let a = Arena::for_model(&mnist_mlp(), 8)
            .with_scratch_words(16)
            .with_flat64_words(32);
        assert_eq!(
            a.bytes(),
            4 * (a.bits_a.len() + a.bits_b.len() + a.ints.len() + a.logits.len())
                + 8 * (a.words64.len() + a.flat64.len())
        );
    }
}
