//! The execution arena: every buffer the packed-bit forward pass ever
//! touches, allocated once from the model shape at build time so the
//! request hot path performs zero heap allocation.

use crate::bitops::pack64::words64;
use crate::nn::layer::LayerSpec;
use crate::nn::{ModelDef, Scheme};

/// Words needed for an HWNC packed activation.
fn bits_words(hw: usize, n: usize, c: usize) -> usize {
    hw * hw * n * c.div_ceil(32)
}

/// Words needed for a row-packed flat activation.
fn flat_words(n: usize, feat: usize) -> usize {
    n * feat.div_ceil(32)
}

/// Pre-allocated buffers for one executor.
///
/// * `bits_a` / `bits_b` — ping-pong packed activations.  Each is large
///   enough for the biggest intermediate in either representation
///   (HWNC bit tensor before pooling, or row-packed flat rows).
/// * `ints` — i32 staging for the convolution accumulator pass (and
///   the fastpath FC dot staging, when a plan routes FC layers there).
/// * `words64` — u64 operand scratch for fastpath layers (im2row image
///   for bconv, repacked input rows for FC); empty unless the plan
///   selects `Scheme::Fastpath` somewhere.
/// * `logits` — the classifier output.
pub struct Arena {
    pub bits_a: Vec<u32>,
    pub bits_b: Vec<u32>,
    pub ints: Vec<i32>,
    pub words64: Vec<u64>,
    pub logits: Vec<f32>,
}

impl Arena {
    /// Size every buffer for `model` at batch capacity `batch`, with no
    /// fastpath layers (no u64 scratch).
    pub fn for_model(model: &ModelDef, batch: usize) -> Arena {
        Arena::for_model_with_schemes(model, batch, &[])
    }

    /// Size every buffer for `model` at batch capacity `batch`.
    /// `schemes` is the plan's per-layer scheme choice (missing entries
    /// mean "not fastpath"); layers routed to `Scheme::Fastpath` add
    /// their u64 operand scratch and FC dot staging to the arena.
    pub fn for_model_with_schemes(
        model: &ModelDef,
        batch: usize,
        schemes: &[Scheme],
    ) -> Arena {
        let mut dims = model.input;
        let mut max_words = 0usize;
        let mut max_ints = 0usize;
        let mut max_w64 = 0usize;
        // the first binarization of a flat fp input also lands in a buffer
        if dims.hw == 0 {
            max_words = max_words.max(flat_words(batch, dims.feat));
        }
        for (li, l) in model.layers.iter().enumerate() {
            let fast = schemes.get(li) == Some(&Scheme::Fastpath);
            match *l {
                LayerSpec::FirstConv { o, k, stride, pad, .. } => {
                    let ohw = (dims.hw + 2 * pad - k) / stride + 1;
                    max_words = max_words.max(bits_words(ohw, batch, o));
                }
                LayerSpec::BinConv { o, k, stride, pad, .. } => {
                    // pre-pool extent (the conv writes this; pooling shrinks)
                    let opre = (dims.hw + 2 * pad - k) / stride + 1;
                    max_words = max_words.max(bits_words(opre, batch, o));
                    max_ints = max_ints.max(opre * opre * batch * o);
                    if fast {
                        let tap_words = words64(dims.feat.div_ceil(32));
                        max_w64 = max_w64
                            .max(opre * opre * batch * k * k * tap_words);
                    }
                }
                LayerSpec::BinFc { d_in, d_out } => {
                    // flatten staging + the packed output rows
                    max_words = max_words.max(flat_words(batch, d_in));
                    max_words = max_words.max(flat_words(batch, d_out));
                    if fast {
                        max_w64 = max_w64.max(batch * words64(d_in.div_ceil(32)));
                        max_ints = max_ints.max(batch * d_out);
                    }
                }
                LayerSpec::FinalFc { d_in, d_out } => {
                    max_words = max_words.max(flat_words(batch, d_in));
                    if fast {
                        max_w64 = max_w64.max(batch * words64(d_in.div_ceil(32)));
                        max_ints = max_ints.max(batch * d_out);
                    }
                }
                LayerSpec::Pool => {
                    max_words = max_words.max(bits_words(dims.hw, batch, dims.feat));
                }
            }
            dims = dims.after(l);
        }
        Arena {
            bits_a: vec![0u32; max_words],
            bits_b: vec![0u32; max_words],
            ints: vec![0i32; max_ints],
            words64: vec![0u64; max_w64],
            logits: vec![0f32; batch * model.classes],
        }
    }

    /// Total allocated bytes — the arena's high-water mark.  Constant
    /// after construction; benches assert it never grows across requests.
    pub fn bytes(&self) -> usize {
        self.bits_a.len() * 4
            + self.bits_b.len() * 4
            + self.ints.len() * 4
            + self.words64.len() * 8
            + self.logits.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{cifar_vgg, mnist_mlp};

    #[test]
    fn mlp_arena_has_no_conv_staging() {
        let a = Arena::for_model(&mnist_mlp(), 32);
        assert!(a.ints.is_empty());
        // biggest flat activation: 32 rows x 1024 bits
        assert!(a.bits_a.len() >= 32 * (1024 / 32));
        assert_eq!(a.logits.len(), 32 * 10);
    }

    #[test]
    fn conv_arena_covers_prepool_extent() {
        let m = cifar_vgg();
        let a = Arena::for_model(&m, 8);
        // layer 2 (first BinConv) pre-pool: 32x32 x 8 x 128ch packed
        assert!(a.bits_a.len() >= 32 * 32 * 8 * (128 / 32));
        assert!(a.ints.len() >= 32 * 32 * 8 * 128);
        assert_eq!(a.bits_a.len(), a.bits_b.len());
    }

    #[test]
    fn fastpath_schemes_add_u64_scratch() {
        let m = mnist_mlp();
        let schemes = vec![Scheme::Fastpath; m.layers.len()];
        let a = Arena::for_model_with_schemes(&m, 8, &schemes);
        // repacked input rows for the widest FC + dot staging
        assert!(!a.words64.is_empty());
        assert!(a.ints.len() >= 8 * 1024);
        // without fastpath layers the scratch stays empty
        let plain = Arena::for_model(&m, 8);
        assert!(plain.words64.is_empty());
        assert!(plain.ints.is_empty());
    }

    #[test]
    fn bytes_reports_total() {
        let a = Arena::for_model(&mnist_mlp(), 8);
        assert_eq!(
            a.bytes(),
            4 * (a.bits_a.len() + a.bits_b.len() + a.ints.len() + a.logits.len())
        );
    }
}
