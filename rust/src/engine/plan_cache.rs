//! Persistent JSON plan cache keyed by (model, batch shape, gpu).
//!
//! Planning is cheap but not free (one cost simulation per registered
//! backend per layer); serving stacks restart often and re-plan the
//! same shapes.  The cache stores one JSON document per key under a
//! directory and counts hits/misses so benches can report cache
//! effectiveness.
//!
//! Staleness: every plan embeds its JSON schema version, the scheme
//! set it was searched over, and the cost-profile id it was ranked
//! under.  An entry written by an older build (schema mismatch),
//! planned before a new backend registered (scheme-set mismatch), or
//! planned under a different calibration profile (cost-profile
//! mismatch — see `tuner::CostSource`) is treated as a miss and
//! re-planned — cached winners never silently pin out a backend they
//! were never compared against, nor survive a calibration change that
//! re-priced the competition.
//!
//! The active `CalibrationProfile` itself persists next to the entries
//! ([`PlanCache::profile_path`]), so a serving process reopens both
//! the plans and the calibration they were priced under.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernels::backend::BackendRegistry;
use crate::nn::ModelDef;

use super::plan::ModelPlan;
use super::planner::Planner;

/// A directory of `*.plan.json` files + hit/miss counters.
pub struct PlanCache {
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl AsRef<Path>) -> std::io::Result<PlanCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(PlanCache { dir, hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    /// Path of the entry for a key.
    pub fn entry_path(&self, model: &str, batch: usize, gpu: &str) -> PathBuf {
        self.dir.join(ModelPlan::cache_file(model, batch, gpu))
    }

    /// Where the active calibration profile lives, next to the plan
    /// entries it prices (`tuner::CalibrationProfile::save`/`load`).
    pub fn profile_path(&self) -> PathBuf {
        self.dir.join("calibration.profile.json")
    }

    /// Read + validate an entry without touching the counters.
    /// `scheme_names` is the serving registry's scheme set and
    /// `cost_profile` the serving planner's cost-source id — an entry
    /// planned over a different set or under a different calibration
    /// is stale and filtered out.
    fn read(
        &self,
        model: &str,
        batch: usize,
        gpu: &str,
        scheme_names: &[String],
        cost_profile: &str,
    ) -> Option<ModelPlan> {
        let path = self.entry_path(model, batch, gpu);
        std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| ModelPlan::from_json(&text).ok())
            .filter(|p| {
                p.model == model
                    && p.batch == batch
                    && p.gpu == gpu
                    && p.scheme_set == scheme_names
                    && p.cost_profile == cost_profile
            })
    }

    /// Look up a cached plan, validated against `scheme_names` and
    /// `cost_profile` — pass the serving planner's scheme set
    /// (`planner.scheme_names()`) and cost-source id
    /// (`planner.cost_profile_id()`) so `get_for` and
    /// [`PlanCache::get_or_plan`] agree on what is stale.  A missing,
    /// malformed, old-schema, stale-scheme-set, or stale-cost-profile
    /// entry counts as a miss.
    pub fn get_for(
        &self,
        model: &str,
        batch: usize,
        gpu: &str,
        scheme_names: &[String],
        cost_profile: &str,
    ) -> Option<ModelPlan> {
        match self.read(model, batch, gpu, scheme_names, cost_profile) {
            Some(p) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(p)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// [`PlanCache::get_for`] against the *global* builtin registry's
    /// scheme set and the analytic cost source.  Callers serving a
    /// custom registry or a calibrated planner must use
    /// `get_for`/`get_or_plan` instead, or hits and misses will
    /// disagree with what their planner considers stale.
    pub fn get(&self, model: &str, batch: usize, gpu: &str) -> Option<ModelPlan> {
        let names: Vec<String> = BackendRegistry::global()
            .names()
            .iter()
            .map(|s| s.to_string())
            .collect();
        self.get_for(model, batch, gpu, &names, crate::tuner::ANALYTIC_PROFILE_ID)
    }

    /// Store a plan (overwrites any existing entry for its key).
    pub fn put(&self, plan: &ModelPlan) -> std::io::Result<PathBuf> {
        let path = self.entry_path(&plan.model, plan.batch, &plan.gpu);
        std::fs::write(&path, plan.to_json())?;
        Ok(path)
    }

    /// Cached plan, or plan now and persist.  A failed write is not
    /// fatal (the fresh plan is still returned); it will simply re-plan
    /// next time.
    pub fn get_or_plan(
        &self,
        planner: &Planner,
        model: &ModelDef,
        batch: usize,
    ) -> ModelPlan {
        let names = planner.scheme_names();
        let profile = planner.cost_profile_id();
        if let Some(p) = self.read(model.name, batch, planner.gpu.name, &names, &profile)
        {
            // validate against the live model definition; shape drift
            // (e.g. a renamed layer) is a MISS that falls back to fresh
            // planning (and re-persists below, self-healing the entry).
            // The sparsity fingerprint guards the graph models: a plan
            // ranked for one adjacency density must not survive a
            // regenerated graph whose sparse-vs-dense crossover differs.
            let tags_match = p.layers.len() == model.layers.len()
                && p.layers.iter().zip(&model.layers).all(|(lp, l)| lp.tag == l.tag());
            if tags_match && p.sparsity == Planner::sparsity_fingerprint(model) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return p;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = planner.plan(model, batch);
        if let Err(e) = self.put(&plan) {
            eprintln!(
                "plan cache: could not persist {}/b{}: {e}",
                plan.model, plan.batch
            );
        }
        plan
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::mnist_mlp;
    use crate::sim::RTX2080TI;

    fn temp_cache(tag: &str) -> PlanCache {
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_plan_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        PlanCache::open(dir).unwrap()
    }

    #[test]
    fn miss_then_hit_roundtrips() {
        let cache = temp_cache("roundtrip");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let p1 = cache.get_or_plan(&planner, &m, 32);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 0);
        let p2 = cache.get_or_plan(&planner, &m, 32);
        assert_eq!(cache.hits(), 1);
        assert_eq!(p1, p2, "cached plan must round-trip bit-exactly");
        // a different batch bucket is a different key
        let _ = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn corrupt_entry_is_a_miss() {
        let cache = temp_cache("corrupt");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let p = cache.get_or_plan(&planner, &m, 8);
        std::fs::write(cache.entry_path(&p.model, 8, &p.gpu), "{not json").unwrap();
        assert!(cache.get(&p.model, 8, &p.gpu).is_none());
        // and get_or_plan self-heals the entry
        let healed = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(healed, p);
        assert!(cache.get(&p.model, 8, &p.gpu).is_some());
    }

    #[test]
    fn stale_scheme_set_is_a_miss_and_self_heals() {
        // a plan cached before a new backend registered must not pin
        // its old winners: the scheme-set mismatch forces a re-plan
        let cache = temp_cache("stale_schemes");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let fresh = cache.get_or_plan(&planner, &m, 8);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // simulate an entry written when one backend fewer existed
        let mut stale = fresh.clone();
        stale.scheme_set.pop();
        cache.put(&stale).unwrap();
        let replanned = cache.get_or_plan(&planner, &m, 8);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert_eq!(replanned, fresh, "re-plan restores the full-set plan");
        // the entry self-healed: next lookup is a hit again
        let again = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(cache.hits(), 1);
        assert_eq!(again, fresh);
    }

    #[test]
    fn old_schema_entry_is_a_miss() {
        let cache = temp_cache("old_schema");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let p = cache.get_or_plan(&planner, &m, 8);
        // rewrite the entry claiming an older document version — a v3
        // (pre-layout) plan never chose layout edges, so it must be a
        // miss even if everything else matches
        let old = p.to_json().replace("\"schema\":5", "\"schema\":4");
        std::fs::write(cache.entry_path(&p.model, 8, &p.gpu), old).unwrap();
        assert!(cache.get(&p.model, 8, &p.gpu).is_none());
        let healed = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(healed, p);
    }

    #[test]
    fn stale_sparsity_fingerprint_is_a_miss_and_self_heals() {
        // a GCN plan cached for one adjacency density must re-plan when
        // the graph changes: the sparse-vs-dense crossover it ranked no
        // longer applies
        let cache = temp_cache("stale_sparsity");
        let planner = Planner::new(&RTX2080TI);
        let m = crate::nn::model::gcn_grid();
        let fresh = cache.get_or_plan(&planner, &m, 8);
        assert_ne!(fresh.sparsity, "dense");
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // simulate an entry persisted for a differently-seeded graph
        let mut stale = fresh.clone();
        stale.sparsity = stale.sparsity.replace("-s0:", "-s9:");
        assert_ne!(stale.sparsity, fresh.sparsity);
        cache.put(&stale).unwrap();
        let replanned = cache.get_or_plan(&planner, &m, 8);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert_eq!(replanned, fresh, "re-plan restores the live-graph plan");
        // the entry self-healed: next lookup is a hit again
        let again = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(cache.hits(), 1);
        assert_eq!(again, fresh);
    }

    #[test]
    fn stale_cost_profile_is_a_miss_and_self_heals() {
        // a plan cached under one calibration profile must not survive a
        // profile change: the entry's winners were ranked by costs the
        // serving planner no longer uses
        let cache = temp_cache("stale_profile");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let fresh = cache.get_or_plan(&planner, &m, 8);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // simulate an entry planned under a (now replaced) calibration
        let mut stale = fresh.clone();
        stale.cost_profile = "cal1-00000000deadbeef".to_string();
        cache.put(&stale).unwrap();
        let replanned = cache.get_or_plan(&planner, &m, 8);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert_eq!(replanned, fresh, "re-plan restores the analytic-profile plan");
        // the entry self-healed: next lookup is a hit again
        let again = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(cache.hits(), 1);
        assert_eq!(again, fresh);
    }

    #[test]
    fn calibrated_and_analytic_planners_do_not_share_entries() {
        use crate::tuner::{
            CalibrationProfile, CostSource, HostFingerprint, SchemeCoeffs,
        };
        use std::sync::Arc;

        let cache = temp_cache("profile_split");
        let analytic = Planner::new(&RTX2080TI);
        let profile = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(
                crate::kernels::backend::BackendRegistry::global(),
            ),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
            repacks: Vec::new(),
        });
        let calibrated = Planner::new(&RTX2080TI)
            .with_cost_source(CostSource::Calibrated(Arc::clone(&profile)));
        let m = mnist_mlp();
        let _ = cache.get_or_plan(&analytic, &m, 8);
        // the calibrated planner sees the analytic entry as stale
        let cal_plan = cache.get_or_plan(&calibrated, &m, 8);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert_eq!(cal_plan.cost_profile, profile.id());
        // ... and its re-persisted entry now hits for the calibrated
        // planner but is stale again for the analytic one
        assert!(cache
            .get_for(
                m.name,
                8,
                calibrated.gpu.name,
                &calibrated.scheme_names(),
                &calibrated.cost_profile_id(),
            )
            .is_some());
        assert!(cache.get(m.name, 8, analytic.gpu.name).is_none());
    }

    #[test]
    fn profile_path_sits_next_to_the_entries() {
        let cache = temp_cache("profile_path");
        let p = cache.profile_path();
        assert_eq!(p.file_name().unwrap(), "calibration.profile.json");
        assert_eq!(p.parent().unwrap(), cache.entry_path("m", 8, "g").parent().unwrap());
    }
}
