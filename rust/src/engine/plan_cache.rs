//! Persistent JSON plan cache keyed by (model, batch shape, gpu).
//!
//! Planning is cheap but not free (six scheme simulations per layer);
//! serving stacks restart often and re-plan the same shapes.  The cache
//! stores one JSON document per key under a directory and counts
//! hits/misses so benches can report cache effectiveness.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::nn::ModelDef;

use super::plan::ModelPlan;
use super::planner::Planner;

/// A directory of `*.plan.json` files + hit/miss counters.
pub struct PlanCache {
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl AsRef<Path>) -> std::io::Result<PlanCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(PlanCache { dir, hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    /// Path of the entry for a key.
    pub fn entry_path(&self, model: &str, batch: usize, gpu: &str) -> PathBuf {
        self.dir.join(ModelPlan::cache_file(model, batch, gpu))
    }

    /// Read + validate an entry without touching the counters.
    fn read(&self, model: &str, batch: usize, gpu: &str) -> Option<ModelPlan> {
        let path = self.entry_path(model, batch, gpu);
        std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| ModelPlan::from_json(&text).ok())
            .filter(|p| p.model == model && p.batch == batch && p.gpu == gpu)
    }

    /// Look up a cached plan.  A missing or malformed entry counts as a
    /// miss.  (Callers with the live `ModelDef` should prefer
    /// `get_or_plan`, which additionally rejects stale entries whose
    /// layer tags drifted — those count as misses there too.)
    pub fn get(&self, model: &str, batch: usize, gpu: &str) -> Option<ModelPlan> {
        match self.read(model, batch, gpu) {
            Some(p) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(p)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a plan (overwrites any existing entry for its key).
    pub fn put(&self, plan: &ModelPlan) -> std::io::Result<PathBuf> {
        let path = self.entry_path(&plan.model, plan.batch, &plan.gpu);
        std::fs::write(&path, plan.to_json())?;
        Ok(path)
    }

    /// Cached plan, or plan now and persist.  A failed write is not
    /// fatal (the fresh plan is still returned); it will simply re-plan
    /// next time.
    pub fn get_or_plan(
        &self,
        planner: &Planner,
        model: &ModelDef,
        batch: usize,
    ) -> ModelPlan {
        if let Some(p) = self.read(model.name, batch, planner.gpu.name) {
            // validate against the live model definition; shape drift
            // (e.g. a renamed layer) is a MISS that falls back to fresh
            // planning (and re-persists below, self-healing the entry)
            let tags_match = p.layers.len() == model.layers.len()
                && p.layers.iter().zip(&model.layers).all(|(lp, l)| lp.tag == l.tag());
            if tags_match {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return p;
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = planner.plan(model, batch);
        if let Err(e) = self.put(&plan) {
            eprintln!(
                "plan cache: could not persist {}/b{}: {e}",
                plan.model, plan.batch
            );
        }
        plan
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::mnist_mlp;
    use crate::sim::RTX2080TI;

    fn temp_cache(tag: &str) -> PlanCache {
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_plan_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        PlanCache::open(dir).unwrap()
    }

    #[test]
    fn miss_then_hit_roundtrips() {
        let cache = temp_cache("roundtrip");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let p1 = cache.get_or_plan(&planner, &m, 32);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 0);
        let p2 = cache.get_or_plan(&planner, &m, 32);
        assert_eq!(cache.hits(), 1);
        assert_eq!(p1, p2, "cached plan must round-trip bit-exactly");
        // a different batch bucket is a different key
        let _ = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn corrupt_entry_is_a_miss() {
        let cache = temp_cache("corrupt");
        let planner = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let p = cache.get_or_plan(&planner, &m, 8);
        std::fs::write(cache.entry_path(&p.model, 8, &p.gpu), "{not json").unwrap();
        assert!(cache.get(&p.model, 8, &p.gpu).is_none());
        // and get_or_plan self-heals the entry
        let healed = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(healed, p);
        assert!(cache.get(&p.model, 8, &p.gpu).is_some());
    }
}
