//! `EngineModel`: the bridge from the arena executor to the serving
//! stack — any Table-5 BNN model becomes a `coordinator::server`
//! `BatchModel`, with executor throughput surfaced through
//! `coordinator::metrics`.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::server::BatchModel;
use crate::coordinator::Metrics;
use crate::nn::forward::ModelWeights;
use crate::nn::ModelDef;

use super::executor::EngineExecutor;
use super::plan_cache::PlanCache;
use super::planner::Planner;

/// A served engine-backed model.
pub struct EngineModel {
    exec: EngineExecutor,
    buckets: Vec<usize>,
    row_elems: usize,
    out_elems: usize,
    /// executor-side metrics (images/sec over busy time); the serving
    /// `InferenceServer` keeps its own end-to-end metrics
    pub metrics: Arc<Metrics>,
}

impl EngineModel {
    /// Build from an explicit plan-per-max-bucket: plans (or fetches
    /// from `cache`) at the largest bucket, which also sizes the arena.
    pub fn new(
        planner: &Planner,
        model: &ModelDef,
        weights: &ModelWeights,
        buckets: Vec<usize>,
        cache: Option<&PlanCache>,
    ) -> Result<EngineModel> {
        let max_bucket = validate_buckets(&buckets)?;
        let plan = match cache {
            Some(c) => c.get_or_plan(planner, model, max_bucket),
            None => planner.plan(model, max_bucket),
        };
        EngineModel::from_plan(model, weights, buckets, plan)
    }

    /// Build with every layer pinned to `scheme` — e.g.
    /// `Scheme::Fastpath` to serve the blocked-u64 host backend on a
    /// machine without a Turing GPU.
    pub fn new_fixed(
        planner: &Planner,
        model: &ModelDef,
        weights: &ModelWeights,
        buckets: Vec<usize>,
        scheme: crate::nn::Scheme,
    ) -> Result<EngineModel> {
        let max_bucket = validate_buckets(&buckets)?;
        let plan = planner.plan_fixed(model, max_bucket, scheme);
        EngineModel::from_plan(model, weights, buckets, plan)
    }

    /// Build from an explicit plan (sized for the largest bucket).
    fn from_plan(
        model: &ModelDef,
        weights: &ModelWeights,
        buckets: Vec<usize>,
        plan: super::plan::ModelPlan,
    ) -> Result<EngineModel> {
        let row_elems = model.input.flat();
        let out_elems = model.classes;
        let exec = EngineExecutor::new(model.clone(), weights, plan)?;
        Ok(EngineModel {
            exec,
            buckets,
            row_elems,
            out_elems,
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Share the metrics sink (e.g. to read images/sec from outside the
    /// server worker thread).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn plan(&self) -> &super::plan::ModelPlan {
        self.exec.plan()
    }

    pub fn arena_bytes(&self) -> usize {
        self.exec.arena_bytes()
    }
}

/// Shared bucket invariants; returns the largest bucket (which sizes
/// the arena).
fn validate_buckets(buckets: &[usize]) -> Result<usize> {
    ensure!(!buckets.is_empty(), "need at least one batch bucket");
    ensure!(
        buckets.windows(2).all(|w| w[0] < w[1]),
        "buckets must be ascending"
    );
    ensure!(
        buckets.iter().all(|b| b % 8 == 0),
        "buckets must be multiples of 8 (bit-tensor-core batch unit)"
    );
    Ok(*buckets.last().unwrap())
}

impl BatchModel for EngineModel {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
        ensure!(
            self.buckets.contains(&padded),
            "batch {padded} is not a configured bucket"
        );
        let t0 = Instant::now();
        let logits = self.exec.forward(data, padded);
        let out = logits.to_vec();
        self.metrics
            .record_engine_batch(padded, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    fn row_elems(&self) -> usize {
        self.row_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::forward::random_weights;
    use crate::nn::model::mnist_mlp;
    use crate::sim::RTX2080TI;
    use crate::util::Rng;

    #[test]
    fn runs_every_bucket() {
        let m = mnist_mlp();
        let mut rng = Rng::new(3);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let mut em =
            EngineModel::new(&planner, &m, &w, vec![8, 32], None).unwrap();
        assert_eq!(em.row_elems(), 784);
        assert_eq!(em.out_elems(), 10);
        for b in em.buckets() {
            let x: Vec<f32> = (0..b * 784).map(|_| rng.next_f32() - 0.5).collect();
            let out = em.run_batch(&x, b).unwrap();
            assert_eq!(out.len(), b * 10);
        }
        assert_eq!(em.metrics.engine_rows(), 8 + 32);
        assert!(em.metrics.engine_images_per_sec() > 0.0);
        // not a bucket -> refused
        let x: Vec<f32> = (0..16 * 784).map(|_| 0.0).collect();
        assert!(em.run_batch(&x, 16).is_err());
    }

    #[test]
    fn bucket_validation() {
        let m = mnist_mlp();
        let mut rng = Rng::new(4);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        assert!(EngineModel::new(&planner, &m, &w, vec![], None).is_err());
        assert!(EngineModel::new(&planner, &m, &w, vec![32, 8], None).is_err());
        assert!(EngineModel::new(&planner, &m, &w, vec![12], None).is_err());
    }
}
