//! `EngineModel`: the bridge from the arena executor to the serving
//! stack — any Table-5 BNN model becomes a `coordinator::server`
//! `BatchModel`, with executor throughput surfaced through
//! `coordinator::metrics`.
//!
//! Construction goes through [`EngineModel::builder`] with a
//! [`PlanPolicy`] — `Search` (per-layer cost search over the planner's
//! registry), `Fixed(scheme)` (pin one scheme everywhere, e.g.
//! `Scheme::Fastpath` on a GPU-less host), or `Cached` (consult a
//! [`PlanCache`], search on miss).  The executor is built against the
//! planner's registry, so custom backends serve end to end with no
//! changes here.  (The old `EngineModel::new` / `new_fixed`
//! constructors collapsed into this builder.)
//!
//! A planner with `CostSource::Live` turns the served model into a
//! closed loop: the executor records per-layer measured latencies into
//! the source's [`LiveCosts`](crate::tuner::LiveCosts) sink, the drift
//! snapshot is published through `Metrics`, and when a scheme in the
//! active plan drifts past the threshold (default 2x, either
//! direction) the model re-plans against the now-corrected costs and
//! rebuilds its executor in place — outputs stay bit-identical across
//! re-plans because every backend computes the same exact integer
//! math.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::server::BatchModel;
use crate::coordinator::Metrics;
use crate::nn::forward::ModelWeights;
use crate::nn::{ModelDef, Scheme};
use crate::sim::Engine;
use crate::tuner::{CalibrationProfile, CostSource, LiveCosts};

use super::executor::EngineExecutor;
use super::plan::ModelPlan;
use super::plan_cache::PlanCache;
use super::planner::Planner;

/// How the builder obtains the model's execution plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanPolicy {
    /// Per-layer cost search over every backend in the planner's
    /// registry (the default).
    Search,
    /// Pin every layer to one scheme — e.g. `Scheme::Fastpath` to
    /// serve the blocked-u64 host backend on a machine without a
    /// Turing GPU.
    Fixed(Scheme),
    /// Look the plan up in the builder's [`PlanCache`] (search + persist
    /// on miss).  Requires [`EngineModelBuilder::cache`].
    Cached,
}

/// A served engine-backed model.
pub struct EngineModel {
    exec: EngineExecutor,
    buckets: Vec<usize>,
    row_elems: usize,
    out_elems: usize,
    /// executor-side metrics (images/sec over busy time); the serving
    /// `InferenceServer` keeps its own end-to-end metrics
    pub metrics: Arc<Metrics>,
    /// live-feedback state (present iff the planner's cost source is
    /// `CostSource::Live`)
    replan: Option<ReplanState>,
}

/// Everything a live re-plan needs: the model can rebuild its executor
/// without the builder's borrows.
struct ReplanState {
    planner: Planner,
    model: ModelDef,
    weights: ModelWeights,
    live: Arc<LiveCosts>,
    /// the builder's plan policy: a `Fixed(..)` pin is honored — drift
    /// is still measured and published, but never re-plans away from
    /// the operator's pinned scheme
    policy: PlanPolicy,
    drift_threshold: f64,
    /// samples a scheme needs before its drift counts (EWMA warmup)
    min_samples: u64,
    batches: u64,
    /// batch index before which no re-plan attempt happens (backoff
    /// after an attempt, so a persistent uniform drift does not re-plan
    /// every batch)
    next_attempt: u64,
}

/// Builder for [`EngineModel`] — see [`PlanPolicy`].
pub struct EngineModelBuilder<'a> {
    planner: &'a Planner,
    model: &'a ModelDef,
    weights: &'a ModelWeights,
    buckets: Vec<usize>,
    policy: PlanPolicy,
    cache: Option<&'a PlanCache>,
    drift_threshold: f64,
}

impl<'a> EngineModelBuilder<'a> {
    /// Batch buckets the served model accepts (ascending multiples of
    /// 8); the largest sizes the arena.  Required.
    pub fn buckets(mut self, buckets: Vec<usize>) -> Self {
        self.buckets = buckets;
        self
    }

    /// The plan policy (default [`PlanPolicy::Search`]).
    pub fn policy(mut self, policy: PlanPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Attach a plan cache (required for [`PlanPolicy::Cached`]).
    pub fn cache(mut self, cache: &'a PlanCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Override the live re-plan drift threshold (default 2.0: re-plan
    /// when a scheme's measured cost is over 2x — or under half — its
    /// prediction).  Only meaningful with a `CostSource::Live` planner.
    pub fn drift_threshold(mut self, threshold: f64) -> Self {
        self.drift_threshold = threshold.max(1.0);
        self
    }

    /// Plan per the policy and build the executor + metrics sink.
    pub fn build(self) -> Result<EngineModel> {
        let metrics = Arc::new(Metrics::new());
        let max_bucket = validate_buckets(&self.buckets)?;
        let plan = match self.policy {
            PlanPolicy::Search => self.planner.plan(self.model, max_bucket),
            PlanPolicy::Fixed(scheme) => {
                // surface a bad configuration as a build Result instead
                // of reaching plan_fixed's panic (which would kill a
                // serving worker running this builder in its factory)
                ensure!(
                    self.planner.registry().get(scheme).is_some(),
                    "PlanPolicy::Fixed({}): scheme has no backend in the \
                     planner's registry",
                    scheme.name()
                );
                self.planner.plan_fixed(self.model, max_bucket, scheme)
            }
            PlanPolicy::Cached => {
                let cache =
                    self.cache.context("PlanPolicy::Cached requires .cache(..)")?;
                let plan = cache.get_or_plan(self.planner, self.model, max_bucket);
                // satellite: the cache counts hits/misses — surface them
                metrics.record_plan_cache(cache.hits(), cache.misses());
                plan
            }
        };
        let row_elems = self.model.input.flat();
        let out_elems = self.model.classes;
        let mut exec = EngineExecutor::with_registry(
            self.model.clone(),
            self.weights,
            plan,
            self.planner.registry(),
        )?;
        // a Live cost source closes the feedback loop: the executor
        // feeds the sink, and the model re-plans on drift
        let live = self.planner.cost_source().live_handle();
        if let Some(l) = &live {
            // record ratios against the ratio-free prior, never the
            // live-blended plan secs (which already contain the EWMA:
            // feeding them back would converge on sqrt(true drift))
            let baselines = live_baselines(self.planner, self.model, exec.plan());
            exec = exec
                .with_latency_sink(Arc::clone(l))
                .with_latency_baselines(baselines);
        }
        let replan = live.map(|live| ReplanState {
            planner: self.planner.clone(),
            model: self.model.clone(),
            weights: self.weights.clone(),
            live,
            policy: self.policy,
            drift_threshold: self.drift_threshold,
            min_samples: 2,
            batches: 0,
            next_attempt: 0,
        });
        Ok(EngineModel {
            exec,
            buckets: self.buckets,
            row_elems,
            out_elems,
            metrics,
            replan,
        })
    }
}

impl EngineModel {
    /// Start building a served model (see [`EngineModelBuilder`]).
    pub fn builder<'a>(
        planner: &'a Planner,
        model: &'a ModelDef,
        weights: &'a ModelWeights,
    ) -> EngineModelBuilder<'a> {
        EngineModelBuilder {
            planner,
            model,
            weights,
            buckets: Vec::new(),
            policy: PlanPolicy::Search,
            cache: None,
            drift_threshold: 2.0,
        }
    }

    /// Share the metrics sink (e.g. to read images/sec from outside the
    /// server worker thread).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn plan(&self) -> &super::plan::ModelPlan {
        self.exec.plan()
    }

    pub fn arena_bytes(&self) -> usize {
        self.exec.arena_bytes()
    }

    /// The prior `CalibrationProfile` corrected by the live loop's
    /// converged EWMA ratios — `None` when the planner has no
    /// `CostSource::Live` source or no scheme has enough samples yet.
    /// The corrected profile's content id differs from the prior's, so
    /// persisting it (see [`EngineModel::shutdown`]) invalidates every
    /// cached plan priced under the stale prior: the next start begins
    /// corrected and re-plans immediately.
    pub fn converged_profile(&self) -> Option<CalibrationProfile> {
        let st = self.replan.as_ref()?;
        let CostSource::Live { prior, live } = st.planner.cost_source() else {
            return None;
        };
        let ratios: Vec<(String, f64)> = live
            .snapshot()
            .into_iter()
            .filter(|(_, _, samples)| *samples >= st.min_samples)
            .map(|(name, ratio, _)| (name.to_string(), ratio))
            .collect();
        if ratios.is_empty() {
            return None;
        }
        let corrected = prior.scaled_by(&ratios);
        // nothing the profile covers drifted -> nothing to persist
        (corrected != **prior).then_some(corrected)
    }

    /// Clean shutdown (ROADMAP tuner follow-up): persist the
    /// live-converged profile next to the plan cache
    /// (`PlanCache::profile_path`) so the next serving process starts
    /// from corrected costs.  Returns the persisted profile's id, or
    /// `None` when there was nothing to persist (not a Live model, no
    /// converged samples, or no drift recorded against the prior).
    pub fn shutdown(self, cache: &super::plan_cache::PlanCache) -> Result<Option<String>> {
        match self.converged_profile() {
            Some(p) => {
                p.save(cache.profile_path()).with_context(|| {
                    format!("persist converged profile {:?}", cache.profile_path())
                })?;
                Ok(Some(p.id()))
            }
            None => Ok(None),
        }
    }

    /// After each batch under a `CostSource::Live` planner: publish the
    /// drift snapshot and, when a scheme in the active plan has drifted
    /// past the threshold, re-plan against the corrected costs and
    /// rebuild the executor in place.  Outputs are bit-identical across
    /// re-plans (every backend computes the same exact integer math),
    /// so a swap mid-serving is invisible except in latency.
    fn maybe_replan(&mut self) {
        let Some(st) = self.replan.as_mut() else { return };
        st.batches += 1;
        self.metrics.set_cost_drift(
            st.live
                .snapshot()
                .into_iter()
                .map(|(n, r, s)| (n.to_string(), r, s))
                .collect(),
        );
        if st.batches < st.next_attempt {
            return;
        }
        // an operator-pinned scheme is never re-planned away: the
        // drift stays visible in the metrics, the pin stands
        if matches!(st.policy, PlanPolicy::Fixed(_)) {
            return;
        }
        let drifted = self.exec.plan().layers.iter().any(|lp| {
            st.live.samples(lp.scheme) >= st.min_samples
                && st.live.drift(lp.scheme) > st.drift_threshold
        });
        if !drifted {
            return;
        }
        // back off either way: planning is cheap but not free, and a
        // uniform drift (same ratio everywhere) re-plans onto the same
        // schemes repeatedly
        st.next_attempt = st.batches + 8;
        let new_plan = st.planner.plan(&st.model, self.exec.batch_capacity());
        // a re-plan is only worth an executor rebuild when the scheme
        // mix OR the layout edges actually changed
        let same_routing = new_plan.layers.len() == self.exec.plan().layers.len()
            && new_plan
                .layers
                .iter()
                .zip(&self.exec.plan().layers)
                .all(|(a, b)| {
                    a.scheme == b.scheme
                        && a.in_layout == b.in_layout
                        && a.out_layout == b.out_layout
                });
        if same_routing {
            return;
        }
        let baselines = live_baselines(&st.planner, &st.model, &new_plan);
        match EngineExecutor::with_registry(
            st.model.clone(),
            &st.weights,
            new_plan,
            st.planner.registry(),
        ) {
            Ok(exec) => {
                self.exec = exec
                    .with_latency_sink(Arc::clone(&st.live))
                    .with_latency_baselines(baselines);
                self.metrics.record_replan();
            }
            // keep serving on the old plan; the drift stays visible in
            // the metrics and the next attempt may succeed
            Err(e) => eprintln!("engine live re-plan failed (plan kept): {e:#}"),
        }
    }
}

/// The ratio-free per-layer baseline predictions of `plan` at its
/// batch capacity (`CostSource::prior_layer_secs` of each planned
/// layer's backend) — what the executor's latency sink records
/// measured ratios against.
///
/// The baselines mirror the planner's layout accounting: a layer fed
/// its native (chained) layout skips the internal conversion its cost
/// face prices, and a layer behind an explicit repack edge pays that
/// conversion inside its timed region — pricing neither would make
/// layout choices read as per-scheme cost drift and leak into the
/// EWMA (and from there into [`EngineModel::converged_profile`]).
fn live_baselines(planner: &Planner, model: &ModelDef, plan: &ModelPlan) -> Vec<f64> {
    let engine = Engine::new(&planner.gpu);
    let mut dims = model.input;
    let mut out = Vec::with_capacity(plan.layers.len());
    for (lp, l) in plan.layers.iter().zip(&model.layers) {
        let backend = planner
            .registry()
            .get(lp.scheme)
            .expect("planned scheme has a registered backend");
        let raw = planner.cost_source().prior_layer_secs(
            backend,
            &engine,
            l,
            dims,
            plan.batch,
            planner.residual,
            model.residual_blocks > 0,
        );
        let discount = planner.native_discount(
            backend,
            l,
            dims.flat(),
            plan.batch,
            lp.in_layout,
            raw,
        );
        out.push(raw - discount);
        dims = dims.after(l);
    }
    // explicit repack ops execute inside the consuming layer's timed
    // region, so their (ratio-free) prior cost belongs in its baseline
    for r in &plan.repacks {
        if let Some(slot) = out.get_mut(r.layer) {
            *slot += planner.cost_source().repack_secs(r.src, r.dst, r.bytes);
        }
    }
    out
}

/// Shared bucket invariants; returns the largest bucket (which sizes
/// the arena).
fn validate_buckets(buckets: &[usize]) -> Result<usize> {
    ensure!(!buckets.is_empty(), "need at least one batch bucket");
    ensure!(
        buckets.windows(2).all(|w| w[0] < w[1]),
        "buckets must be ascending"
    );
    ensure!(
        buckets.iter().all(|b| b % 8 == 0),
        "buckets must be multiples of 8 (bit-tensor-core batch unit)"
    );
    Ok(*buckets.last().unwrap())
}

impl BatchModel for EngineModel {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
        ensure!(
            self.buckets.contains(&padded),
            "batch {padded} is not a configured bucket"
        );
        let t0 = Instant::now();
        let logits = self.exec.forward(data, padded);
        let out = logits.to_vec();
        self.metrics
            .record_engine_batch(padded, t0.elapsed().as_secs_f64());
        // surface the executor's explicit layout-repack counters —
        // unconditionally, so a re-plan onto an edge-free plan resets
        // the published snapshot instead of pinning the stale one
        self.metrics.set_repacks(
            self.exec
                .repack_stats()
                .into_iter()
                .map(|(name, ops, bytes)| (name.to_string(), ops, bytes))
                .collect(),
        );
        // ...and the per-layer / per-edge attribution for obs snapshots
        self.metrics.set_layer_attribution(self.exec.layer_attribution());
        self.metrics.set_repack_edges(
            self.exec
                .repack_edges()
                .iter()
                .map(|e| crate::obs::RepackEdge {
                    layer: e.layer,
                    src: e.src.to_string(),
                    dst: e.dst.to_string(),
                    ops: e.ops,
                    bytes: e.bytes,
                    secs: e.secs,
                })
                .collect(),
        );
        self.maybe_replan();
        Ok(out)
    }

    fn row_elems(&self) -> usize {
        self.row_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn layer_spans(&self) -> Vec<crate::obs::Span> {
        self.exec.last_pass_spans()
    }

    fn obs_snapshot(&self) -> Option<crate::obs::Snapshot> {
        Some(self.metrics.snapshot())
    }

    fn replans(&self) -> u64 {
        self.metrics.replans()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::forward::random_weights;
    use crate::nn::model::mnist_mlp;
    use crate::sim::RTX2080TI;
    use crate::util::Rng;

    #[test]
    fn runs_every_bucket() {
        let m = mnist_mlp();
        let mut rng = Rng::new(3);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let mut em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8, 32])
            .build()
            .unwrap();
        assert_eq!(em.row_elems(), 784);
        assert_eq!(em.out_elems(), 10);
        for b in em.buckets() {
            let x: Vec<f32> = (0..b * 784).map(|_| rng.next_f32() - 0.5).collect();
            let out = em.run_batch(&x, b).unwrap();
            assert_eq!(out.len(), b * 10);
        }
        assert_eq!(em.metrics.engine_rows(), 8 + 32);
        assert!(em.metrics.engine_images_per_sec() > 0.0);
        // not a bucket -> refused
        let x: Vec<f32> = (0..16 * 784).map(|_| 0.0).collect();
        assert!(em.run_batch(&x, 16).is_err());
    }

    #[test]
    fn fixed_policy_pins_the_scheme() {
        let m = mnist_mlp();
        let mut rng = Rng::new(5);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Fixed(Scheme::Fastpath))
            .build()
            .unwrap();
        for lp in &em.plan().layers {
            assert_eq!(lp.scheme, Scheme::Fastpath);
        }
    }

    #[test]
    fn fixed_policy_rejects_unregistered_scheme() {
        let m = mnist_mlp();
        let mut rng = Rng::new(7);
        let w = random_weights(&m, &mut rng);
        let mut reg = crate::kernels::backend::BackendRegistry::empty();
        reg.register(Box::new(
            crate::kernels::backends::fastpath::FastpathBackend,
        ));
        let planner = Planner::with_registry(&RTX2080TI, std::sync::Arc::new(reg));
        let err = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Fixed(Scheme::Btc))
            .build()
            .err()
            .expect("unregistered fixed scheme must be a build error, not a panic");
        assert!(format!("{err:#}").contains("no backend"), "{err:#}");
    }

    #[test]
    fn cached_policy_surfaces_plan_cache_counters_in_metrics() {
        // satellite regression: the cache counts hits/misses but never
        // exported them — the served model's metrics now carry them
        let m = mnist_mlp();
        let mut rng = Rng::new(8);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_bm_cache_metrics_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = super::PlanCache::open(&dir).unwrap();
        let em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Cached)
            .cache(&cache)
            .build()
            .unwrap();
        assert_eq!(em.metrics.plan_cache_misses(), 1, "cold build misses");
        assert_eq!(em.metrics.plan_cache_hits(), 0);
        let em2 = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Cached)
            .cache(&cache)
            .build()
            .unwrap();
        assert_eq!(em2.metrics.plan_cache_hits(), 1, "warm build hits");
        assert!(em2.metrics.report().contains("plan_cache=1h/1m"));
    }

    #[test]
    fn cached_policy_requires_a_cache() {
        let m = mnist_mlp();
        let mut rng = Rng::new(6);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let err = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Cached)
            .build()
            .err()
            .expect("no cache attached");
        assert!(format!("{err:#}").contains("cache"), "{err:#}");
    }

    #[test]
    fn live_cost_source_records_drift_and_keeps_outputs_bit_identical() {
        use crate::kernels::backend::BackendRegistry;
        use crate::tuner::{
            CalibrationProfile, CostSource, HostFingerprint, LiveCosts, SchemeCoeffs,
        };
        let m = mnist_mlp();
        let mut rng = Rng::new(77);
        let w = random_weights(&m, &mut rng);
        let prior = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
            repacks: Vec::new(),
        });
        let live = Arc::new(LiveCosts::new());
        let planner = Planner::new(&RTX2080TI)
            .with_cost_source(CostSource::Live { prior, live: Arc::clone(&live) });
        let mut em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .build()
            .unwrap();
        let x: Vec<f32> = (0..8 * 784).map(|_| rng.next_f32() - 0.5).collect();
        let first = em.run_batch(&x, 8).unwrap();
        // the loop may re-plan (simulated GPU predictions vs real CPU
        // time drift wildly) — outputs must stay bit-identical anyway
        for _ in 0..6 {
            assert_eq!(em.run_batch(&x, 8).unwrap(), first);
        }
        // the executor fed the sink and the drift surfaced in metrics
        assert!(!em.metrics.cost_drift().is_empty());
        assert!(em.metrics.report().contains("drift["));
    }

    #[test]
    fn fixed_fastpath_surfaces_repack_counters_when_edges_convert() {
        // a fastpath-pinned MLP chains Blocked64 edges (no explicit
        // conversions), so craft a model whose conv->FC boundary keeps
        // the executor counting: pin the whole model to a GPU scheme
        // but hand the classifier to the fastpath via a doctored plan
        use crate::engine::EngineExecutor;
        use crate::layout::LayoutKind;
        let m = mnist_mlp();
        let mut rng = Rng::new(91);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let mut plan = planner
            .clone()
            .with_layout_search(false)
            .plan_fixed(&m, 8, Scheme::Sbnn32);
        let last = plan.layers.len() - 1;
        plan.layers[last].scheme = Scheme::Fastpath;
        plan.layers[last].in_layout = LayoutKind::Blocked64;
        let mut exec = EngineExecutor::new(m.clone(), &w, plan).unwrap();
        let x: Vec<f32> = (0..8 * 784).map(|_| rng.next_f32() - 0.5).collect();
        let _ = exec.forward(&x, 8);
        let stats = exec.repack_stats();
        assert_eq!(stats.len(), 1, "{stats:?}");
        assert_eq!(stats[0].0, "FASTPATH");
        assert_eq!(stats[0].1, 1, "one explicit edge per pass");
        assert!(stats[0].2 > 0, "bytes counted");
        let _ = exec.forward(&x, 8);
        assert_eq!(exec.repack_stats()[0].1, 2, "counters accumulate");
    }

    #[test]
    fn live_model_persists_converged_profile_and_restart_replans() {
        // ROADMAP tuner follow-up: the live EWMA ratios are written
        // back into the profile on clean shutdown (new content id), so
        // cached plans priced under the stale prior miss immediately
        use crate::kernels::backend::BackendRegistry;
        use crate::tuner::{
            CalibrationProfile, CostSource, HostFingerprint, LiveCosts, SchemeCoeffs,
        };
        let m = mnist_mlp();
        let mut rng = Rng::new(93);
        let w = random_weights(&m, &mut rng);
        let prior = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
            repacks: Vec::new(),
        });
        let live = Arc::new(LiveCosts::new());
        let planner = Planner::new(&RTX2080TI).with_cost_source(CostSource::Live {
            prior: Arc::clone(&prior),
            live: Arc::clone(&live),
        });
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_bm_live_persist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = super::PlanCache::open(&dir).unwrap();
        // pin to the fastpath so the (calibrated) scheme is the one
        // executing — its measured/prior ratios are what converge
        let mut em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Fixed(Scheme::Fastpath))
            .build()
            .unwrap();
        // seed a cached plan under the live prior's id
        let live_plan = cache.get_or_plan(&planner, &m, 8);
        assert_eq!(live_plan.cost_profile, planner.cost_profile_id());
        let x: Vec<f32> = (0..8 * 784).map(|_| rng.next_f32() - 0.5).collect();
        for _ in 0..4 {
            let _ = em.run_batch(&x, 8).unwrap();
        }
        let converged = em.converged_profile().expect("fastpath samples recorded");
        assert_ne!(converged.id(), prior.id(), "content id must bump");
        let persisted = em.shutdown(&cache).unwrap().expect("profile persisted");
        assert_eq!(persisted, converged.id());
        let reloaded = CalibrationProfile::load(cache.profile_path()).unwrap();
        assert_eq!(reloaded.id(), converged.id());
        // a restarted process plans under the corrected profile: the
        // old live-prior entry is stale, so the cache re-plans at once
        let restarted = Planner::new(&RTX2080TI).with_cost_source(CostSource::Live {
            prior: Arc::new(reloaded),
            live: Arc::new(LiveCosts::new()),
        });
        let (h0, m0) = (cache.hits(), cache.misses());
        let replanned = cache.get_or_plan(&restarted, &m, 8);
        assert_eq!(cache.hits(), h0, "stale prior entry must not hit");
        assert_eq!(cache.misses(), m0 + 1, "restart re-plans immediately");
        assert_ne!(replanned.cost_profile, live_plan.cost_profile);
    }

    #[test]
    fn non_live_models_have_nothing_to_persist() {
        let m = mnist_mlp();
        let mut rng = Rng::new(95);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .build()
            .unwrap();
        assert!(em.converged_profile().is_none());
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_bm_nolive_persist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = super::PlanCache::open(&dir).unwrap();
        assert!(em.shutdown(&cache).unwrap().is_none());
        assert!(!cache.profile_path().exists());
    }

    #[test]
    fn run_batch_publishes_layer_attribution_and_spans() {
        let m = mnist_mlp();
        let mut rng = Rng::new(97);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let mut em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .build()
            .unwrap();
        let x: Vec<f32> = (0..8 * 784).map(|_| rng.next_f32() - 0.5).collect();
        let _ = em.run_batch(&x, 8).unwrap();
        let attr = em.metrics.layer_attribution();
        assert_eq!(attr.len(), m.layers.len(), "one entry per plan layer");
        assert!(attr.iter().all(|a| a.calls == 1));
        // the model's spans mirror the plan (one Layer span per layer)
        use crate::obs::SpanKind;
        let spans = em.layer_spans();
        let n_layers =
            spans.iter().filter(|s| s.kind == SpanKind::Layer).count();
        assert_eq!(n_layers, m.layers.len());
        // layer span seconds sum to the engine busy time within
        // tolerance (the pass is the busy time minus dispatch overhead)
        let span_s: f64 = spans
            .iter()
            .filter(|s| s.kind == SpanKind::Layer)
            .map(|s| s.secs)
            .sum();
        let snap = em.obs_snapshot().expect("engine model snapshots");
        assert!(snap.engine_busy_s > 0.0);
        assert!(
            span_s <= snap.engine_busy_s * 1.05,
            "layer spans ({span_s}) cannot exceed busy time ({})",
            snap.engine_busy_s
        );
        assert_eq!(snap.layers.len(), m.layers.len(), "snapshot carries attribution");
    }

    #[test]
    fn bucket_validation() {
        let m = mnist_mlp();
        let mut rng = Rng::new(4);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let build = |buckets: Vec<usize>| {
            EngineModel::builder(&planner, &m, &w).buckets(buckets).build()
        };
        assert!(build(vec![]).is_err());
        assert!(build(vec![32, 8]).is_err());
        assert!(build(vec![12]).is_err());
    }
}
