//! `EngineModel`: the bridge from the arena executor to the serving
//! stack — any Table-5 BNN model becomes a `coordinator::server`
//! `BatchModel`, with executor throughput surfaced through
//! `coordinator::metrics`.
//!
//! Construction goes through [`EngineModel::builder`] with a
//! [`PlanPolicy`] — `Search` (per-layer cost search over the planner's
//! registry), `Fixed(scheme)` (pin one scheme everywhere, e.g.
//! `Scheme::Fastpath` on a GPU-less host), or `Cached` (consult a
//! [`PlanCache`], search on miss).  The executor is built against the
//! planner's registry, so custom backends serve end to end with no
//! changes here.  (The old `EngineModel::new` / `new_fixed`
//! constructors collapsed into this builder.)

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::server::BatchModel;
use crate::coordinator::Metrics;
use crate::nn::forward::ModelWeights;
use crate::nn::{ModelDef, Scheme};

use super::executor::EngineExecutor;
use super::plan_cache::PlanCache;
use super::planner::Planner;

/// How the builder obtains the model's execution plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanPolicy {
    /// Per-layer cost search over every backend in the planner's
    /// registry (the default).
    Search,
    /// Pin every layer to one scheme — e.g. `Scheme::Fastpath` to
    /// serve the blocked-u64 host backend on a machine without a
    /// Turing GPU.
    Fixed(Scheme),
    /// Look the plan up in the builder's [`PlanCache`] (search + persist
    /// on miss).  Requires [`EngineModelBuilder::cache`].
    Cached,
}

/// A served engine-backed model.
pub struct EngineModel {
    exec: EngineExecutor,
    buckets: Vec<usize>,
    row_elems: usize,
    out_elems: usize,
    /// executor-side metrics (images/sec over busy time); the serving
    /// `InferenceServer` keeps its own end-to-end metrics
    pub metrics: Arc<Metrics>,
}

/// Builder for [`EngineModel`] — see [`PlanPolicy`].
pub struct EngineModelBuilder<'a> {
    planner: &'a Planner,
    model: &'a ModelDef,
    weights: &'a ModelWeights,
    buckets: Vec<usize>,
    policy: PlanPolicy,
    cache: Option<&'a PlanCache>,
}

impl<'a> EngineModelBuilder<'a> {
    /// Batch buckets the served model accepts (ascending multiples of
    /// 8); the largest sizes the arena.  Required.
    pub fn buckets(mut self, buckets: Vec<usize>) -> Self {
        self.buckets = buckets;
        self
    }

    /// The plan policy (default [`PlanPolicy::Search`]).
    pub fn policy(mut self, policy: PlanPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Attach a plan cache (required for [`PlanPolicy::Cached`]).
    pub fn cache(mut self, cache: &'a PlanCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Plan per the policy and build the executor + metrics sink.
    pub fn build(self) -> Result<EngineModel> {
        let max_bucket = validate_buckets(&self.buckets)?;
        let plan = match self.policy {
            PlanPolicy::Search => self.planner.plan(self.model, max_bucket),
            PlanPolicy::Fixed(scheme) => {
                // surface a bad configuration as a build Result instead
                // of reaching plan_fixed's panic (which would kill a
                // serving worker running this builder in its factory)
                ensure!(
                    self.planner.registry().get(scheme).is_some(),
                    "PlanPolicy::Fixed({}): scheme has no backend in the \
                     planner's registry",
                    scheme.name()
                );
                self.planner.plan_fixed(self.model, max_bucket, scheme)
            }
            PlanPolicy::Cached => self
                .cache
                .context("PlanPolicy::Cached requires .cache(..)")?
                .get_or_plan(self.planner, self.model, max_bucket),
        };
        let row_elems = self.model.input.flat();
        let out_elems = self.model.classes;
        let exec = EngineExecutor::with_registry(
            self.model.clone(),
            self.weights,
            plan,
            self.planner.registry(),
        )?;
        Ok(EngineModel {
            exec,
            buckets: self.buckets,
            row_elems,
            out_elems,
            metrics: Arc::new(Metrics::new()),
        })
    }
}

impl EngineModel {
    /// Start building a served model (see [`EngineModelBuilder`]).
    pub fn builder<'a>(
        planner: &'a Planner,
        model: &'a ModelDef,
        weights: &'a ModelWeights,
    ) -> EngineModelBuilder<'a> {
        EngineModelBuilder {
            planner,
            model,
            weights,
            buckets: Vec::new(),
            policy: PlanPolicy::Search,
            cache: None,
        }
    }

    /// Share the metrics sink (e.g. to read images/sec from outside the
    /// server worker thread).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn plan(&self) -> &super::plan::ModelPlan {
        self.exec.plan()
    }

    pub fn arena_bytes(&self) -> usize {
        self.exec.arena_bytes()
    }
}

/// Shared bucket invariants; returns the largest bucket (which sizes
/// the arena).
fn validate_buckets(buckets: &[usize]) -> Result<usize> {
    ensure!(!buckets.is_empty(), "need at least one batch bucket");
    ensure!(
        buckets.windows(2).all(|w| w[0] < w[1]),
        "buckets must be ascending"
    );
    ensure!(
        buckets.iter().all(|b| b % 8 == 0),
        "buckets must be multiples of 8 (bit-tensor-core batch unit)"
    );
    Ok(*buckets.last().unwrap())
}

impl BatchModel for EngineModel {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
        ensure!(
            self.buckets.contains(&padded),
            "batch {padded} is not a configured bucket"
        );
        let t0 = Instant::now();
        let logits = self.exec.forward(data, padded);
        let out = logits.to_vec();
        self.metrics
            .record_engine_batch(padded, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    fn row_elems(&self) -> usize {
        self.row_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::forward::random_weights;
    use crate::nn::model::mnist_mlp;
    use crate::sim::RTX2080TI;
    use crate::util::Rng;

    #[test]
    fn runs_every_bucket() {
        let m = mnist_mlp();
        let mut rng = Rng::new(3);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let mut em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8, 32])
            .build()
            .unwrap();
        assert_eq!(em.row_elems(), 784);
        assert_eq!(em.out_elems(), 10);
        for b in em.buckets() {
            let x: Vec<f32> = (0..b * 784).map(|_| rng.next_f32() - 0.5).collect();
            let out = em.run_batch(&x, b).unwrap();
            assert_eq!(out.len(), b * 10);
        }
        assert_eq!(em.metrics.engine_rows(), 8 + 32);
        assert!(em.metrics.engine_images_per_sec() > 0.0);
        // not a bucket -> refused
        let x: Vec<f32> = (0..16 * 784).map(|_| 0.0).collect();
        assert!(em.run_batch(&x, 16).is_err());
    }

    #[test]
    fn fixed_policy_pins_the_scheme() {
        let m = mnist_mlp();
        let mut rng = Rng::new(5);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let em = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Fixed(Scheme::Fastpath))
            .build()
            .unwrap();
        for lp in &em.plan().layers {
            assert_eq!(lp.scheme, Scheme::Fastpath);
        }
    }

    #[test]
    fn fixed_policy_rejects_unregistered_scheme() {
        let m = mnist_mlp();
        let mut rng = Rng::new(7);
        let w = random_weights(&m, &mut rng);
        let mut reg = crate::kernels::backend::BackendRegistry::empty();
        reg.register(Box::new(
            crate::kernels::backends::fastpath::FastpathBackend,
        ));
        let planner = Planner::with_registry(&RTX2080TI, std::sync::Arc::new(reg));
        let err = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Fixed(Scheme::Btc))
            .build()
            .err()
            .expect("unregistered fixed scheme must be a build error, not a panic");
        assert!(format!("{err:#}").contains("no backend"), "{err:#}");
    }

    #[test]
    fn cached_policy_requires_a_cache() {
        let m = mnist_mlp();
        let mut rng = Rng::new(6);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let err = EngineModel::builder(&planner, &m, &w)
            .buckets(vec![8])
            .policy(PlanPolicy::Cached)
            .build()
            .err()
            .expect("no cache attached");
        assert!(format!("{err:#}").contains("cache"), "{err:#}");
    }

    #[test]
    fn bucket_validation() {
        let m = mnist_mlp();
        let mut rng = Rng::new(4);
        let w = random_weights(&m, &mut rng);
        let planner = Planner::new(&RTX2080TI);
        let build = |buckets: Vec<usize>| {
            EngineModel::builder(&planner, &m, &w).buckets(buckets).build()
        };
        assert!(build(vec![]).is_err());
        assert!(build(vec![32, 8]).is_err());
        assert!(build(vec![12]).is_err());
    }
}
