//! Executable model plans: the planner's output, serializable to JSON
//! for the persistent plan cache.

use anyhow::{bail, Context, Result};

use crate::layout::LayoutKind;
use crate::nn::Scheme;

use super::json::Value;

/// Version of the plan JSON document.  Bump whenever the document
/// layout changes; `from_json` rejects any other version, so stale
/// cache entries degrade to a re-plan instead of silently parsing.
///
/// v2: the `KernelBackend` registry redesign — plans embed the scheme
/// set they were searched over (`schemes`), so a plan cached before a
/// new backend registered is detectably stale.
///
/// v3: the tuner's measured-calibration subsystem — plans embed the
/// `cost_profile` id of the `CostSource` they were planned under
/// (`"analytic"`, a calibration-profile digest, or `"live:<digest>"`),
/// so a plan cached under one calibration is detectably stale once the
/// active profile changes.
///
/// v4: the layout co-design subsystem — every layer carries explicit
/// layout edges (`in_layout` / `out_layout`) and the plan lists the
/// explicit repack ops the executor must materialize (`repacks`), so
/// v3 plans (which never chose layouts) are detectably stale.
///
/// v5: the sparse/GNN subsystem — plans embed a `sparsity` fingerprint
/// (`"dense"` for models with no graph layers; otherwise the joined
/// per-GCN-layer adjacency fingerprints, including stored-block
/// counts), so a plan cached for one adjacency density is detectably
/// stale once the graph changes.
pub const PLAN_SCHEMA: usize = 5;

/// One layer's planned execution: the winning scheme, the activation
/// layout edges around it, and its simulated cost on the plan's GPU.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    /// index into `ModelDef::layers`
    pub index: usize,
    /// display tag ("128C3p", "1024FC", ...) — also a consistency check
    /// when a cached plan is applied to a model definition
    pub tag: String,
    /// the scheme the planner selected for this layer
    pub scheme: Scheme,
    /// the activation layout this layer consumes (one endpoint of the
    /// incoming layout edge; when it differs from the previous layer's
    /// `out_layout` the executor materializes an explicit repack op)
    pub in_layout: LayoutKind,
    /// the layout the executor packs this layer's thresholded output
    /// into (`Row32` unless a `Blocked64` chain pays off)
    pub out_layout: LayoutKind,
    /// simulated compute seconds (excl. per-layer sync and edge
    /// repacks; includes the native-layout discount when `in_layout`
    /// is the backend's preferred form)
    pub secs: f64,
}

/// One explicit repack op the executor materializes through arena
/// scratch: converts the activation entering `layer` from `src` to
/// `dst` layout.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanRepack {
    /// index of the consuming layer (the conversion runs just before it)
    pub layer: usize,
    pub src: LayoutKind,
    pub dst: LayoutKind,
    /// streamed bytes (source image + destination image)
    pub bytes: usize,
    /// modeled conversion seconds (already included in `total_secs`)
    pub secs: f64,
}

/// A complete plan for (model, batch bucket, gpu).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPlan {
    pub model: String,
    pub dataset: String,
    pub gpu: String,
    pub batch: usize,
    pub classes: usize,
    /// the scheme names the emitting planner's registry searched, in
    /// search order.  A cached plan whose set differs from the serving
    /// registry is stale: a newly registered backend never competed
    /// for these layers, so the cache must re-plan.
    pub scheme_set: Vec<String>,
    /// the id of the cost source the plan was searched under
    /// (`Planner::cost_profile_id`): `"analytic"` for the backends' own
    /// cost faces, a `CalibrationProfile` digest for a fitted per-host
    /// profile, `"live:<digest>"` for the live blend.  A cached plan
    /// whose id differs from the serving planner's is stale: its
    /// winners were ranked by different costs.
    pub cost_profile: String,
    /// sparsity fingerprint of the model the plan was searched for:
    /// `"dense"` when no layer carries a graph adjacency, otherwise
    /// the comma-joined `sparse::layer_fingerprint` of every GCN layer
    /// (adjacency spec tag, node count, stored-block count).  A cached
    /// plan whose fingerprint differs from the serving model's is
    /// stale: its sparse-vs-dense crossover was ranked for a different
    /// density.
    pub sparsity: String,
    pub layers: Vec<LayerPlan>,
    /// explicit layout conversions along layer edges (empty when every
    /// edge's layouts already agree)
    pub repacks: Vec<PlanRepack>,
    /// simulated end-to-end seconds (launch + per-layer compute + sync
    /// + edge repacks), directly comparable to
    /// `nn::cost::model_cost(...).total_secs`
    pub total_secs: f64,
}

impl ModelPlan {
    /// Simulated images/second at this plan's batch.
    pub fn throughput_fps(&self) -> f64 {
        self.batch as f64 / self.total_secs
    }

    /// The filename this plan lives under in a plan cache — the cache
    /// key is exactly (model, batch shape, gpu).
    pub fn cache_file(model: &str, batch: usize, gpu: &str) -> String {
        let sane = |s: &str| -> String {
            s.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' {
                    c
                } else {
                    '_'
                })
                .collect()
        };
        format!("{}_b{batch}_{}.plan.json", sane(model), sane(gpu))
    }

    /// Serialize to the plan-cache JSON document.
    pub fn to_json(&self) -> String {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                Value::Obj(vec![
                    ("index".to_string(), Value::Num(l.index as f64)),
                    ("tag".to_string(), Value::Str(l.tag.clone())),
                    (
                        "scheme".to_string(),
                        Value::Str(l.scheme.name().to_string()),
                    ),
                    (
                        "in_layout".to_string(),
                        Value::Str(l.in_layout.name().to_string()),
                    ),
                    (
                        "out_layout".to_string(),
                        Value::Str(l.out_layout.name().to_string()),
                    ),
                    ("secs".to_string(), Value::Num(l.secs)),
                ])
            })
            .collect();
        let repacks: Vec<Value> = self
            .repacks
            .iter()
            .map(|r| {
                Value::Obj(vec![
                    ("layer".to_string(), Value::Num(r.layer as f64)),
                    ("src".to_string(), Value::Str(r.src.name().to_string())),
                    ("dst".to_string(), Value::Str(r.dst.name().to_string())),
                    ("bytes".to_string(), Value::Num(r.bytes as f64)),
                    ("secs".to_string(), Value::Num(r.secs)),
                ])
            })
            .collect();
        let schemes: Vec<Value> = self
            .scheme_set
            .iter()
            .map(|s| Value::Str(s.clone()))
            .collect();
        Value::Obj(vec![
            ("schema".to_string(), Value::Num(PLAN_SCHEMA as f64)),
            ("model".to_string(), Value::Str(self.model.clone())),
            ("dataset".to_string(), Value::Str(self.dataset.clone())),
            ("gpu".to_string(), Value::Str(self.gpu.clone())),
            ("batch".to_string(), Value::Num(self.batch as f64)),
            ("classes".to_string(), Value::Num(self.classes as f64)),
            ("schemes".to_string(), Value::Arr(schemes)),
            (
                "cost_profile".to_string(),
                Value::Str(self.cost_profile.clone()),
            ),
            ("sparsity".to_string(), Value::Str(self.sparsity.clone())),
            ("total_secs".to_string(), Value::Num(self.total_secs)),
            ("layers".to_string(), Value::Arr(layers)),
            ("repacks".to_string(), Value::Arr(repacks)),
        ])
        .to_string()
    }

    /// Parse a plan-cache JSON document.  Documents from any other
    /// [`PLAN_SCHEMA`] version (including pre-versioning ones without a
    /// `schema` field) are rejected — the cache treats that as a miss.
    pub fn from_json(text: &str) -> Result<ModelPlan> {
        let v = Value::parse(text).map_err(|e| anyhow::anyhow!("plan json: {e}"))?;
        let schema = v
            .get("schema")
            .and_then(Value::as_usize)
            .context("plan field \"schema\" (pre-versioning document?)")?;
        if schema != PLAN_SCHEMA {
            bail!("plan schema {schema} (this build reads {PLAN_SCHEMA}); stale entry");
        }
        let str_field = |key: &str| -> Result<String> {
            Ok(v.get(key)
                .and_then(Value::as_str)
                .with_context(|| format!("plan field {key:?}"))?
                .to_string())
        };
        let num_field = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(Value::as_usize)
                .with_context(|| format!("plan field {key:?}"))
        };
        let mut scheme_set = Vec::new();
        for (i, sv) in v
            .get("schemes")
            .and_then(Value::as_arr)
            .context("plan field \"schemes\"")?
            .iter()
            .enumerate()
        {
            scheme_set.push(
                sv.as_str()
                    .with_context(|| format!("schemes[{i}]"))?
                    .to_string(),
            );
        }
        let mut layers = Vec::new();
        for (i, lv) in v
            .get("layers")
            .and_then(Value::as_arr)
            .context("plan field \"layers\"")?
            .iter()
            .enumerate()
        {
            let scheme_name = lv
                .get("scheme")
                .and_then(Value::as_str)
                .with_context(|| format!("layer {i} scheme"))?;
            let scheme = Scheme::from_name(scheme_name)
                .map_err(|e| anyhow::anyhow!("layer {i}: {e}"))?;
            let layout_field = |key: &str| -> Result<LayoutKind> {
                let name = lv
                    .get(key)
                    .and_then(Value::as_str)
                    .with_context(|| format!("layer {i} {key}"))?;
                LayoutKind::from_name(name)
                    .map_err(|e| anyhow::anyhow!("layer {i}: {e}"))
            };
            layers.push(LayerPlan {
                index: lv
                    .get("index")
                    .and_then(Value::as_usize)
                    .with_context(|| format!("layer {i} index"))?,
                tag: lv
                    .get("tag")
                    .and_then(Value::as_str)
                    .with_context(|| format!("layer {i} tag"))?
                    .to_string(),
                scheme,
                in_layout: layout_field("in_layout")?,
                out_layout: layout_field("out_layout")?,
                secs: lv
                    .get("secs")
                    .and_then(Value::as_f64)
                    .with_context(|| format!("layer {i} secs"))?,
            });
        }
        let mut repacks = Vec::new();
        for (i, rv) in v
            .get("repacks")
            .and_then(Value::as_arr)
            .context("plan field \"repacks\"")?
            .iter()
            .enumerate()
        {
            let layout_field = |key: &str| -> Result<LayoutKind> {
                let name = rv
                    .get(key)
                    .and_then(Value::as_str)
                    .with_context(|| format!("repack {i} {key}"))?;
                LayoutKind::from_name(name)
                    .map_err(|e| anyhow::anyhow!("repack {i}: {e}"))
            };
            repacks.push(PlanRepack {
                layer: rv
                    .get("layer")
                    .and_then(Value::as_usize)
                    .with_context(|| format!("repack {i} layer"))?,
                src: layout_field("src")?,
                dst: layout_field("dst")?,
                bytes: rv
                    .get("bytes")
                    .and_then(Value::as_usize)
                    .with_context(|| format!("repack {i} bytes"))?,
                secs: rv
                    .get("secs")
                    .and_then(Value::as_f64)
                    .with_context(|| format!("repack {i} secs"))?,
            });
        }
        Ok(ModelPlan {
            model: str_field("model")?,
            dataset: str_field("dataset")?,
            gpu: str_field("gpu")?,
            batch: num_field("batch")?,
            classes: num_field("classes")?,
            scheme_set,
            cost_profile: str_field("cost_profile")?,
            sparsity: str_field("sparsity")?,
            layers,
            repacks,
            total_secs: v
                .get("total_secs")
                .and_then(Value::as_f64)
                .context("plan field \"total_secs\"")?,
        })
    }

    /// Per-scheme layer counts (for reporting).
    pub fn scheme_histogram(&self) -> Vec<(&'static str, usize)> {
        let mut out: Vec<(&'static str, usize)> = Vec::new();
        for l in &self.layers {
            match out.iter_mut().find(|(n, _)| *n == l.scheme.name()) {
                Some((_, c)) => *c += 1,
                None => out.push((l.scheme.name(), 1)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ModelPlan {
        ModelPlan {
            model: "MNIST-MLP".to_string(),
            dataset: "MNIST".to_string(),
            gpu: "RTX2080Ti".to_string(),
            batch: 32,
            classes: 10,
            scheme_set: Scheme::all().iter().map(|s| s.name().to_string()).collect(),
            cost_profile: "analytic".to_string(),
            sparsity: "dense".to_string(),
            layers: vec![
                LayerPlan {
                    index: 0,
                    tag: "1024FC".to_string(),
                    scheme: Scheme::BtcFmt,
                    in_layout: LayoutKind::Row32,
                    out_layout: LayoutKind::Row32,
                    secs: 1.25e-5,
                },
                LayerPlan {
                    index: 1,
                    tag: "10out".to_string(),
                    scheme: Scheme::Fastpath,
                    in_layout: LayoutKind::Blocked64,
                    out_layout: LayoutKind::Row32,
                    secs: 3.0e-6,
                },
            ],
            repacks: vec![PlanRepack {
                layer: 1,
                src: LayoutKind::Row32,
                dst: LayoutKind::Blocked64,
                bytes: 8192,
                secs: 3.1e-6,
            }],
            total_secs: 2.05e-5,
        }
    }

    #[test]
    fn json_roundtrip_exact() {
        let p = sample();
        let back = ModelPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // a graph-model fingerprint rides the same field
        let mut gcn = sample();
        gcn.sparsity = "powerlaw-d6-s1:512n:960b,powerlaw-d6-s1:512n:960b".to_string();
        let back = ModelPlan::from_json(&gcn.to_json()).unwrap();
        assert_eq!(back.sparsity, gcn.sparsity);
    }

    #[test]
    fn rejects_unknown_scheme() {
        let text = sample().to_json().replace("BTC-FMT", "WARP-9");
        let err = ModelPlan::from_json(&text).unwrap_err();
        // the error names the valid schemes (from Scheme::from_name)
        assert!(format!("{err:#}").contains("valid schemes"), "{err:#}");
    }

    #[test]
    fn rejects_other_schema_versions() {
        let text = sample()
            .to_json()
            .replace("\"schema\":5", "\"schema\":4");
        assert!(ModelPlan::from_json(&text).is_err(), "v4 documents are stale");
        // a pre-versioning document (no schema field at all) also fails
        let legacy = sample().to_json().replace("\"schema\":5,", "");
        assert!(ModelPlan::from_json(&legacy).is_err());
        // a v4 document (no sparsity fingerprint) is unreadable even if
        // it claims schema 5
        let no_sparsity = sample()
            .to_json()
            .replace("\"sparsity\":\"dense\",", "");
        assert!(ModelPlan::from_json(&no_sparsity).is_err());
        // a v3 document (no cost_profile-era layout edges) is unreadable:
        // claiming the current schema without layout fields fails the parse
        let no_layouts = sample()
            .to_json()
            .replace("\"in_layout\":\"Row32\",", "")
            .replace("\"in_layout\":\"Blocked64\",", "");
        assert!(ModelPlan::from_json(&no_layouts).is_err());
        // ... and so does a document without the repacks list
        let no_repacks = {
            let p = sample().to_json();
            let start = p.find(",\"repacks\":").unwrap();
            let mut depth = 0usize;
            let bytes = p.as_bytes();
            let mut end = start;
            for (off, &b) in bytes.iter().enumerate().skip(start) {
                match b {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            end = off + 1;
                            break;
                        }
                    }
                    _ => {}
                }
            }
            format!("{}{}", &p[..start], &p[end..])
        };
        assert!(ModelPlan::from_json(&no_repacks).is_err());
    }

    #[test]
    fn rejects_unknown_layout_names() {
        let text = sample().to_json().replace("Blocked64", "Blocked128");
        let err = ModelPlan::from_json(&text).unwrap_err();
        assert!(format!("{err:#}").contains("valid layouts"), "{err:#}");
    }

    #[test]
    fn cache_file_is_sane() {
        let f = ModelPlan::cache_file("ImageNet-ResNet18", 128, "RTX2080Ti");
        assert_eq!(f, "ImageNet-ResNet18_b128_RTX2080Ti.plan.json");
        let odd = ModelPlan::cache_file("a b/c", 8, "g pu");
        assert!(!odd.contains(' ') && !odd.contains('/'));
    }

    #[test]
    fn histogram_counts() {
        let h = sample().scheme_histogram();
        assert_eq!(h, vec![("BTC-FMT", 1), ("FASTPATH", 1)]);
    }
}
