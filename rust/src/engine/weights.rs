//! Model-weight persistence: `nn::forward::ModelWeights` <-> the flat
//! blob format (`*.bin` + `*.meta`) the runtime already speaks.
//!
//! Naming convention, one prefix per layer index:
//!
//! ```text
//!   l{i}_w       f32  k*k*c*o      first-conv +/-1 filter (KKCO order)
//!   l{i}_thresh  f32  o            fused thresholds
//!   l{i}_filter  u32  packed words binarized conv filter (KKOC packed C)
//!   l{i}_wbits   u32  packed words fc weight rows (d_out x d_in bits)
//!   l{i}_gamma   f32  d_out        classifier bn scale
//!   l{i}_beta    f32  d_out        classifier bn shift
//! ```

use anyhow::{bail, ensure, Result};

use crate::bitops::{BitMatrix, BitTensor4, Layout, TensorLayout};
use crate::nn::forward::{LayerWeights, ModelWeights};
use crate::nn::layer::LayerSpec;
use crate::nn::ModelDef;
use crate::runtime::{Blob, BlobWriter};

/// Serialize weights into a `BlobWriter` (call `.write(base)` after).
pub fn weights_to_blob(model: &ModelDef, weights: &ModelWeights) -> Result<BlobWriter> {
    ensure!(
        weights.layers.len() == model.layers.len(),
        "weights/model layer count mismatch"
    );
    let mut w = BlobWriter::new();
    for (i, (l, lw)) in model.layers.iter().zip(&weights.layers).enumerate() {
        match (l, lw) {
            (LayerSpec::FirstConv { .. }, LayerWeights::FirstConv { w_pm1, thresh }) => {
                w.push_f32(&format!("l{i}_w"), &[w_pm1.len()], w_pm1);
                w.push_f32(&format!("l{i}_thresh"), &[thresh.len()], thresh);
            }
            (LayerSpec::BinConv { .. }, LayerWeights::BinConv { filter, thresh }) => {
                w.push_u32(&format!("l{i}_filter"), &[filter.data.len()], &filter.data);
                w.push_f32(&format!("l{i}_thresh"), &[thresh.len()], thresh);
            }
            (LayerSpec::BinFc { .. }, LayerWeights::BinFc { w: m, thresh }) => {
                w.push_u32(&format!("l{i}_wbits"), &[m.data.len()], &m.data);
                w.push_f32(&format!("l{i}_thresh"), &[thresh.len()], thresh);
            }
            (LayerSpec::BinGcn { .. }, LayerWeights::BinGcn { w: m, thresh, .. }) => {
                // the adjacency is NOT serialized: it is spec-determined
                // (regenerated from the layer's AdjSpec on load), so the
                // blob stays a pure weight artifact
                w.push_u32(&format!("l{i}_wbits"), &[m.data.len()], &m.data);
                w.push_f32(&format!("l{i}_thresh"), &[thresh.len()], thresh);
            }
            (LayerSpec::FinalFc { .. }, LayerWeights::FinalFc { w: m, gamma, beta }) => {
                w.push_u32(&format!("l{i}_wbits"), &[m.data.len()], &m.data);
                w.push_f32(&format!("l{i}_gamma"), &[gamma.len()], gamma);
                w.push_f32(&format!("l{i}_beta"), &[beta.len()], beta);
            }
            (LayerSpec::Pool, LayerWeights::Pool) => {}
            _ => bail!("layer {i}: weight kind does not match layer spec"),
        }
    }
    Ok(w)
}

/// Reconstruct `ModelWeights` from a blob written by `weights_to_blob`
/// (shapes come from the `ModelDef`, values from the blob — the same
/// split the PJRT path uses between manifest and weight blob).
pub fn weights_from_blob(model: &ModelDef, blob: &Blob) -> Result<ModelWeights> {
    let mut layers = Vec::with_capacity(model.layers.len());
    for (i, l) in model.layers.iter().enumerate() {
        layers.push(match *l {
            LayerSpec::FirstConv { c, o, k, .. } => {
                let w_pm1 = blob.as_f32(&format!("l{i}_w"))?;
                ensure!(w_pm1.len() == k * k * c * o, "layer {i}: filter size");
                let thresh = blob.as_f32(&format!("l{i}_thresh"))?;
                ensure!(thresh.len() == o, "layer {i}: threshold size");
                LayerWeights::FirstConv { w_pm1, thresh }
            }
            LayerSpec::BinConv { c, o, k, .. } => {
                let data = blob.as_u32(&format!("l{i}_filter"))?;
                let mut filter =
                    BitTensor4::zeros([k, k, o, c], TensorLayout::Kkoc);
                ensure!(
                    data.len() == filter.data.len(),
                    "layer {i}: packed filter word count"
                );
                filter.data = data;
                let thresh = blob.as_f32(&format!("l{i}_thresh"))?;
                ensure!(thresh.len() == o, "layer {i}: threshold size");
                LayerWeights::BinConv { filter, thresh }
            }
            LayerSpec::BinFc { d_in, d_out } => {
                let data = blob.as_u32(&format!("l{i}_wbits"))?;
                let mut m = BitMatrix::zeros(d_out, d_in, Layout::RowMajor);
                ensure!(data.len() == m.data.len(), "layer {i}: packed fc word count");
                m.data = data;
                let thresh = blob.as_f32(&format!("l{i}_thresh"))?;
                ensure!(thresh.len() == d_out, "layer {i}: threshold size");
                LayerWeights::BinFc { w: m, thresh }
            }
            LayerSpec::BinGcn { nodes, d_in, d_out, adj, .. } => {
                let data = blob.as_u32(&format!("l{i}_wbits"))?;
                let mut m = BitMatrix::zeros(d_out, d_in, Layout::RowMajor);
                ensure!(data.len() == m.data.len(), "layer {i}: packed gcn word count");
                m.data = data;
                let thresh = blob.as_f32(&format!("l{i}_thresh"))?;
                ensure!(thresh.len() == d_out, "layer {i}: threshold size");
                LayerWeights::BinGcn {
                    adj: std::sync::Arc::new(crate::sparse::generate(adj, nodes)),
                    w: m,
                    thresh,
                }
            }
            LayerSpec::FinalFc { d_in, d_out } => {
                let data = blob.as_u32(&format!("l{i}_wbits"))?;
                let mut m = BitMatrix::zeros(d_out, d_in, Layout::RowMajor);
                ensure!(data.len() == m.data.len(), "layer {i}: packed fc word count");
                m.data = data;
                LayerWeights::FinalFc {
                    w: m,
                    gamma: blob.as_f32(&format!("l{i}_gamma"))?,
                    beta: blob.as_f32(&format!("l{i}_beta"))?,
                }
            }
            LayerSpec::Pool => LayerWeights::Pool,
        });
    }
    Ok(ModelWeights { layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::forward::{forward, random_weights};
    use crate::nn::layer::Dims;
    use crate::util::Rng;

    fn model() -> ModelDef {
        ModelDef {
            name: "blob-rt",
            dataset: "synthetic",
            input: Dims { hw: 6, feat: 3 },
            classes: 3,
            layers: vec![
                LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
                LayerSpec::BinConv {
                    c: 32,
                    o: 32,
                    k: 3,
                    stride: 1,
                    pad: 1,
                    pool: true,
                    residual: false,
                },
                LayerSpec::BinFc { d_in: 3 * 3 * 32, d_out: 32 },
                LayerSpec::FinalFc { d_in: 32, d_out: 3 },
            ],
            residual_blocks: 0,
        }
    }

    #[test]
    fn weights_roundtrip_through_blob_files() {
        let m = model();
        let mut rng = Rng::new(41);
        let w = random_weights(&m, &mut rng);
        let base = std::env::temp_dir()
            .join(format!("tcbnn_weights_{}", std::process::id()))
            .join("m")
            .to_str()
            .unwrap()
            .to_string();
        weights_to_blob(&m, &w).unwrap().write(&base).unwrap();
        let blob = Blob::load(&base).unwrap();
        let w2 = weights_from_blob(&m, &blob).unwrap();
        // loaded weights must drive an identical forward pass
        let x: Vec<f32> = (0..4 * 6 * 6 * 3).map(|_| rng.next_f32() - 0.5).collect();
        assert_eq!(forward(&m, &w, &x, 4), forward(&m, &w2, &x, 4));
    }

    #[test]
    fn gcn_weights_roundtrip_and_regenerate_adjacency() {
        let spec = crate::sparse::AdjSpec {
            kind: crate::sparse::AdjKind::PowerLaw,
            degree: 3,
            seed: 17,
        };
        let nodes = 24;
        let nnz_blocks = crate::sparse::generate(spec, nodes).nnz_blocks();
        let m = ModelDef {
            name: "blob-gcn",
            dataset: "synthetic",
            input: Dims { hw: 0, feat: nodes * 64 },
            classes: 3,
            layers: vec![
                LayerSpec::BinGcn { nodes, d_in: 64, d_out: 64, adj: spec, nnz_blocks },
                LayerSpec::FinalFc { d_in: nodes * 64, d_out: 3 },
            ],
            residual_blocks: 0,
        };
        let mut rng = Rng::new(47);
        let w = random_weights(&m, &mut rng);
        let base = std::env::temp_dir()
            .join(format!("tcbnn_weights_gcn_{}", std::process::id()))
            .join("m")
            .to_str()
            .unwrap()
            .to_string();
        weights_to_blob(&m, &w).unwrap().write(&base).unwrap();
        let blob = Blob::load(&base).unwrap();
        let w2 = weights_from_blob(&m, &blob).unwrap();
        // the loaded side regenerated the adjacency from the spec —
        // forward passes must be bit-identical
        let x: Vec<f32> =
            (0..2 * nodes * 64).map(|_| rng.next_f32() - 0.5).collect();
        assert_eq!(forward(&m, &w, &x, 2), forward(&m, &w2, &x, 2));
        match (&w.layers[0], &w2.layers[0]) {
            (
                LayerWeights::BinGcn { adj: a, .. },
                LayerWeights::BinGcn { adj: b, .. },
            ) => assert_eq!(a.as_ref(), b.as_ref(), "regenerated adjacency differs"),
            _ => panic!("expected gcn weights"),
        }
    }

    #[test]
    fn missing_tensor_is_an_error() {
        let m = model();
        let mut rng = Rng::new(43);
        let w = random_weights(&m, &mut rng);
        let base = std::env::temp_dir()
            .join(format!("tcbnn_weights_missing_{}", std::process::id()))
            .join("m")
            .to_str()
            .unwrap()
            .to_string();
        let mut writer = weights_to_blob(&m, &w).unwrap();
        // clobber: write an unrelated extra tensor, then load against a
        // model whose first layer wants a different name
        writer.push_f32("unrelated", &[1], &[0.0]);
        writer.write(&base).unwrap();
        let blob = Blob::load(&base).unwrap();
        assert!(weights_from_blob(&m, &blob).is_ok());
        let mut bigger = m.clone();
        bigger.layers.push(LayerSpec::BinFc { d_in: 3, d_out: 8 });
        assert!(weights_from_blob(&bigger, &blob).is_err());
    }
}
