//! The planner: per-layer scheme selection driven by the calibrated
//! Turing cost model.
//!
//! For every layer of a `ModelDef` (at a given batch bucket) the planner
//! simulates each Tables-6/7 scheme with `nn::cost::layer_secs` — the
//! exact same machinery `nn::cost::model_cost` uses — and selects the
//! cheapest.  Ties resolve to the first scheme in `Scheme::all()` order,
//! so planning is fully deterministic.

use crate::nn::cost::layer_secs;
use crate::nn::{ModelDef, ResidualMode, Scheme};
use crate::sim::{Engine, GpuModel};

use super::plan::{LayerPlan, ModelPlan};

/// Planner configuration: the target GPU plus the same knobs
/// `model_cost` exposes.
#[derive(Clone, Debug)]
pub struct Planner {
    pub gpu: GpuModel,
    pub residual: ResidualMode,
    pub layer_sync: bool,
}

impl Planner {
    /// Planner with the paper's default operating point (full residual
    /// traffic, per-layer cooperative sync).
    pub fn new(gpu: &GpuModel) -> Planner {
        Planner { gpu: gpu.clone(), residual: ResidualMode::Full, layer_sync: true }
    }

    /// The cheapest scheme for one layer, with its simulated seconds.
    /// `dims` is the layer's input dims (walk them with `Dims::after`).
    pub fn best_scheme(
        &self,
        engine: &Engine,
        model: &ModelDef,
        layer_index: usize,
        dims: crate::nn::layer::Dims,
        batch: usize,
    ) -> (Scheme, f64) {
        let layer = &model.layers[layer_index];
        let mut best = Scheme::all()[0];
        let mut best_secs = f64::INFINITY;
        for s in Scheme::all() {
            let secs = layer_secs(
                engine,
                s,
                layer,
                dims,
                batch,
                self.residual,
                model.residual_blocks > 0,
            );
            if secs < best_secs {
                best = s;
                best_secs = secs;
            }
        }
        (best, best_secs)
    }

    /// Plan a whole model at one batch bucket.
    pub fn plan(&self, model: &ModelDef, batch: usize) -> ModelPlan {
        self.plan_with(model, batch, None)
    }

    /// Plan with every layer pinned to `scheme` (no per-layer search).
    /// This is how a host without a Turing GPU serves the blocked-u64
    /// backend: `plan_fixed(model, batch, Scheme::Fastpath)` routes the
    /// whole model through `kernels::fastpath` in the executor.
    pub fn plan_fixed(&self, model: &ModelDef, batch: usize, scheme: Scheme) -> ModelPlan {
        self.plan_with(model, batch, Some(scheme))
    }

    fn plan_with(&self, model: &ModelDef, batch: usize, force: Option<Scheme>) -> ModelPlan {
        let engine = Engine::new(&self.gpu);
        let sync_secs = if self.layer_sync {
            self.gpu.secs(self.gpu.coop_sync_cycles)
        } else {
            0.0
        };
        let mut dims = model.input;
        let mut layers = Vec::with_capacity(model.layers.len());
        // one fused kernel launch, same accounting as model_cost
        let mut total = self.gpu.launch_overhead_s;
        for (i, l) in model.layers.iter().enumerate() {
            let (scheme, secs) = match force {
                Some(s) => (
                    s,
                    layer_secs(
                        &engine,
                        s,
                        l,
                        dims,
                        batch,
                        self.residual,
                        model.residual_blocks > 0,
                    ),
                ),
                None => self.best_scheme(&engine, model, i, dims, batch),
            };
            total += secs + sync_secs;
            layers.push(LayerPlan { index: i, tag: l.tag(), scheme, secs });
            dims = dims.after(l);
        }
        ModelPlan {
            model: model.name.to_string(),
            dataset: model.dataset.to_string(),
            gpu: self.gpu.name.to_string(),
            batch,
            classes: model.classes,
            layers,
            total_secs: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{all_models, mnist_mlp};
    use crate::nn::model_cost;
    use crate::sim::RTX2080TI;

    #[test]
    fn plan_covers_every_layer() {
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            assert_eq!(plan.layers.len(), m.layers.len(), "{}", m.name);
            for (i, (lp, l)) in plan.layers.iter().zip(&m.layers).enumerate() {
                assert_eq!(lp.index, i);
                assert_eq!(lp.tag, l.tag());
                assert!(lp.secs.is_finite() && lp.secs > 0.0);
            }
        }
    }

    #[test]
    fn planned_total_never_beats_best_fixed_scheme_by_construction() {
        // the per-layer optimum is at most the best whole-model fixed
        // scheme (it can only improve by mixing)
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            let best_fixed = Scheme::all()
                .iter()
                .map(|s| {
                    model_cost(&m, 8, &RTX2080TI, *s, ResidualMode::Full, true)
                        .total_secs
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                plan.total_secs <= best_fixed * (1.0 + 1e-12),
                "{}: planned {} vs best fixed {}",
                m.name,
                plan.total_secs,
                best_fixed
            );
        }
    }

    #[test]
    fn deterministic() {
        let p = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        assert_eq!(p.plan(&m, 32), p.plan(&m, 32));
    }

    #[test]
    fn fixed_plan_pins_every_layer() {
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan_fixed(&m, 8, Scheme::Fastpath);
            assert_eq!(plan.layers.len(), m.layers.len());
            for lp in &plan.layers {
                assert_eq!(lp.scheme, Scheme::Fastpath, "{} {}", m.name, lp.tag);
                assert!(lp.secs.is_finite() && lp.secs > 0.0);
            }
            // a fixed plan costs at least the searched optimum
            assert!(plan.total_secs >= p.plan(&m, 8).total_secs * (1.0 - 1e-12));
        }
    }
}
