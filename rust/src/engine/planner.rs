//! The planner: per-layer scheme selection driven by the backends'
//! cost faces.
//!
//! For every layer of a `ModelDef` (at a given batch bucket) the
//! planner asks its [`CostSource`] for each registered backend's
//! per-layer seconds — by default the backends' own `layer_secs` cost
//! faces, the exact same face `nn::cost::model_cost` sums — and
//! selects the cheapest.  Ties resolve to the first-registered backend
//! (the builtin registry registers in `Scheme::all()` order), so
//! planning is fully deterministic.  A backend registered at runtime
//! joins the search automatically — no planner changes needed.
//!
//! [`Planner::with_cost_source`] swaps the analytic faces for a fitted
//! per-host [`CalibrationProfile`](crate::tuner::CalibrationProfile)
//! (`CostSource::Calibrated`) or the live executor-fed blend
//! (`CostSource::Live`); every emitted plan records the source's
//! `profile_id` so the plan cache can invalidate entries planned under
//! a different calibration.

use std::sync::Arc;

use crate::kernels::backend::BackendRegistry;
use crate::nn::{ModelDef, ResidualMode, Scheme};
use crate::sim::{Engine, GpuModel};
use crate::tuner::CostSource;

use super::plan::{LayerPlan, ModelPlan};

/// Planner configuration: the target GPU plus the same knobs
/// `model_cost` exposes, searching over a backend registry.
#[derive(Clone, Debug)]
pub struct Planner {
    pub gpu: GpuModel,
    pub residual: ResidualMode,
    pub layer_sync: bool,
    registry: Arc<BackendRegistry>,
    cost: CostSource,
}

impl Planner {
    /// Planner with the paper's default operating point (full residual
    /// traffic, per-layer cooperative sync) over the builtin backends.
    pub fn new(gpu: &GpuModel) -> Planner {
        Planner::with_registry(gpu, Arc::new(BackendRegistry::builtin()))
    }

    /// Planner over an explicit registry (custom/test backends).  The
    /// registry is shared with the executor build through
    /// [`Planner::registry`].
    pub fn with_registry(gpu: &GpuModel, registry: Arc<BackendRegistry>) -> Planner {
        Planner {
            gpu: gpu.clone(),
            residual: ResidualMode::Full,
            layer_sync: true,
            registry,
            cost: CostSource::Analytic,
        }
    }

    /// Replace the cost source the search queries (default
    /// [`CostSource::Analytic`]): `Calibrated` for a fitted per-host
    /// profile, `Live` for the executor-fed drift blend.
    pub fn with_cost_source(mut self, cost: CostSource) -> Planner {
        self.cost = cost;
        self
    }

    /// The cost source this planner queries.
    pub fn cost_source(&self) -> &CostSource {
        &self.cost
    }

    /// The cost source's stable id — what emitted plans record as
    /// `cost_profile` and the plan cache validates against.
    pub fn cost_profile_id(&self) -> String {
        self.cost.profile_id()
    }

    /// The registry this planner searches.
    pub fn registry(&self) -> &BackendRegistry {
        &self.registry
    }

    /// A shared handle to the registry (e.g. for a second planner).
    pub fn registry_handle(&self) -> Arc<BackendRegistry> {
        Arc::clone(&self.registry)
    }

    /// The registered scheme names, in search order — embedded in every
    /// emitted plan so the plan cache can invalidate entries planned
    /// against a different backend set.
    pub fn scheme_names(&self) -> Vec<String> {
        self.registry.names().iter().map(|s| s.to_string()).collect()
    }

    /// The cheapest scheme for one layer, with its simulated seconds.
    /// `dims` is the layer's input dims (walk them with `Dims::after`).
    pub fn best_scheme(
        &self,
        engine: &Engine,
        model: &ModelDef,
        layer_index: usize,
        dims: crate::nn::layer::Dims,
        batch: usize,
    ) -> (Scheme, f64) {
        let layer = &model.layers[layer_index];
        let mut best: Option<Scheme> = None;
        let mut best_secs = f64::INFINITY;
        for b in self.registry.backends() {
            let secs = self.cost.layer_secs(
                b,
                engine,
                layer,
                dims,
                batch,
                self.residual,
                model.residual_blocks > 0,
            );
            if secs < best_secs || best.is_none() {
                best = Some(b.scheme());
                best_secs = secs;
            }
        }
        (best.expect("planner registry must not be empty"), best_secs)
    }

    /// Plan a whole model at one batch bucket (per-layer search).
    pub fn plan(&self, model: &ModelDef, batch: usize) -> ModelPlan {
        self.plan_with(model, batch, None)
    }

    /// Plan with every layer pinned to `scheme` (no per-layer search).
    /// This is how a host without a Turing GPU serves the blocked-u64
    /// backend: `plan_fixed(model, batch, Scheme::Fastpath)` routes the
    /// whole model through `kernels::fastpath` in the executor.
    ///
    /// Panics if `scheme` has no backend in this planner's registry.
    pub fn plan_fixed(&self, model: &ModelDef, batch: usize, scheme: Scheme) -> ModelPlan {
        self.plan_with(model, batch, Some(scheme))
    }

    fn plan_with(&self, model: &ModelDef, batch: usize, force: Option<Scheme>) -> ModelPlan {
        let engine = Engine::new(&self.gpu);
        let forced = force.map(|s| {
            self.registry.get(s).unwrap_or_else(|| {
                panic!("scheme {} has no registered backend", s.name())
            })
        });
        let sync_secs = if self.layer_sync {
            self.gpu.secs(self.gpu.coop_sync_cycles)
        } else {
            0.0
        };
        let mut dims = model.input;
        let mut layers = Vec::with_capacity(model.layers.len());
        // one fused kernel launch, same accounting as model_cost
        let mut total = self.gpu.launch_overhead_s;
        for (i, l) in model.layers.iter().enumerate() {
            let (scheme, secs) = match &forced {
                Some(b) => (
                    b.scheme(),
                    self.cost.layer_secs(
                        *b,
                        &engine,
                        l,
                        dims,
                        batch,
                        self.residual,
                        model.residual_blocks > 0,
                    ),
                ),
                None => self.best_scheme(&engine, model, i, dims, batch),
            };
            total += secs + sync_secs;
            layers.push(LayerPlan { index: i, tag: l.tag(), scheme, secs });
            dims = dims.after(l);
        }
        ModelPlan {
            model: model.name.to_string(),
            dataset: model.dataset.to_string(),
            gpu: self.gpu.name.to_string(),
            batch,
            classes: model.classes,
            scheme_set: self.scheme_names(),
            cost_profile: self.cost.profile_id(),
            layers,
            total_secs: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{all_models, mnist_mlp};
    use crate::nn::model_cost;
    use crate::sim::RTX2080TI;

    #[test]
    fn plan_covers_every_layer() {
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            assert_eq!(plan.layers.len(), m.layers.len(), "{}", m.name);
            for (i, (lp, l)) in plan.layers.iter().zip(&m.layers).enumerate() {
                assert_eq!(lp.index, i);
                assert_eq!(lp.tag, l.tag());
                assert!(lp.secs.is_finite() && lp.secs > 0.0);
            }
            // the plan records the searched backend set
            let want: Vec<String> =
                Scheme::all().iter().map(|s| s.name().to_string()).collect();
            assert_eq!(plan.scheme_set, want);
        }
    }

    #[test]
    fn planned_total_never_beats_best_fixed_scheme_by_construction() {
        // the per-layer optimum is at most the best whole-model fixed
        // scheme (it can only improve by mixing)
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            let best_fixed = Scheme::all()
                .iter()
                .map(|s| {
                    model_cost(&m, 8, &RTX2080TI, *s, ResidualMode::Full, true)
                        .total_secs
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                plan.total_secs <= best_fixed * (1.0 + 1e-12),
                "{}: planned {} vs best fixed {}",
                m.name,
                plan.total_secs,
                best_fixed
            );
        }
    }

    #[test]
    fn deterministic() {
        let p = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        assert_eq!(p.plan(&m, 32), p.plan(&m, 32));
    }

    #[test]
    fn fixed_plan_pins_every_layer() {
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan_fixed(&m, 8, Scheme::Fastpath);
            assert_eq!(plan.layers.len(), m.layers.len());
            for lp in &plan.layers {
                assert_eq!(lp.scheme, Scheme::Fastpath, "{} {}", m.name, lp.tag);
                assert!(lp.secs.is_finite() && lp.secs > 0.0);
            }
            // a fixed plan costs at least the searched optimum
            assert!(plan.total_secs >= p.plan(&m, 8).total_secs * (1.0 - 1e-12));
        }
    }

    #[test]
    fn default_plans_record_the_analytic_cost_profile() {
        let p = Planner::new(&RTX2080TI);
        assert_eq!(p.cost_profile_id(), crate::tuner::ANALYTIC_PROFILE_ID);
        let plan = p.plan(&mnist_mlp(), 8);
        assert_eq!(plan.cost_profile, "analytic");
    }

    #[test]
    fn calibrated_source_changes_the_recorded_profile_not_the_search_space() {
        use crate::tuner::{
            CalibrationProfile, CostSource, HostFingerprint, SchemeCoeffs,
        };
        let reg = Arc::new(BackendRegistry::builtin());
        let profile = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(&reg),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
        });
        let p = Planner::with_registry(&RTX2080TI, Arc::clone(&reg))
            .with_cost_source(CostSource::Calibrated(Arc::clone(&profile)));
        let plan = p.plan(&mnist_mlp(), 8);
        assert_eq!(plan.cost_profile, profile.id());
        // analytic coefficients => identical per-layer choices
        let analytic = Planner::with_registry(&RTX2080TI, reg).plan(&mnist_mlp(), 8);
        let schemes: Vec<_> = plan.layers.iter().map(|l| l.scheme).collect();
        let want: Vec<_> = analytic.layers.iter().map(|l| l.scheme).collect();
        assert_eq!(schemes, want);
    }

    #[test]
    fn search_is_restricted_to_the_registry() {
        // a planner over a single-backend registry can only ever pick
        // that backend's scheme
        let mut reg = BackendRegistry::empty();
        reg.register(Box::new(
            crate::kernels::backends::fastpath::FastpathBackend,
        ));
        let p = Planner::with_registry(&RTX2080TI, Arc::new(reg));
        let plan = p.plan(&mnist_mlp(), 8);
        for lp in &plan.layers {
            assert_eq!(lp.scheme, Scheme::Fastpath);
        }
        assert_eq!(plan.scheme_set, vec!["FASTPATH".to_string()]);
    }
}
