//! The planner: a dynamic program over per-layer (scheme, layout)
//! pairs, driven by the backends' cost faces plus a modeled repack
//! cost along layer edges.
//!
//! For every layer of a `ModelDef` (at a given batch bucket) the
//! planner asks its [`CostSource`] for each registered backend's
//! per-layer seconds — by default the backends' own `layer_secs` cost
//! faces, the exact same face `nn::cost::model_cost` sums.  Since the
//! layout co-design subsystem (`crate::layout`) the search is no
//! longer independent per layer: each candidate also picks the
//! activation layout it consumes (`Row32`, or the backend's preferred
//! native form) and the layout the executor packs its output into,
//! and the DP charges [`CostSource::repack_secs`] on every edge whose
//! layouts disagree.  Feeding a backend its native layout earns a
//! *discount* (the internal conversion its analytic cost face already
//! prices goes away), so chains of same-native-layout layers — e.g.
//! consecutive fastpath FC layers exchanging `Blocked64` activations —
//! genuinely beat the all-`Row32` plan, while an isolated native edge
//! ties with `Row32` and loses the deterministic tie-break.
//!
//! By construction the DP never predicts a plan worse than the
//! scheme-only search ([`Planner::with_layout_search`]`(false)`, the
//! pre-layout behavior): the all-`Row32` path is always in its search
//! space at exactly the old cost.
//!
//! Ties resolve to the earliest (prev-layout, backend, in-layout,
//! out-layout) candidate in iteration order — prev layouts and layout
//! options in `LayoutKind::all()` order, backends in registration
//! order — so planning is fully deterministic.  A backend registered
//! at runtime joins the search automatically, and its layout face
//! ([`crate::kernels::backend::KernelBackend::preferred_input_layout`])
//! widens the DP with no planner changes.
//!
//! [`Planner::with_cost_source`] swaps the analytic faces for a fitted
//! per-host [`CalibrationProfile`](crate::tuner::CalibrationProfile)
//! (`CostSource::Calibrated`) or the live executor-fed blend
//! (`CostSource::Live`); every emitted plan records the source's
//! `profile_id` so the plan cache can invalidate entries planned under
//! a different calibration.  Calibrated profiles price repack edges
//! from measured per-pair bandwidth (`CalibrationProfile::repacks`).

use std::sync::Arc;

use crate::kernels::backend::{BackendRegistry, KernelBackend};
use crate::layout::{LayoutDesc, LayoutKind};
use crate::nn::layer::LayerSpec;
use crate::nn::{ModelDef, ResidualMode, Scheme};
use crate::sim::{Engine, GpuModel};
use crate::tuner::CostSource;

use super::plan::{LayerPlan, ModelPlan, PlanRepack};

/// Planner configuration: the target GPU plus the same knobs
/// `model_cost` exposes, searching over a backend registry.
#[derive(Clone, Debug)]
pub struct Planner {
    pub gpu: GpuModel,
    pub residual: ResidualMode,
    pub layer_sync: bool,
    registry: Arc<BackendRegistry>,
    cost: CostSource,
    /// search (scheme, layout) pairs (default); `false` restricts the
    /// DP to all-`Row32` edges — exactly the pre-layout scheme-only
    /// planner, kept for comparison and for the regression guarantee.
    layout_search: bool,
}

/// One DP transition choice, recorded per layer for reconstruction.
#[derive(Clone, Copy)]
struct Choice {
    scheme: Scheme,
    in_layout: LayoutKind,
    out_layout: LayoutKind,
    /// compute seconds (incl. native-layout discount)
    secs: f64,
    /// layout the previous state handed over
    edge_from: LayoutKind,
    /// modeled seconds of the edge conversion (0 when layouts agree)
    edge_secs: f64,
    /// streamed bytes of the edge conversion
    edge_bytes: usize,
}

impl Planner {
    /// Planner with the paper's default operating point (full residual
    /// traffic, per-layer cooperative sync) over the builtin backends.
    pub fn new(gpu: &GpuModel) -> Planner {
        Planner::with_registry(gpu, Arc::new(BackendRegistry::builtin()))
    }

    /// Planner over an explicit registry (custom/test backends).  The
    /// registry is shared with the executor build through
    /// [`Planner::registry`].
    pub fn with_registry(gpu: &GpuModel, registry: Arc<BackendRegistry>) -> Planner {
        Planner {
            gpu: gpu.clone(),
            residual: ResidualMode::Full,
            layer_sync: true,
            registry,
            cost: CostSource::Analytic,
            layout_search: true,
        }
    }

    /// Replace the cost source the search queries (default
    /// [`CostSource::Analytic`]): `Calibrated` for a fitted per-host
    /// profile, `Live` for the executor-fed drift blend.
    pub fn with_cost_source(mut self, cost: CostSource) -> Planner {
        self.cost = cost;
        self
    }

    /// Toggle the layout dimension of the search (default on).  With
    /// `false` the planner degenerates to the scheme-only per-layer
    /// search over all-`Row32` edges — byte-identical plans to the
    /// pre-layout planner, useful as the DP's regression baseline.
    pub fn with_layout_search(mut self, on: bool) -> Planner {
        self.layout_search = on;
        self
    }

    /// Whether the (scheme, layout) DP is enabled.
    pub fn layout_search(&self) -> bool {
        self.layout_search
    }

    /// The cost source this planner queries.
    pub fn cost_source(&self) -> &CostSource {
        &self.cost
    }

    /// The cost source's stable id — what emitted plans record as
    /// `cost_profile` and the plan cache validates against.
    pub fn cost_profile_id(&self) -> String {
        self.cost.profile_id()
    }

    /// The registry this planner searches.
    pub fn registry(&self) -> &BackendRegistry {
        &self.registry
    }

    /// A shared handle to the registry (e.g. for a second planner).
    pub fn registry_handle(&self) -> Arc<BackendRegistry> {
        Arc::clone(&self.registry)
    }

    /// The registered scheme names, in search order — embedded in every
    /// emitted plan so the plan cache can invalidate entries planned
    /// against a different backend set.
    pub fn scheme_names(&self) -> Vec<String> {
        self.registry.names().iter().map(|s| s.to_string()).collect()
    }

    /// The cheapest scheme for one layer in isolation (all-`Row32`
    /// edges), with its simulated seconds — the scheme-only view.
    /// `dims` is the layer's input dims (walk them with `Dims::after`).
    pub fn best_scheme(
        &self,
        engine: &Engine,
        model: &ModelDef,
        layer_index: usize,
        dims: crate::nn::layer::Dims,
        batch: usize,
    ) -> (Scheme, f64) {
        let layer = &model.layers[layer_index];
        let mut best: Option<Scheme> = None;
        let mut best_secs = f64::INFINITY;
        for b in self.registry.backends() {
            let secs = self.cost.layer_secs(
                b,
                engine,
                layer,
                dims,
                batch,
                self.residual,
                model.residual_blocks > 0,
            );
            if secs < best_secs || best.is_none() {
                best = Some(b.scheme());
                best_secs = secs;
            }
        }
        (best.expect("planner registry must not be empty"), best_secs)
    }

    /// Plan a whole model at one batch bucket: the (scheme, layout) DP.
    pub fn plan(&self, model: &ModelDef, batch: usize) -> ModelPlan {
        self.plan_with(model, batch, None)
    }

    /// Predicted end-to-end seconds of one `batch`-row pass under this
    /// planner's cost source — the feed for SLO-aware batch sizing
    /// (`serve::slo`).  Because the prediction goes through
    /// [`Planner::plan`], it automatically inherits whatever the source
    /// knows: `Live` blends the executor's measured EWMA, `Calibrated`
    /// uses the fitted host profile, `Analytic` is the model-based
    /// fallback.
    pub fn predict_secs(&self, model: &ModelDef, batch: usize) -> f64 {
        self.plan(model, batch).total_secs
    }

    /// Plan with every layer pinned to `scheme` (the layout DP still
    /// runs within that scheme).  This is how a host without a Turing
    /// GPU serves the blocked-u64 backend:
    /// `plan_fixed(model, batch, Scheme::Fastpath)` routes the whole
    /// model through `kernels::fastpath` in the executor — chaining
    /// consecutive FC layers in `Blocked64`.
    ///
    /// Panics if `scheme` has no backend in this planner's registry.
    pub fn plan_fixed(&self, model: &ModelDef, batch: usize, scheme: Scheme) -> ModelPlan {
        self.plan_with(model, batch, Some(scheme))
    }

    /// The input layouts a backend may consume for `layer` (native
    /// form last so `Row32` wins exact ties deterministically).  Only
    /// flat (FC) activations have a layout choice — HWNC conv/pool
    /// buffers are `Row32` by executor construction.
    fn input_options(&self, b: &dyn KernelBackend, layer: &LayerSpec) -> Vec<LayoutKind> {
        let mut v = vec![LayoutKind::Row32];
        if self.layout_search
            && matches!(layer, LayerSpec::BinFc { .. } | LayerSpec::FinalFc { .. })
        {
            let pref = b.preferred_input_layout(layer);
            if pref != LayoutKind::Row32 {
                v.push(pref);
            }
        }
        v
    }

    /// The output layouts the executor can pack `layer`'s result into
    /// under `b`.  Only `BinFc` produces a packed flat activation with
    /// a choice; everything else (HWNC buffers, the classifier's
    /// logits) is `Row32` by construction.
    fn output_options(&self, b: &dyn KernelBackend, layer: &LayerSpec) -> Vec<LayoutKind> {
        let mut v = vec![LayoutKind::Row32];
        if self.layout_search && matches!(layer, LayerSpec::BinFc { .. }) {
            let out = b.output_layout(layer);
            if out != LayoutKind::Row32 {
                v.push(out);
            }
        }
        v
    }

    /// Streamed bytes of converting the flat activation entering
    /// `layer` (batch rows of `d_in` bits) from `src` to `dst`.
    fn edge_bytes(src: LayoutKind, dst: LayoutKind, batch: usize, d_in: usize) -> usize {
        LayoutDesc::new(src, batch, d_in).storage_bytes()
            + LayoutDesc::new(dst, batch, d_in).storage_bytes()
    }

    /// The native-layout discount the DP grants for feeding `b` its
    /// preferred (non-`Row32`) form: the internal `Row32 -> native`
    /// conversion its cost face prices goes away, capped so a
    /// discounted layer always keeps most of its compute cost.  Zero
    /// for `Row32` or non-preferred layouts.  Shared with
    /// `EngineModel`'s live baselines so chained layers are not
    /// misread as cost drift.
    pub fn native_discount(
        &self,
        b: &dyn KernelBackend,
        layer: &LayerSpec,
        d_in_bits: usize,
        batch: usize,
        in_layout: LayoutKind,
        raw_secs: f64,
    ) -> f64 {
        if in_layout == LayoutKind::Row32 || in_layout != b.preferred_input_layout(layer)
        {
            return 0.0;
        }
        let bytes = Planner::edge_bytes(LayoutKind::Row32, in_layout, batch, d_in_bits);
        self.cost
            .repack_secs(LayoutKind::Row32, in_layout, bytes)
            .min(raw_secs * 0.9)
    }

    fn plan_with(&self, model: &ModelDef, batch: usize, force: Option<Scheme>) -> ModelPlan {
        let engine = Engine::new(&self.gpu);
        let forced = force.map(|s| {
            self.registry.get(s).unwrap_or_else(|| {
                panic!("scheme {} has no registered backend", s.name())
            })
        });
        let sync_secs = if self.layer_sync {
            self.gpu.secs(self.gpu.coop_sync_cycles)
        } else {
            0.0
        };
        let kinds = LayoutKind::all();
        // dp[k] = cheapest (total secs, choice path) reaching an
        // activation in layout k after the layers processed so far.
        // One fused kernel launch, same accounting as model_cost.
        let mut dp: Vec<Option<(f64, Vec<Choice>)>> = vec![None; kinds.len()];
        dp[LayoutKind::Row32.index()] = Some((self.gpu.launch_overhead_s, Vec::new()));
        // candidate (scheme, in-layout, discounted secs, outs) rows —
        // none of this depends on the previous DP state, so the cost
        // faces are queried once per backend per layer, not once per
        // prev-layout.  The discount removes the internal Row32 ->
        // native conversion the cost face prices when the backend is
        // fed its preferred form directly.
        struct Candidate {
            scheme: Scheme,
            in_layout: LayoutKind,
            secs: f64,
            outs: Vec<LayoutKind>,
        }
        let mut dims = model.input;
        for l in &model.layers {
            let mut next: Vec<Option<(f64, Vec<Choice>)>> = vec![None; kinds.len()];
            let backends: Vec<&dyn KernelBackend> = match &forced {
                Some(b) => vec![*b],
                None => self.registry.backends().collect(),
            };
            let d_in_bits = dims.flat();
            let mut candidates: Vec<Candidate> = Vec::new();
            for b in &backends {
                let raw = self.cost.layer_secs(
                    *b,
                    &engine,
                    l,
                    dims,
                    batch,
                    self.residual,
                    model.residual_blocks > 0,
                );
                let outs = self.output_options(*b, l);
                for in_layout in self.input_options(*b, l) {
                    let secs =
                        raw - self.native_discount(*b, l, d_in_bits, batch, in_layout, raw);
                    candidates.push(Candidate {
                        scheme: b.scheme(),
                        in_layout,
                        secs,
                        outs: outs.clone(),
                    });
                }
            }
            for prev_kind in kinds {
                let Some((prev_total, prev_path)) = dp[prev_kind.index()].as_ref()
                else {
                    continue;
                };
                for c in &candidates {
                    let (edge_secs, edge_bytes) = if c.in_layout == prev_kind {
                        (0.0, 0)
                    } else {
                        let bytes =
                            Planner::edge_bytes(prev_kind, c.in_layout, batch, d_in_bits);
                        (self.cost.repack_secs(prev_kind, c.in_layout, bytes), bytes)
                    };
                    for &out_layout in &c.outs {
                        let total = prev_total + edge_secs + c.secs + sync_secs;
                        let slot = &mut next[out_layout.index()];
                        // strictly-better-with-margin: an exact tie
                        // (e.g. edge repack cancelling the native
                        // discount to the last ulp) must go to the
                        // earlier candidate deterministically.  The
                        // multiplicative form stays NaN-free when the
                        // held total is infinite (a rejected shape), so
                        // a finite candidate replaces it.
                        let better = match slot {
                            None => true,
                            Some((t, _)) => total * (1.0 + 1e-12) < *t,
                        };
                        if better {
                            let mut path = prev_path.clone();
                            path.push(Choice {
                                scheme: c.scheme,
                                in_layout: c.in_layout,
                                out_layout,
                                secs: c.secs,
                                edge_from: prev_kind,
                                edge_secs,
                                edge_bytes,
                            });
                            *slot = Some((total, path));
                        }
                    }
                }
            }
            dp = next;
            dims = dims.after(l);
        }
        // best end state; iterate in LayoutKind order with a strict <
        // so ties resolve to the earliest kind (Row32 first)
        let mut best: Option<(f64, Vec<Choice>)> = None;
        for state in dp.into_iter().flatten() {
            let better = match &best {
                None => true,
                Some((t, _)) => state.0 * (1.0 + 1e-12) < *t,
            };
            if better {
                best = Some(state);
            }
        }
        let (total, path) =
            best.expect("planner registry must not be empty (no DP state survived)");
        let layers: Vec<LayerPlan> = path
            .iter()
            .enumerate()
            .map(|(i, c)| LayerPlan {
                index: i,
                tag: model.layers[i].tag(),
                scheme: c.scheme,
                in_layout: c.in_layout,
                out_layout: c.out_layout,
                secs: c.secs,
            })
            .collect();
        let repacks: Vec<PlanRepack> = path
            .iter()
            .enumerate()
            .filter(|(_, c)| c.edge_from != c.in_layout)
            .map(|(i, c)| PlanRepack {
                layer: i,
                src: c.edge_from,
                dst: c.in_layout,
                bytes: c.edge_bytes,
                secs: c.edge_secs,
            })
            .collect();
        ModelPlan {
            model: model.name.to_string(),
            dataset: model.dataset.to_string(),
            gpu: self.gpu.name.to_string(),
            batch,
            classes: model.classes,
            scheme_set: self.scheme_names(),
            cost_profile: self.cost.profile_id(),
            sparsity: Planner::sparsity_fingerprint(model),
            layers,
            repacks,
            total_secs: total,
        }
    }

    /// The sparsity fingerprint an emitted plan records: `"dense"` for
    /// models with no graph layers, otherwise the comma-joined
    /// adjacency fingerprint of every GCN layer.  The plan cache
    /// compares this against the serving model, so a density change
    /// (regenerated graph, different stored-block count) re-plans
    /// instead of reusing a crossover ranked for the old graph.
    pub fn sparsity_fingerprint(model: &ModelDef) -> String {
        let parts: Vec<String> = model
            .layers
            .iter()
            .filter_map(|l| match l {
                LayerSpec::BinGcn { nodes, adj, nnz_blocks, .. } => Some(
                    crate::sparse::layer_fingerprint(*adj, *nodes, *nnz_blocks),
                ),
                _ => None,
            })
            .collect();
        if parts.is_empty() {
            "dense".to_string()
        } else {
            parts.join(",")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{all_models, mnist_mlp};
    use crate::nn::model_cost;
    use crate::sim::RTX2080TI;

    #[test]
    fn plan_covers_every_layer() {
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            assert_eq!(plan.layers.len(), m.layers.len(), "{}", m.name);
            for (i, (lp, l)) in plan.layers.iter().zip(&m.layers).enumerate() {
                assert_eq!(lp.index, i);
                assert_eq!(lp.tag, l.tag());
                assert!(lp.secs.is_finite() && lp.secs > 0.0);
            }
            // the plan records the searched backend set
            let want: Vec<String> =
                Scheme::all().iter().map(|s| s.name().to_string()).collect();
            assert_eq!(plan.scheme_set, want);
        }
    }

    #[test]
    fn planned_total_never_beats_best_fixed_scheme_by_construction() {
        // the per-layer optimum is at most the best whole-model fixed
        // scheme (it can only improve by mixing and layout-chaining)
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            let best_fixed = Scheme::all()
                .iter()
                .map(|s| {
                    model_cost(&m, 8, &RTX2080TI, *s, ResidualMode::Full, true)
                        .total_secs
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                plan.total_secs <= best_fixed * (1.0 + 1e-12),
                "{}: planned {} vs best fixed {}",
                m.name,
                plan.total_secs,
                best_fixed
            );
        }
    }

    #[test]
    fn layout_dp_never_predicts_worse_than_scheme_only() {
        // the all-Row32 path is always in the DP's search space at the
        // old scheme-only cost, so the DP total can only be <=
        let dp = Planner::new(&RTX2080TI);
        let scheme_only = Planner::new(&RTX2080TI).with_layout_search(false);
        for m in all_models() {
            for batch in [8usize, 128] {
                let a = dp.plan(&m, batch);
                let b = scheme_only.plan(&m, batch);
                assert!(
                    a.total_secs <= b.total_secs * (1.0 + 1e-12),
                    "{} b{batch}: DP {} vs scheme-only {}",
                    m.name,
                    a.total_secs,
                    b.total_secs
                );
                // the scheme-only plan has no layout edges or repacks
                assert!(b.repacks.is_empty());
                for lp in &b.layers {
                    assert_eq!(lp.in_layout, LayoutKind::Row32);
                    assert_eq!(lp.out_layout, LayoutKind::Row32);
                }
            }
        }
    }

    #[test]
    fn scheme_only_planner_matches_the_per_layer_brute_force() {
        // with the layout dimension off, the DP degenerates to the
        // historical independent per-layer argmin over layer_secs
        let p = Planner::new(&RTX2080TI).with_layout_search(false);
        let engine = Engine::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan(&m, 8);
            let mut dims = m.input;
            for (li, l) in m.layers.iter().enumerate() {
                let (want, want_secs) = p.best_scheme(&engine, &m, li, dims, 8);
                assert_eq!(plan.layers[li].scheme, want, "{} layer {li}", m.name);
                assert!((plan.layers[li].secs - want_secs).abs() <= 1e-18);
                dims = dims.after(l);
            }
        }
    }

    #[test]
    fn deterministic() {
        let p = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        assert_eq!(p.plan(&m, 32), p.plan(&m, 32));
    }

    #[test]
    fn fixed_plan_pins_every_layer() {
        let p = Planner::new(&RTX2080TI);
        for m in all_models() {
            let plan = p.plan_fixed(&m, 8, Scheme::Fastpath);
            assert_eq!(plan.layers.len(), m.layers.len());
            for lp in &plan.layers {
                assert_eq!(lp.scheme, Scheme::Fastpath, "{} {}", m.name, lp.tag);
                assert!(lp.secs.is_finite() && lp.secs > 0.0);
            }
            // a fixed plan costs at least the searched optimum
            assert!(plan.total_secs >= p.plan(&m, 8).total_secs * (1.0 - 1e-12));
        }
    }

    #[test]
    fn fixed_fastpath_chains_consecutive_fc_layers_in_blocked64() {
        // the MLP is all FC: a fastpath-pinned plan must hand every
        // layer after the first its native Blocked64 form over
        // zero-cost edges, beating the Row32-only fixed plan strictly
        let p = Planner::new(&RTX2080TI);
        let m = mnist_mlp();
        let plan = p.plan_fixed(&m, 8, Scheme::Fastpath);
        for (i, lp) in plan.layers.iter().enumerate() {
            if i == 0 {
                // first layer consumes the freshly binarized Row32 rows
                assert_eq!(lp.in_layout, LayoutKind::Row32, "{}", lp.tag);
            } else {
                assert_eq!(lp.in_layout, LayoutKind::Blocked64, "{}", lp.tag);
            }
            if i + 1 < plan.layers.len() {
                assert_eq!(lp.out_layout, LayoutKind::Blocked64, "{}", lp.tag);
            }
        }
        // chained edges already agree — no explicit repack ops needed
        assert!(plan.repacks.is_empty(), "{:?}", plan.repacks);
        let row32 = Planner::new(&RTX2080TI)
            .with_layout_search(false)
            .plan_fixed(&m, 8, Scheme::Fastpath);
        assert!(
            plan.total_secs < row32.total_secs,
            "chained {} vs row32 {}",
            plan.total_secs,
            row32.total_secs
        );
    }

    #[test]
    fn plans_record_the_sparsity_fingerprint() {
        let p = Planner::new(&RTX2080TI);
        // dense models record the literal "dense"
        assert_eq!(p.plan(&mnist_mlp(), 8).sparsity, "dense");
        // graph models record one adjacency fingerprint per GCN layer
        let gcn = crate::nn::model::gcn_powerlaw();
        let plan = p.plan(&gcn, 8);
        let parts: Vec<&str> = plan.sparsity.split(',').collect();
        let n_gcn = gcn
            .layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::BinGcn { .. }))
            .count();
        assert_eq!(parts.len(), n_gcn);
        for part in &parts {
            assert!(part.starts_with("powerlaw-"), "{part}");
            assert!(part.ends_with('b'), "{part}");
        }
        // the fingerprint tracks the stored-block count: a different
        // density is a different plan key
        let mut denser = gcn.clone();
        if let LayerSpec::BinGcn { nnz_blocks, .. } = &mut denser.layers[0] {
            *nnz_blocks += 1;
        }
        assert_ne!(p.plan(&denser, 8).sparsity, plan.sparsity);
    }

    #[test]
    fn default_plans_record_the_analytic_cost_profile() {
        let p = Planner::new(&RTX2080TI);
        assert_eq!(p.cost_profile_id(), crate::tuner::ANALYTIC_PROFILE_ID);
        let plan = p.plan(&mnist_mlp(), 8);
        assert_eq!(plan.cost_profile, "analytic");
    }

    #[test]
    fn calibrated_source_changes_the_recorded_profile_not_the_search_space() {
        use crate::tuner::{
            CalibrationProfile, CostSource, HostFingerprint, SchemeCoeffs,
        };
        let reg = Arc::new(BackendRegistry::builtin());
        let profile = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(&reg),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
            repacks: Vec::new(),
        });
        let p = Planner::with_registry(&RTX2080TI, Arc::clone(&reg))
            .with_cost_source(CostSource::Calibrated(Arc::clone(&profile)));
        let plan = p.plan(&mnist_mlp(), 8);
        assert_eq!(plan.cost_profile, profile.id());
        // analytic coefficients => identical per-layer choices
        let analytic = Planner::with_registry(&RTX2080TI, reg).plan(&mnist_mlp(), 8);
        let schemes: Vec<_> = plan.layers.iter().map(|l| l.scheme).collect();
        let want: Vec<_> = analytic.layers.iter().map(|l| l.scheme).collect();
        assert_eq!(schemes, want);
    }

    #[test]
    fn search_is_restricted_to_the_registry() {
        // a planner over a single-backend registry can only ever pick
        // that backend's scheme
        let mut reg = BackendRegistry::empty();
        reg.register(Box::new(
            crate::kernels::backends::fastpath::FastpathBackend,
        ));
        let p = Planner::with_registry(&RTX2080TI, Arc::new(reg));
        let plan = p.plan(&mnist_mlp(), 8);
        for lp in &plan.layers {
            assert_eq!(lp.scheme, Scheme::Fastpath);
        }
        assert_eq!(plan.scheme_set, vec!["FASTPATH".to_string()]);
    }
}
