//! The telemetry snapshot: one struct, three renderings.
//!
//! `coordinator::Metrics::snapshot()` materializes everything the
//! serving stack knows — request counters, the bounded latency
//! histogram, engine busy time, plan-cache counters, per-scheme cost
//! drift, per-*layer* attribution (calls, measured vs predicted
//! seconds), and per-*edge* layout-repack traffic — into a
//! [`Snapshot`].  From there:
//!
//! * [`Snapshot::render_report`] — the human one-liner
//!   (`Metrics::report()` delegates here),
//! * [`Snapshot::to_json`] / [`Snapshot::from_json`] — a
//!   round-trippable `engine::json` document,
//! * [`Snapshot::to_prometheus`] — text exposition format.
//!
//! All three read the same struct fields, and the scalar families are
//! enumerated once in [`Snapshot::scalars`] — the field-parity test in
//! `rust/tests/obs_integration.rs` walks that list against every
//! rendering, so a counter added to one face cannot silently miss the
//! others.

use crate::engine::json::Value;
use crate::util::stats::Summary;

/// Snapshot JSON schema version (bump on breaking shape changes).
/// v2 added the fleet-serving fields: `max_batch_rows`, `sheds`,
/// `steals`, the SLO hit/miss counters, and per-shard attribution.
pub const OBS_SCHEMA: u64 = 2;

/// Cumulative per-layer attribution from the arena executor: how often
/// the layer ran, measured wall seconds, and the plan's predicted
/// seconds scaled to each executed batch.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAttr {
    pub index: usize,
    /// display tag ("1024FC", "128C3p", ...)
    pub tag: String,
    /// scheme name the plan selected for this layer
    pub scheme: String,
    pub calls: u64,
    pub secs: f64,
    pub predicted_s: f64,
}

impl LayerAttr {
    /// Measured/predicted ratio (1.0 when there is nothing to compare).
    pub fn drift(&self) -> f64 {
        if self.predicted_s > 0.0 && self.secs > 0.0 {
            self.secs / self.predicted_s
        } else {
            1.0
        }
    }
}

/// Cumulative explicit layout-repack traffic on one plan edge.
#[derive(Clone, Debug, PartialEq)]
pub struct RepackEdge {
    /// consuming layer's index into the plan
    pub layer: usize,
    pub src: String,
    pub dst: String,
    pub ops: u64,
    pub bytes: u64,
    pub secs: f64,
}

/// Per-shard attribution from a `serve::Fleet` model: which replica
/// did the work, and how much of it arrived by stealing.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardAttr {
    pub shard: usize,
    /// requests this shard executed (its own plus stolen ones)
    pub requests: u64,
    pub batches: u64,
    /// steal operations this shard performed against loaded siblings
    pub steals: u64,
}

/// Everything the serving stack reports, in one structure.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    /// end-to-end request throughput (first-to-last batch wall time)
    pub throughput_rps: f64,
    /// fraction of executed rows that were batch padding
    pub padding_frac: f64,
    /// request latency distribution (histogram-derived percentiles)
    pub latency: Summary,
    /// non-empty histogram buckets: (lo_s, hi_s, count)
    pub latency_buckets: Vec<(f64, f64, u64)>,
    pub engine_rows: u64,
    pub engine_busy_s: f64,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub replans: u64,
    /// per-scheme live EWMA: (scheme, measured/predicted, samples)
    pub cost_drift: Vec<(String, f64, u64)>,
    /// per-scheme explicit repack totals: (scheme, ops, bytes)
    pub repacks_by_scheme: Vec<(String, u64, u64)>,
    pub repack_edges: Vec<RepackEdge>,
    pub layers: Vec<LayerAttr>,
    pub traces_pushed: u64,
    pub traces_dropped: u64,
    pub traces_capacity: u64,
    /// largest padded batch executed (the SLO batch sizer's observable)
    pub max_batch_rows: u64,
    /// requests rejected by admission control (rate limit + queue depth)
    pub sheds: u64,
    /// work-steal operations across the model's replica shards
    pub steals: u64,
    /// accepted requests that met the configured p99 deadline
    pub slo_hits: u64,
    /// accepted requests that missed it
    pub slo_misses: u64,
    /// per-shard attribution (empty outside fleet serving)
    pub shards: Vec<ShardAttr>,
}

impl Snapshot {
    /// Engine executor throughput (images per busy-second).
    pub fn engine_img_s(&self) -> f64 {
        if self.engine_busy_s > 0.0 {
            self.engine_rows as f64 / self.engine_busy_s
        } else {
            0.0
        }
    }

    /// The scalar families every rendering must carry — the single
    /// enumeration the field-parity test walks.
    pub fn scalars(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("requests_total", self.requests as f64),
            ("batches_total", self.batches as f64),
            ("throughput_requests_per_second", self.throughput_rps),
            ("padding_fraction", self.padding_frac),
            ("latency_p50_seconds", self.latency.p50),
            ("latency_p90_seconds", self.latency.p90),
            ("latency_p99_seconds", self.latency.p99),
            ("latency_mean_seconds", self.latency.mean),
            ("engine_rows_total", self.engine_rows as f64),
            ("engine_busy_seconds_total", self.engine_busy_s),
            ("engine_images_per_second", self.engine_img_s()),
            ("plan_cache_hits_total", self.plan_cache_hits as f64),
            ("plan_cache_misses_total", self.plan_cache_misses as f64),
            ("replans_total", self.replans as f64),
            ("traces_pushed_total", self.traces_pushed as f64),
            ("traces_dropped_total", self.traces_dropped as f64),
            ("max_batch_rows", self.max_batch_rows as f64),
            ("sheds_total", self.sheds as f64),
            ("steals_total", self.steals as f64),
            ("slo_hits_total", self.slo_hits as f64),
            ("slo_misses_total", self.slo_misses as f64),
        ]
    }

    /// SLO hit fraction over accepted requests (1.0 when no SLO data).
    pub fn slo_hit_rate(&self) -> f64 {
        let total = self.slo_hits + self.slo_misses;
        if total == 0 {
            1.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }

    /// Graft an engine-side snapshot (the served `EngineModel`'s own
    /// `Metrics`) into this server-side snapshot: the server knows
    /// requests/batches/latency/traces, the engine knows busy time,
    /// plan-cache counters, drift, and the per-layer / per-edge
    /// attribution.
    pub fn absorb_engine(&mut self, eng: &Snapshot) {
        self.engine_rows = eng.engine_rows;
        self.engine_busy_s = eng.engine_busy_s;
        self.plan_cache_hits = eng.plan_cache_hits;
        self.plan_cache_misses = eng.plan_cache_misses;
        self.replans = eng.replans;
        self.cost_drift = eng.cost_drift.clone();
        self.repacks_by_scheme = eng.repacks_by_scheme.clone();
        self.repack_edges = eng.repack_edges.clone();
        self.layers = eng.layers.clone();
    }

    /// The human one-line report (`Metrics::report()` renders this).
    pub fn render_report(&self) -> String {
        let s = &self.latency;
        let mut out = format!(
            "requests={} batches={} p50={:.3}ms p90={:.3}ms p99={:.3}ms \
             mean={:.3}ms throughput={:.0} req/s padding={:.1}%",
            self.requests,
            self.batches,
            s.p50 * 1e3,
            s.p90 * 1e3,
            s.p99 * 1e3,
            s.mean * 1e3,
            self.throughput_rps,
            self.padding_frac * 100.0
        );
        if self.engine_rows > 0 {
            out.push_str(&format!(" engine={:.0} img/s", self.engine_img_s()));
        }
        let (h, mi) = (self.plan_cache_hits, self.plan_cache_misses);
        if h + mi > 0 {
            out.push_str(&format!(" plan_cache={h}h/{mi}m"));
        }
        // explicit layout-repack traffic, totalled across schemes
        let (ops, bytes) = self
            .repacks_by_scheme
            .iter()
            .fold((0u64, 0u64), |(o, b), (_, ro, rb)| (o + ro, b + rb));
        if ops > 0 {
            out.push_str(&format!(" repack={ops}ops/{bytes}B"));
        }
        if self.replans > 0 {
            out.push_str(&format!(" replans={}", self.replans));
        }
        if self.sheds > 0 {
            out.push_str(&format!(" sheds={}", self.sheds));
        }
        if self.steals > 0 {
            out.push_str(&format!(" steals={}", self.steals));
        }
        if self.slo_hits + self.slo_misses > 0 {
            out.push_str(&format!(" slo_hit={:.1}%", self.slo_hit_rate() * 100.0));
        }
        // the worst live drift (ratio furthest from 1x in either
        // direction) is the one worth a glance
        let sym = |r: f64| if r > 0.0 { r.max(1.0 / r) } else { 1.0 };
        if let Some((name, ratio, _)) = self
            .cost_drift
            .iter()
            .max_by(|a, b| sym(a.1).partial_cmp(&sym(b.1)).unwrap())
        {
            out.push_str(&format!(" drift[{name}]={ratio:.2}x"));
        }
        // ...and the worst per-LAYER drift, which locates it
        if let Some(l) = self
            .layers
            .iter()
            .filter(|l| l.calls > 0)
            .max_by(|a, b| sym(a.drift()).partial_cmp(&sym(b.drift())).unwrap())
        {
            out.push_str(&format!(" layer_drift[{}]={:.2}x", l.tag, l.drift()));
        }
        out
    }

    /// Serialize via `engine::json` — round-trips exactly through
    /// [`Snapshot::from_json`] (f64 Display is shortest-roundtrip).
    pub fn to_json(&self) -> Value {
        let num = Value::Num;
        let st = |s: &str| Value::Str(s.to_string());
        Value::Obj(vec![
            ("schema".to_string(), num(OBS_SCHEMA as f64)),
            ("requests".to_string(), num(self.requests as f64)),
            ("batches".to_string(), num(self.batches as f64)),
            ("throughput_rps".to_string(), num(self.throughput_rps)),
            ("padding_frac".to_string(), num(self.padding_frac)),
            (
                "latency".to_string(),
                Value::Obj(vec![
                    ("n".to_string(), num(self.latency.n as f64)),
                    ("mean_s".to_string(), num(self.latency.mean)),
                    ("stddev_s".to_string(), num(self.latency.stddev)),
                    ("min_s".to_string(), num(self.latency.min)),
                    ("max_s".to_string(), num(self.latency.max)),
                    ("p50_s".to_string(), num(self.latency.p50)),
                    ("p90_s".to_string(), num(self.latency.p90)),
                    ("p95_s".to_string(), num(self.latency.p95)),
                    ("p99_s".to_string(), num(self.latency.p99)),
                ]),
            ),
            (
                "latency_buckets".to_string(),
                Value::Arr(
                    self.latency_buckets
                        .iter()
                        .map(|(lo, hi, c)| {
                            Value::Obj(vec![
                                ("lo_s".to_string(), num(*lo)),
                                ("hi_s".to_string(), num(*hi)),
                                ("count".to_string(), num(*c as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "engine".to_string(),
                Value::Obj(vec![
                    ("rows".to_string(), num(self.engine_rows as f64)),
                    ("busy_s".to_string(), num(self.engine_busy_s)),
                    ("img_s".to_string(), num(self.engine_img_s())),
                ]),
            ),
            (
                "plan_cache".to_string(),
                Value::Obj(vec![
                    ("hits".to_string(), num(self.plan_cache_hits as f64)),
                    ("misses".to_string(), num(self.plan_cache_misses as f64)),
                ]),
            ),
            ("replans".to_string(), num(self.replans as f64)),
            (
                "cost_drift".to_string(),
                Value::Arr(
                    self.cost_drift
                        .iter()
                        .map(|(name, ratio, samples)| {
                            Value::Obj(vec![
                                ("scheme".to_string(), st(name)),
                                ("ratio".to_string(), num(*ratio)),
                                ("samples".to_string(), num(*samples as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "repacks".to_string(),
                Value::Arr(
                    self.repacks_by_scheme
                        .iter()
                        .map(|(name, ops, bytes)| {
                            Value::Obj(vec![
                                ("scheme".to_string(), st(name)),
                                ("ops".to_string(), num(*ops as f64)),
                                ("bytes".to_string(), num(*bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "repack_edges".to_string(),
                Value::Arr(
                    self.repack_edges
                        .iter()
                        .map(|e| {
                            Value::Obj(vec![
                                ("layer".to_string(), num(e.layer as f64)),
                                ("src".to_string(), st(&e.src)),
                                ("dst".to_string(), st(&e.dst)),
                                ("ops".to_string(), num(e.ops as f64)),
                                ("bytes".to_string(), num(e.bytes as f64)),
                                ("secs".to_string(), num(e.secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "layers".to_string(),
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Value::Obj(vec![
                                ("index".to_string(), num(l.index as f64)),
                                ("tag".to_string(), st(&l.tag)),
                                ("scheme".to_string(), st(&l.scheme)),
                                ("calls".to_string(), num(l.calls as f64)),
                                ("secs".to_string(), num(l.secs)),
                                ("predicted_s".to_string(), num(l.predicted_s)),
                                // derived, for readers; ignored on parse
                                ("drift".to_string(), num(l.drift())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "traces".to_string(),
                Value::Obj(vec![
                    ("pushed".to_string(), num(self.traces_pushed as f64)),
                    ("dropped".to_string(), num(self.traces_dropped as f64)),
                    ("capacity".to_string(), num(self.traces_capacity as f64)),
                ]),
            ),
            ("max_batch_rows".to_string(), num(self.max_batch_rows as f64)),
            (
                "fleet".to_string(),
                Value::Obj(vec![
                    ("sheds".to_string(), num(self.sheds as f64)),
                    ("steals".to_string(), num(self.steals as f64)),
                    ("slo_hits".to_string(), num(self.slo_hits as f64)),
                    ("slo_misses".to_string(), num(self.slo_misses as f64)),
                ]),
            ),
            (
                "shards".to_string(),
                Value::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Value::Obj(vec![
                                ("shard".to_string(), num(s.shard as f64)),
                                ("requests".to_string(), num(s.requests as f64)),
                                ("batches".to_string(), num(s.batches as f64)),
                                ("steals".to_string(), num(s.steals as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a snapshot previously emitted by [`Snapshot::to_json`].
    pub fn from_json(v: &Value) -> Result<Snapshot, String> {
        let schema = req_u64(v, "schema")?;
        if schema != OBS_SCHEMA {
            return Err(format!("obs snapshot schema {schema}, want {OBS_SCHEMA}"));
        }
        let lat = v.get("latency").ok_or("missing latency")?;
        let latency = Summary::from_quantiles(
            req_u64(lat, "n")? as usize,
            req_f64(lat, "mean_s")?,
            req_f64(lat, "stddev_s")?,
            req_f64(lat, "min_s")?,
            req_f64(lat, "max_s")?,
            req_f64(lat, "p50_s")?,
            req_f64(lat, "p90_s")?,
            req_f64(lat, "p95_s")?,
            req_f64(lat, "p99_s")?,
        );
        let eng = v.get("engine").ok_or("missing engine")?;
        let cache = v.get("plan_cache").ok_or("missing plan_cache")?;
        let traces = v.get("traces").ok_or("missing traces")?;
        let fleet = v.get("fleet").ok_or("missing fleet")?;
        Ok(Snapshot {
            requests: req_u64(v, "requests")?,
            batches: req_u64(v, "batches")?,
            throughput_rps: req_f64(v, "throughput_rps")?,
            padding_frac: req_f64(v, "padding_frac")?,
            latency,
            latency_buckets: arr(v, "latency_buckets")?
                .iter()
                .map(|b| {
                    Ok((req_f64(b, "lo_s")?, req_f64(b, "hi_s")?, req_u64(b, "count")?))
                })
                .collect::<Result<_, String>>()?,
            engine_rows: req_u64(eng, "rows")?,
            engine_busy_s: req_f64(eng, "busy_s")?,
            plan_cache_hits: req_u64(cache, "hits")?,
            plan_cache_misses: req_u64(cache, "misses")?,
            replans: req_u64(v, "replans")?,
            cost_drift: arr(v, "cost_drift")?
                .iter()
                .map(|d| {
                    Ok((
                        req_str(d, "scheme")?,
                        req_f64(d, "ratio")?,
                        req_u64(d, "samples")?,
                    ))
                })
                .collect::<Result<_, String>>()?,
            repacks_by_scheme: arr(v, "repacks")?
                .iter()
                .map(|r| {
                    Ok((req_str(r, "scheme")?, req_u64(r, "ops")?, req_u64(r, "bytes")?))
                })
                .collect::<Result<_, String>>()?,
            repack_edges: arr(v, "repack_edges")?
                .iter()
                .map(|e| {
                    Ok(RepackEdge {
                        layer: req_u64(e, "layer")? as usize,
                        src: req_str(e, "src")?,
                        dst: req_str(e, "dst")?,
                        ops: req_u64(e, "ops")?,
                        bytes: req_u64(e, "bytes")?,
                        secs: req_f64(e, "secs")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            layers: arr(v, "layers")?
                .iter()
                .map(|l| {
                    Ok(LayerAttr {
                        index: req_u64(l, "index")? as usize,
                        tag: req_str(l, "tag")?,
                        scheme: req_str(l, "scheme")?,
                        calls: req_u64(l, "calls")?,
                        secs: req_f64(l, "secs")?,
                        predicted_s: req_f64(l, "predicted_s")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            traces_pushed: req_u64(traces, "pushed")?,
            traces_dropped: req_u64(traces, "dropped")?,
            traces_capacity: req_u64(traces, "capacity")?,
            max_batch_rows: req_u64(v, "max_batch_rows")?,
            sheds: req_u64(fleet, "sheds")?,
            steals: req_u64(fleet, "steals")?,
            slo_hits: req_u64(fleet, "slo_hits")?,
            slo_misses: req_u64(fleet, "slo_misses")?,
            shards: arr(v, "shards")?
                .iter()
                .map(|s| {
                    Ok(ShardAttr {
                        shard: req_u64(s, "shard")? as usize,
                        requests: req_u64(s, "requests")?,
                        batches: req_u64(s, "batches")?,
                        steals: req_u64(s, "steals")?,
                    })
                })
                .collect::<Result<_, String>>()?,
        })
    }

    /// Prometheus text exposition.  Scalar families come straight from
    /// [`Snapshot::scalars`]; the labeled families (per scheme, per
    /// layer, per repack edge) and the latency histogram follow.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, value) in self.scalars() {
            let kind =
                if name.ends_with("_total") { "counter" } else { "gauge" };
            out.push_str(&format!("# TYPE tcbnn_{name} {kind}\n"));
            out.push_str(&format!("tcbnn_{name} {value}\n"));
        }
        // request-latency histogram: cumulative counts over the
        // non-empty buckets' upper bounds, then the canonical +Inf
        out.push_str("# TYPE tcbnn_request_latency_seconds histogram\n");
        let mut cum = 0u64;
        for (_, hi, c) in &self.latency_buckets {
            cum += c;
            out.push_str(&format!(
                "tcbnn_request_latency_seconds_bucket{{le=\"{hi}\"}} {cum}\n"
            ));
        }
        out.push_str(&format!(
            "tcbnn_request_latency_seconds_bucket{{le=\"+Inf\"}} {}\n",
            self.latency.n
        ));
        out.push_str(&format!(
            "tcbnn_request_latency_seconds_sum {}\n",
            self.latency.mean * self.latency.n as f64
        ));
        out.push_str(&format!(
            "tcbnn_request_latency_seconds_count {}\n",
            self.latency.n
        ));
        for (scheme, ratio, samples) in &self.cost_drift {
            out.push_str(&format!(
                "tcbnn_cost_drift_ratio{{scheme=\"{scheme}\"}} {ratio}\n"
            ));
            out.push_str(&format!(
                "tcbnn_cost_drift_samples{{scheme=\"{scheme}\"}} {samples}\n"
            ));
        }
        for (scheme, ops, bytes) in &self.repacks_by_scheme {
            out.push_str(&format!(
                "tcbnn_repack_ops_total{{scheme=\"{scheme}\"}} {ops}\n"
            ));
            out.push_str(&format!(
                "tcbnn_repack_bytes_total{{scheme=\"{scheme}\"}} {bytes}\n"
            ));
        }
        for e in &self.repack_edges {
            let lbl = format!(
                "{{layer=\"{}\",src=\"{}\",dst=\"{}\"}}",
                e.layer, e.src, e.dst
            );
            out.push_str(&format!("tcbnn_repack_edge_ops_total{lbl} {}\n", e.ops));
            out.push_str(&format!("tcbnn_repack_edge_bytes_total{lbl} {}\n", e.bytes));
            out.push_str(&format!("tcbnn_repack_edge_seconds_total{lbl} {}\n", e.secs));
        }
        for s in &self.shards {
            let lbl = format!("{{shard=\"{}\"}}", s.shard);
            out.push_str(&format!("tcbnn_shard_requests_total{lbl} {}\n", s.requests));
            out.push_str(&format!("tcbnn_shard_batches_total{lbl} {}\n", s.batches));
            out.push_str(&format!("tcbnn_shard_steals_total{lbl} {}\n", s.steals));
        }
        for l in &self.layers {
            let lbl = format!(
                "{{layer=\"{}\",tag=\"{}\",scheme=\"{}\"}}",
                l.index, l.tag, l.scheme
            );
            out.push_str(&format!("tcbnn_layer_calls_total{lbl} {}\n", l.calls));
            out.push_str(&format!("tcbnn_layer_seconds_total{lbl} {}\n", l.secs));
            out.push_str(&format!(
                "tcbnn_layer_predicted_seconds_total{lbl} {}\n",
                l.predicted_s
            ));
            out.push_str(&format!("tcbnn_layer_drift_ratio{lbl} {}\n", l.drift()));
        }
        out
    }
}

fn req_f64(v: &Value, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| format!("missing/non-numeric field {key:?}"))
}

fn req_u64(v: &Value, key: &str) -> Result<u64, String> {
    let x = req_f64(v, key)?;
    if x >= 0.0 && x.fract() == 0.0 {
        Ok(x as u64)
    } else {
        Err(format!("field {key:?} is not a non-negative integer: {x}"))
    }
}

fn req_str(v: &Value, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing/non-string field {key:?}"))
}

fn arr<'a>(v: &'a Value, key: &str) -> Result<&'a [Value], String> {
    v.get(key)
        .and_then(Value::as_arr)
        .ok_or_else(|| format!("missing/non-array field {key:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            requests: 11,
            batches: 2,
            throughput_rps: 1234.5,
            padding_frac: 0.3125,
            latency: Summary::from_quantiles(
                11, 1.27e-3, 4.0e-4, 1e-3, 2e-3, 1.02e-3, 1.9e-3, 1.95e-3, 2e-3,
            ),
            latency_buckets: vec![(0.96e-3, 1.05e-3, 8), (1.92e-3, 2.1e-3, 3)],
            engine_rows: 16,
            engine_busy_s: 0.004,
            plan_cache_hits: 3,
            plan_cache_misses: 5,
            replans: 1,
            cost_drift: vec![("FASTPATH".to_string(), 1.1, 12)],
            repacks_by_scheme: vec![("FASTPATH".to_string(), 3, 12288)],
            repack_edges: vec![RepackEdge {
                layer: 3,
                src: "Blocked64".to_string(),
                dst: "Row32".to_string(),
                ops: 3,
                bytes: 12288,
                secs: 1.5e-5,
            }],
            layers: vec![LayerAttr {
                index: 0,
                tag: "1024FC".to_string(),
                scheme: "FASTPATH".to_string(),
                calls: 2,
                secs: 0.003,
                predicted_s: 0.001,
            }],
            traces_pushed: 2,
            traces_dropped: 0,
            traces_capacity: 256,
            max_batch_rows: 8,
            sheds: 7,
            steals: 2,
            slo_hits: 9,
            slo_misses: 2,
            shards: vec![
                ShardAttr { shard: 0, requests: 6, batches: 1, steals: 2 },
                ShardAttr { shard: 1, requests: 5, batches: 1, steals: 0 },
            ],
        }
    }

    #[test]
    fn json_round_trips_exactly() {
        let snap = sample();
        let doc = snap.to_json();
        let text = doc.to_string();
        let parsed = Value::parse(&text).expect("valid JSON");
        assert_eq!(parsed, doc, "engine::json round-trip");
        let back = Snapshot::from_json(&parsed).expect("parses back");
        assert_eq!(back, snap, "struct round-trip");
        // the attribution payloads survive the trip
        assert_eq!(back.layers[0].tag, "1024FC");
        assert_eq!(back.repack_edges[0].bytes, 12288);
    }

    #[test]
    fn from_json_rejects_wrong_schema() {
        let mut snap = sample().to_json();
        if let Value::Obj(fields) = &mut snap {
            fields[0].1 = Value::Num(99.0);
        }
        assert!(Snapshot::from_json(&snap).is_err());
    }

    #[test]
    fn report_keeps_the_documented_line_format() {
        let r = sample().render_report();
        assert!(r.contains("requests=11"), "{r}");
        assert!(r.contains("batches=2"), "{r}");
        assert!(r.contains("p50=1.020ms"), "{r}");
        assert!(r.contains("padding=31.2%"), "{r}");
        assert!(r.contains("engine=4000 img/s"), "{r}");
        assert!(r.contains("plan_cache=3h/5m"), "{r}");
        assert!(r.contains("repack=3ops/12288B"), "{r}");
        assert!(r.contains("replans=1"), "{r}");
        assert!(r.contains("sheds=7"), "{r}");
        assert!(r.contains("steals=2"), "{r}");
        assert!(r.contains("slo_hit=81.8%"), "{r}");
        assert!(r.contains("drift[FASTPATH]=1.10x"), "{r}");
        assert!(r.contains("layer_drift[1024FC]=3.00x"), "{r}");
    }

    #[test]
    fn prometheus_exposes_every_scalar_family() {
        let snap = sample();
        let prom = snap.to_prometheus();
        for (name, value) in snap.scalars() {
            let line = format!("tcbnn_{name} {value}");
            assert!(prom.contains(&line), "missing {line:?} in:\n{prom}");
        }
        assert!(prom.contains("tcbnn_request_latency_seconds_bucket{le=\"+Inf\"} 11"));
        assert!(prom.contains(
            "tcbnn_layer_seconds_total{layer=\"0\",tag=\"1024FC\",scheme=\"FASTPATH\"}"
        ));
        assert!(prom.contains(
            "tcbnn_repack_edge_bytes_total{layer=\"3\",src=\"Blocked64\",dst=\"Row32\"} 12288"
        ));
        assert!(prom.contains("tcbnn_shard_requests_total{shard=\"0\"} 6"));
        assert!(prom.contains("tcbnn_shard_steals_total{shard=\"0\"} 2"));
    }

    #[test]
    fn absorb_engine_grafts_engine_side_fields() {
        let eng = sample();
        let mut srv = Snapshot { requests: 100, batches: 9, ..Default::default() };
        srv.absorb_engine(&eng);
        assert_eq!(srv.requests, 100, "server counters kept");
        assert_eq!(srv.engine_rows, 16, "engine counters grafted");
        assert_eq!(srv.layers.len(), 1);
        assert_eq!(srv.repack_edges.len(), 1);
        assert_eq!(srv.plan_cache_hits, 3);
    }

    #[test]
    fn empty_snapshot_is_serializable_and_sane() {
        let snap = Snapshot::default();
        assert_eq!(snap.engine_img_s(), 0.0);
        let text = snap.to_json().to_string();
        let back = Snapshot::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);
        assert!(snap.render_report().contains("requests=0"));
        assert!(!snap.render_report().contains("engine="));
    }
}
