//! The telemetry snapshot: one struct, three renderings.
//!
//! `coordinator::Metrics::snapshot()` materializes everything the
//! serving stack knows — request counters, the bounded latency
//! histogram, engine busy time, plan-cache counters, per-scheme cost
//! drift, per-*layer* attribution (calls, measured vs predicted
//! seconds), and per-*edge* layout-repack traffic — into a
//! [`Snapshot`].  From there:
//!
//! * [`Snapshot::render_report`] — the human one-liner
//!   (`Metrics::report()` delegates here),
//! * [`Snapshot::to_json`] / [`Snapshot::from_json`] — a
//!   round-trippable `engine::json` document,
//! * [`Snapshot::to_prometheus`] — text exposition format.
//!
//! All three read the same struct fields, and the scalar families are
//! enumerated once in [`Snapshot::scalars`] — the field-parity test in
//! `rust/tests/obs_integration.rs` walks that list against every
//! rendering, so a counter added to one face cannot silently miss the
//! others.

use crate::engine::json::Value;
use crate::obs::window::WindowStats;
use crate::util::stats::Summary;

/// Snapshot JSON schema version (bump on breaking shape changes).
/// v2 added the fleet-serving fields: `max_batch_rows`, `sheds`,
/// `steals`, the SLO hit/miss counters, and per-shard attribution.
/// v3 added the live-observability fields: rolling-window stats
/// (`windows`) and per-shard watchdog health (`health`).
/// v4 added `priority_sheds` (requests shed because a low-priority
/// model yielded to shared-host pressure).
/// [`Snapshot::from_json`] still accepts v2/v3 documents (the new
/// fields default to empty/zero).
pub const OBS_SCHEMA: u64 = 4;

/// Oldest schema [`Snapshot::from_json`] accepts.
pub const MIN_OBS_SCHEMA: u64 = 2;

/// Cumulative per-layer attribution from the arena executor: how often
/// the layer ran, measured wall seconds, and the plan's predicted
/// seconds scaled to each executed batch.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAttr {
    pub index: usize,
    /// display tag ("1024FC", "128C3p", ...)
    pub tag: String,
    /// scheme name the plan selected for this layer
    pub scheme: String,
    pub calls: u64,
    pub secs: f64,
    pub predicted_s: f64,
}

impl LayerAttr {
    /// Measured/predicted ratio (1.0 when there is nothing to compare).
    pub fn drift(&self) -> f64 {
        if self.predicted_s > 0.0 && self.secs > 0.0 {
            self.secs / self.predicted_s
        } else {
            1.0
        }
    }
}

/// Cumulative explicit layout-repack traffic on one plan edge.
#[derive(Clone, Debug, PartialEq)]
pub struct RepackEdge {
    /// consuming layer's index into the plan
    pub layer: usize,
    pub src: String,
    pub dst: String,
    pub ops: u64,
    pub bytes: u64,
    pub secs: f64,
}

/// Per-shard attribution from a `serve::Fleet` model: which replica
/// did the work, and how much of it arrived by stealing.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardAttr {
    pub shard: usize,
    /// requests this shard executed (its own plus stolen ones)
    pub requests: u64,
    pub batches: u64,
    /// steal operations this shard performed against loaded siblings
    pub steals: u64,
}

/// Per-shard watchdog health as the snapshot carries it.  The state is
/// a plain string ("healthy" / "degraded" / "stalled") so `obs` stays
/// independent of `serve::health`'s richer enum — the watchdog lowers
/// its classification into this shape when grafting.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardHealthAttr {
    pub shard: usize,
    /// "healthy" | "degraded" | "stalled"
    pub state: String,
    /// classifier's reason, empty when healthy
    pub reason: String,
    /// seconds since the shard's worker last completed a batch (or
    /// touched its heartbeat); 0 when it has not started serving yet
    pub last_batch_age_s: f64,
    /// shard queue depth at probe time
    pub queue_depth: u64,
}

impl ShardHealthAttr {
    /// Up = able to make progress (anything but stalled).
    pub fn is_up(&self) -> bool {
        self.state != "stalled"
    }
}

/// Everything the serving stack reports, in one structure.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    /// end-to-end request throughput (first-to-last batch wall time)
    pub throughput_rps: f64,
    /// fraction of executed rows that were batch padding
    pub padding_frac: f64,
    /// request latency distribution (histogram-derived percentiles)
    pub latency: Summary,
    /// non-empty histogram buckets: (lo_s, hi_s, count)
    pub latency_buckets: Vec<(f64, f64, u64)>,
    pub engine_rows: u64,
    pub engine_busy_s: f64,
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub replans: u64,
    /// per-scheme live EWMA: (scheme, measured/predicted, samples)
    pub cost_drift: Vec<(String, f64, u64)>,
    /// per-scheme explicit repack totals: (scheme, ops, bytes)
    pub repacks_by_scheme: Vec<(String, u64, u64)>,
    pub repack_edges: Vec<RepackEdge>,
    pub layers: Vec<LayerAttr>,
    pub traces_pushed: u64,
    pub traces_dropped: u64,
    pub traces_capacity: u64,
    /// largest padded batch executed (the SLO batch sizer's observable)
    pub max_batch_rows: u64,
    /// requests rejected by admission control (rate limit + queue
    /// depth + priority shedding)
    pub sheds: u64,
    /// the subset of `sheds` rejected because this model is
    /// low-priority and higher-priority models on the host were backed
    /// up (0 before v4 and for priority-0 models)
    pub priority_sheds: u64,
    /// work-steal operations across the model's replica shards
    pub steals: u64,
    /// accepted requests that met the configured p99 deadline
    pub slo_hits: u64,
    /// accepted requests that missed it
    pub slo_misses: u64,
    /// per-shard attribution (empty outside fleet serving)
    pub shards: Vec<ShardAttr>,
    /// rolling-window stats (10s/60s by default; empty before v3 and
    /// in contexts with no windowed recording)
    pub windows: Vec<WindowStats>,
    /// per-shard watchdog health (empty when no watchdog runs)
    pub health: Vec<ShardHealthAttr>,
}

impl Snapshot {
    /// Engine executor throughput (images per busy-second).
    pub fn engine_img_s(&self) -> f64 {
        if self.engine_busy_s > 0.0 {
            self.engine_rows as f64 / self.engine_busy_s
        } else {
            0.0
        }
    }

    /// The scalar families every rendering must carry — the single
    /// enumeration the field-parity test walks.
    pub fn scalars(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("requests_total", self.requests as f64),
            ("batches_total", self.batches as f64),
            ("throughput_requests_per_second", self.throughput_rps),
            ("padding_fraction", self.padding_frac),
            ("latency_p50_seconds", self.latency.p50),
            ("latency_p90_seconds", self.latency.p90),
            ("latency_p99_seconds", self.latency.p99),
            ("latency_mean_seconds", self.latency.mean),
            // min/max render 0 for a zero-request snapshot (the
            // histogram maps its empty-state sentinel to 0, never
            // exposing it) — regression-locked in obs::hist tests
            ("latency_min_seconds", self.latency.min),
            ("latency_max_seconds", self.latency.max),
            ("engine_rows_total", self.engine_rows as f64),
            ("engine_busy_seconds_total", self.engine_busy_s),
            ("engine_images_per_second", self.engine_img_s()),
            ("plan_cache_hits_total", self.plan_cache_hits as f64),
            ("plan_cache_misses_total", self.plan_cache_misses as f64),
            ("replans_total", self.replans as f64),
            ("traces_pushed_total", self.traces_pushed as f64),
            ("traces_dropped_total", self.traces_dropped as f64),
            ("max_batch_rows", self.max_batch_rows as f64),
            ("sheds_total", self.sheds as f64),
            ("priority_sheds_total", self.priority_sheds as f64),
            ("steals_total", self.steals as f64),
            ("slo_hits_total", self.slo_hits as f64),
            ("slo_misses_total", self.slo_misses as f64),
        ]
    }

    /// SLO hit fraction over accepted requests (1.0 when no SLO data).
    pub fn slo_hit_rate(&self) -> f64 {
        let total = self.slo_hits + self.slo_misses;
        if total == 0 {
            1.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }

    /// Graft an engine-side snapshot (the served `EngineModel`'s own
    /// `Metrics`) into this server-side snapshot: the server knows
    /// requests/batches/latency/traces, the engine knows busy time,
    /// plan-cache counters, drift, and the per-layer / per-edge
    /// attribution.
    pub fn absorb_engine(&mut self, eng: &Snapshot) {
        self.engine_rows = eng.engine_rows;
        self.engine_busy_s = eng.engine_busy_s;
        self.plan_cache_hits = eng.plan_cache_hits;
        self.plan_cache_misses = eng.plan_cache_misses;
        self.replans = eng.replans;
        self.cost_drift = eng.cost_drift.clone();
        self.repacks_by_scheme = eng.repacks_by_scheme.clone();
        self.repack_edges = eng.repack_edges.clone();
        self.layers = eng.layers.clone();
    }

    /// The human one-line report (`Metrics::report()` renders this).
    pub fn render_report(&self) -> String {
        let s = &self.latency;
        let mut out = format!(
            "requests={} batches={} p50={:.3}ms p90={:.3}ms p99={:.3}ms \
             mean={:.3}ms throughput={:.0} req/s padding={:.1}%",
            self.requests,
            self.batches,
            s.p50 * 1e3,
            s.p90 * 1e3,
            s.p99 * 1e3,
            s.mean * 1e3,
            self.throughput_rps,
            self.padding_frac * 100.0
        );
        if self.engine_rows > 0 {
            out.push_str(&format!(" engine={:.0} img/s", self.engine_img_s()));
        }
        let (h, mi) = (self.plan_cache_hits, self.plan_cache_misses);
        if h + mi > 0 {
            out.push_str(&format!(" plan_cache={h}h/{mi}m"));
        }
        // explicit layout-repack traffic, totalled across schemes
        let (ops, bytes) = self
            .repacks_by_scheme
            .iter()
            .fold((0u64, 0u64), |(o, b), (_, ro, rb)| (o + ro, b + rb));
        if ops > 0 {
            out.push_str(&format!(" repack={ops}ops/{bytes}B"));
        }
        if self.replans > 0 {
            out.push_str(&format!(" replans={}", self.replans));
        }
        if self.sheds > 0 {
            out.push_str(&format!(" sheds={}", self.sheds));
        }
        if self.priority_sheds > 0 {
            out.push_str(&format!(" priority_sheds={}", self.priority_sheds));
        }
        if self.steals > 0 {
            out.push_str(&format!(" steals={}", self.steals));
        }
        if self.slo_hits + self.slo_misses > 0 {
            out.push_str(&format!(" slo_hit={:.1}%", self.slo_hit_rate() * 100.0));
        }
        // the shortest rolling window is the "what is it doing NOW" view
        if let Some(w) = self.windows.first() {
            out.push_str(&format!(
                " rps[{}]={:.0} p99[{}]={:.3}ms",
                w.label(),
                w.rps,
                w.label(),
                w.p99_s * 1e3
            ));
        }
        let stalled = self.health.iter().filter(|h| !h.is_up()).count();
        if stalled > 0 {
            out.push_str(&format!(" stalled_shards={stalled}"));
        }
        // the worst live drift (ratio furthest from 1x in either
        // direction) is the one worth a glance
        let sym = |r: f64| if r > 0.0 { r.max(1.0 / r) } else { 1.0 };
        if let Some((name, ratio, _)) = self
            .cost_drift
            .iter()
            .max_by(|a, b| sym(a.1).partial_cmp(&sym(b.1)).unwrap())
        {
            out.push_str(&format!(" drift[{name}]={ratio:.2}x"));
        }
        // ...and the worst per-LAYER drift, which locates it
        if let Some(l) = self
            .layers
            .iter()
            .filter(|l| l.calls > 0)
            .max_by(|a, b| sym(a.drift()).partial_cmp(&sym(b.drift())).unwrap())
        {
            out.push_str(&format!(" layer_drift[{}]={:.2}x", l.tag, l.drift()));
        }
        out
    }

    /// Serialize via `engine::json` — round-trips exactly through
    /// [`Snapshot::from_json`] (f64 Display is shortest-roundtrip).
    pub fn to_json(&self) -> Value {
        let num = Value::Num;
        let st = |s: &str| Value::Str(s.to_string());
        Value::Obj(vec![
            ("schema".to_string(), num(OBS_SCHEMA as f64)),
            ("requests".to_string(), num(self.requests as f64)),
            ("batches".to_string(), num(self.batches as f64)),
            ("throughput_rps".to_string(), num(self.throughput_rps)),
            ("padding_frac".to_string(), num(self.padding_frac)),
            (
                "latency".to_string(),
                Value::Obj(vec![
                    ("n".to_string(), num(self.latency.n as f64)),
                    ("mean_s".to_string(), num(self.latency.mean)),
                    ("stddev_s".to_string(), num(self.latency.stddev)),
                    ("min_s".to_string(), num(self.latency.min)),
                    ("max_s".to_string(), num(self.latency.max)),
                    ("p50_s".to_string(), num(self.latency.p50)),
                    ("p90_s".to_string(), num(self.latency.p90)),
                    ("p95_s".to_string(), num(self.latency.p95)),
                    ("p99_s".to_string(), num(self.latency.p99)),
                ]),
            ),
            (
                "latency_buckets".to_string(),
                Value::Arr(
                    self.latency_buckets
                        .iter()
                        .map(|(lo, hi, c)| {
                            Value::Obj(vec![
                                ("lo_s".to_string(), num(*lo)),
                                ("hi_s".to_string(), num(*hi)),
                                ("count".to_string(), num(*c as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "engine".to_string(),
                Value::Obj(vec![
                    ("rows".to_string(), num(self.engine_rows as f64)),
                    ("busy_s".to_string(), num(self.engine_busy_s)),
                    ("img_s".to_string(), num(self.engine_img_s())),
                ]),
            ),
            (
                "plan_cache".to_string(),
                Value::Obj(vec![
                    ("hits".to_string(), num(self.plan_cache_hits as f64)),
                    ("misses".to_string(), num(self.plan_cache_misses as f64)),
                ]),
            ),
            ("replans".to_string(), num(self.replans as f64)),
            (
                "cost_drift".to_string(),
                Value::Arr(
                    self.cost_drift
                        .iter()
                        .map(|(name, ratio, samples)| {
                            Value::Obj(vec![
                                ("scheme".to_string(), st(name)),
                                ("ratio".to_string(), num(*ratio)),
                                ("samples".to_string(), num(*samples as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "repacks".to_string(),
                Value::Arr(
                    self.repacks_by_scheme
                        .iter()
                        .map(|(name, ops, bytes)| {
                            Value::Obj(vec![
                                ("scheme".to_string(), st(name)),
                                ("ops".to_string(), num(*ops as f64)),
                                ("bytes".to_string(), num(*bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "repack_edges".to_string(),
                Value::Arr(
                    self.repack_edges
                        .iter()
                        .map(|e| {
                            Value::Obj(vec![
                                ("layer".to_string(), num(e.layer as f64)),
                                ("src".to_string(), st(&e.src)),
                                ("dst".to_string(), st(&e.dst)),
                                ("ops".to_string(), num(e.ops as f64)),
                                ("bytes".to_string(), num(e.bytes as f64)),
                                ("secs".to_string(), num(e.secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "layers".to_string(),
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Value::Obj(vec![
                                ("index".to_string(), num(l.index as f64)),
                                ("tag".to_string(), st(&l.tag)),
                                ("scheme".to_string(), st(&l.scheme)),
                                ("calls".to_string(), num(l.calls as f64)),
                                ("secs".to_string(), num(l.secs)),
                                ("predicted_s".to_string(), num(l.predicted_s)),
                                // derived, for readers; ignored on parse
                                ("drift".to_string(), num(l.drift())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "traces".to_string(),
                Value::Obj(vec![
                    ("pushed".to_string(), num(self.traces_pushed as f64)),
                    ("dropped".to_string(), num(self.traces_dropped as f64)),
                    ("capacity".to_string(), num(self.traces_capacity as f64)),
                ]),
            ),
            ("max_batch_rows".to_string(), num(self.max_batch_rows as f64)),
            (
                "fleet".to_string(),
                Value::Obj(vec![
                    ("sheds".to_string(), num(self.sheds as f64)),
                    (
                        "priority_sheds".to_string(),
                        num(self.priority_sheds as f64),
                    ),
                    ("steals".to_string(), num(self.steals as f64)),
                    ("slo_hits".to_string(), num(self.slo_hits as f64)),
                    ("slo_misses".to_string(), num(self.slo_misses as f64)),
                ]),
            ),
            (
                "shards".to_string(),
                Value::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Value::Obj(vec![
                                ("shard".to_string(), num(s.shard as f64)),
                                ("requests".to_string(), num(s.requests as f64)),
                                ("batches".to_string(), num(s.batches as f64)),
                                ("steals".to_string(), num(s.steals as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "windows".to_string(),
                Value::Arr(
                    self.windows
                        .iter()
                        .map(|w| {
                            Value::Obj(vec![
                                ("window_s".to_string(), num(w.window_s)),
                                ("requests".to_string(), num(w.requests as f64)),
                                ("sheds".to_string(), num(w.sheds as f64)),
                                ("slo_hits".to_string(), num(w.slo_hits as f64)),
                                (
                                    "slo_misses".to_string(),
                                    num(w.slo_misses as f64),
                                ),
                                ("rps".to_string(), num(w.rps)),
                                ("shed_rps".to_string(), num(w.shed_rps)),
                                ("p50_s".to_string(), num(w.p50_s)),
                                ("p99_s".to_string(), num(w.p99_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "health".to_string(),
                Value::Arr(
                    self.health
                        .iter()
                        .map(|h| {
                            Value::Obj(vec![
                                ("shard".to_string(), num(h.shard as f64)),
                                ("state".to_string(), st(&h.state)),
                                ("reason".to_string(), st(&h.reason)),
                                (
                                    "last_batch_age_s".to_string(),
                                    num(h.last_batch_age_s),
                                ),
                                (
                                    "queue_depth".to_string(),
                                    num(h.queue_depth as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a snapshot previously emitted by [`Snapshot::to_json`].
    pub fn from_json(v: &Value) -> Result<Snapshot, String> {
        let schema = req_u64(v, "schema")?;
        if !(MIN_OBS_SCHEMA..=OBS_SCHEMA).contains(&schema) {
            return Err(format!(
                "obs snapshot schema {schema}, want \
                 {MIN_OBS_SCHEMA}..={OBS_SCHEMA}"
            ));
        }
        let lat = v.get("latency").ok_or("missing latency")?;
        let latency = Summary::from_quantiles(
            req_u64(lat, "n")? as usize,
            req_f64(lat, "mean_s")?,
            req_f64(lat, "stddev_s")?,
            req_f64(lat, "min_s")?,
            req_f64(lat, "max_s")?,
            req_f64(lat, "p50_s")?,
            req_f64(lat, "p90_s")?,
            req_f64(lat, "p95_s")?,
            req_f64(lat, "p99_s")?,
        );
        let eng = v.get("engine").ok_or("missing engine")?;
        let cache = v.get("plan_cache").ok_or("missing plan_cache")?;
        let traces = v.get("traces").ok_or("missing traces")?;
        let fleet = v.get("fleet").ok_or("missing fleet")?;
        Ok(Snapshot {
            requests: req_u64(v, "requests")?,
            batches: req_u64(v, "batches")?,
            throughput_rps: req_f64(v, "throughput_rps")?,
            padding_frac: req_f64(v, "padding_frac")?,
            latency,
            latency_buckets: arr(v, "latency_buckets")?
                .iter()
                .map(|b| {
                    Ok((req_f64(b, "lo_s")?, req_f64(b, "hi_s")?, req_u64(b, "count")?))
                })
                .collect::<Result<_, String>>()?,
            engine_rows: req_u64(eng, "rows")?,
            engine_busy_s: req_f64(eng, "busy_s")?,
            plan_cache_hits: req_u64(cache, "hits")?,
            plan_cache_misses: req_u64(cache, "misses")?,
            replans: req_u64(v, "replans")?,
            cost_drift: arr(v, "cost_drift")?
                .iter()
                .map(|d| {
                    Ok((
                        req_str(d, "scheme")?,
                        req_f64(d, "ratio")?,
                        req_u64(d, "samples")?,
                    ))
                })
                .collect::<Result<_, String>>()?,
            repacks_by_scheme: arr(v, "repacks")?
                .iter()
                .map(|r| {
                    Ok((req_str(r, "scheme")?, req_u64(r, "ops")?, req_u64(r, "bytes")?))
                })
                .collect::<Result<_, String>>()?,
            repack_edges: arr(v, "repack_edges")?
                .iter()
                .map(|e| {
                    Ok(RepackEdge {
                        layer: req_u64(e, "layer")? as usize,
                        src: req_str(e, "src")?,
                        dst: req_str(e, "dst")?,
                        ops: req_u64(e, "ops")?,
                        bytes: req_u64(e, "bytes")?,
                        secs: req_f64(e, "secs")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            layers: arr(v, "layers")?
                .iter()
                .map(|l| {
                    Ok(LayerAttr {
                        index: req_u64(l, "index")? as usize,
                        tag: req_str(l, "tag")?,
                        scheme: req_str(l, "scheme")?,
                        calls: req_u64(l, "calls")?,
                        secs: req_f64(l, "secs")?,
                        predicted_s: req_f64(l, "predicted_s")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            traces_pushed: req_u64(traces, "pushed")?,
            traces_dropped: req_u64(traces, "dropped")?,
            traces_capacity: req_u64(traces, "capacity")?,
            max_batch_rows: req_u64(v, "max_batch_rows")?,
            sheds: req_u64(fleet, "sheds")?,
            // v4 field: absent in v2/v3 documents -> 0
            priority_sheds: opt_u64(fleet, "priority_sheds")?,
            steals: req_u64(fleet, "steals")?,
            slo_hits: req_u64(fleet, "slo_hits")?,
            slo_misses: req_u64(fleet, "slo_misses")?,
            shards: arr(v, "shards")?
                .iter()
                .map(|s| {
                    Ok(ShardAttr {
                        shard: req_u64(s, "shard")? as usize,
                        requests: req_u64(s, "requests")?,
                        batches: req_u64(s, "batches")?,
                        steals: req_u64(s, "steals")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            // v3 fields: absent in v2 documents -> empty
            windows: arr_opt(v, "windows")?
                .iter()
                .map(|w| {
                    Ok(WindowStats {
                        window_s: req_f64(w, "window_s")?,
                        requests: req_u64(w, "requests")?,
                        sheds: req_u64(w, "sheds")?,
                        slo_hits: req_u64(w, "slo_hits")?,
                        slo_misses: req_u64(w, "slo_misses")?,
                        rps: req_f64(w, "rps")?,
                        shed_rps: req_f64(w, "shed_rps")?,
                        p50_s: req_f64(w, "p50_s")?,
                        p99_s: req_f64(w, "p99_s")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            health: arr_opt(v, "health")?
                .iter()
                .map(|h| {
                    Ok(ShardHealthAttr {
                        shard: req_u64(h, "shard")? as usize,
                        state: req_str(h, "state")?,
                        reason: req_str(h, "reason")?,
                        last_batch_age_s: req_f64(h, "last_batch_age_s")?,
                        queue_depth: req_u64(h, "queue_depth")?,
                    })
                })
                .collect::<Result<_, String>>()?,
        })
    }

    /// Prometheus text exposition for this one snapshot (no `model`
    /// label).  Multi-model endpoints render through
    /// [`render_prometheus_fleet`], which prepends `model="..."` to
    /// every sample while keeping each family's samples contiguous —
    /// the exposition format forbids repeating a family block.
    pub fn to_prometheus(&self) -> String {
        render_prometheus(&[(None, self)])
    }
}

/// Render several named snapshots (one per served model) into one
/// Prometheus exposition — what a fleet's `/metrics` endpoint serves.
/// Every sample carries a `model` label; `# HELP`/`# TYPE` headers
/// appear once per family.
pub fn render_prometheus_fleet(models: &[(String, Snapshot)]) -> String {
    let refs: Vec<(Option<&str>, &Snapshot)> =
        models.iter().map(|(n, s)| (Some(n.as_str()), s)).collect();
    render_prometheus(&refs)
}

/// Escape a label value per the exposition format: backslash, double
/// quote, and newline.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// `{model="...",k="v",...}` — empty string when there are no labels.
fn label_set(model: Option<&str>, extra: &[(&str, String)]) -> String {
    let mut pairs: Vec<String> = Vec::new();
    if let Some(m) = model {
        pairs.push(format!("model=\"{}\"", esc(m)));
    }
    for (k, v) in extra {
        pairs.push(format!("{k}=\"{}\"", esc(v)));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

/// One-line help text per family (the `# HELP` line — terse on
/// purpose; docs/OBSERVABILITY.md is the real documentation).
fn family_help(name: &str) -> &'static str {
    match name {
        "requests_total" => "Requests completed since start",
        "batches_total" => "Batches executed since start",
        "throughput_requests_per_second" => "Cumulative request throughput",
        "padding_fraction" => "Fraction of executed rows that were padding",
        "latency_p50_seconds" => "Cumulative latency p50",
        "latency_p90_seconds" => "Cumulative latency p90",
        "latency_p99_seconds" => "Cumulative latency p99",
        "latency_mean_seconds" => "Cumulative latency mean",
        "latency_min_seconds" => "Fastest request (0 when none served)",
        "latency_max_seconds" => "Slowest request (0 when none served)",
        "engine_rows_total" => "Rows the engine executed",
        "engine_busy_seconds_total" => "Engine busy time",
        "engine_images_per_second" => "Engine throughput over busy time",
        "plan_cache_hits_total" => "Plan cache hits",
        "plan_cache_misses_total" => "Plan cache misses",
        "replans_total" => "Live drift-triggered executor rebuilds",
        "traces_pushed_total" => "Batch traces pushed into the ring",
        "traces_dropped_total" => "Batch traces evicted from the ring",
        "max_batch_rows" => "Largest padded batch executed",
        "sheds_total" => "Requests rejected by admission control",
        "priority_sheds_total" => {
            "Low-priority requests shed under shared-host pressure"
        }
        "steals_total" => "Work-steal operations between shards",
        "slo_hits_total" => "Requests that met the SLO deadline",
        "slo_misses_total" => "Requests that missed the SLO deadline",
        "window_requests" => "Requests completed in the window",
        "window_requests_per_second" => "Windowed request throughput",
        "window_sheds" => "Admission sheds in the window",
        "window_sheds_per_second" => "Windowed shed rate",
        "window_latency_p50_seconds" => "Windowed latency p50",
        "window_latency_p99_seconds" => "Windowed latency p99",
        "window_slo_miss_rate" => "SLO miss fraction over the window",
        "request_latency_seconds" => "Request latency distribution",
        "cost_drift_ratio" => "Per-scheme measured/predicted cost ratio",
        "cost_drift_samples" => "Samples behind the drift ratio",
        "repack_ops_total" => "Explicit layout repacks per scheme",
        "repack_bytes_total" => "Bytes repacked per scheme",
        "repack_edge_ops_total" => "Repacks on one plan edge",
        "repack_edge_bytes_total" => "Bytes repacked on one plan edge",
        "repack_edge_seconds_total" => "Seconds spent repacking one edge",
        "shard_requests_total" => "Requests executed by the shard",
        "shard_batches_total" => "Batches executed by the shard",
        "shard_steals_total" => "Steals the shard performed",
        "layer_calls_total" => "Times the layer ran",
        "layer_seconds_total" => "Measured seconds in the layer",
        "layer_predicted_seconds_total" => "Plan-predicted seconds",
        "layer_drift_ratio" => "Layer measured/predicted ratio",
        "shard_up" => "1 unless the watchdog classifies the shard stalled",
        "shard_health_state" => "Watchdog classification (state label)",
        "shard_last_batch_age_seconds" => "Seconds since the shard's last batch",
        "shard_queue_depth" => "Shard queue depth at probe time",
        _ => "tcbnn serving metric",
    }
}

/// The family-major renderer behind [`Snapshot::to_prometheus`] and
/// [`render_prometheus_fleet`].
fn render_prometheus(models: &[(Option<&str>, &Snapshot)]) -> String {
    let mut out = String::new();
    let Some((_, first)) = models.first() else { return out };
    let header = |out: &mut String, name: &str, kind: &str| {
        out.push_str(&format!("# HELP tcbnn_{name} {}\n", family_help(name)));
        out.push_str(&format!("# TYPE tcbnn_{name} {kind}\n"));
    };
    // scalar families come straight from `Snapshot::scalars` — the
    // field-parity test's single enumeration
    for (i, (name, _)) in first.scalars().iter().enumerate() {
        let kind = if name.ends_with("_total") { "counter" } else { "gauge" };
        header(&mut out, name, kind);
        for (model, snap) in models {
            let (_, value) = snap.scalars()[i];
            out.push_str(&format!(
                "tcbnn_{name}{} {value}\n",
                label_set(*model, &[])
            ));
        }
    }
    // rolling windows, one sample per (model, window)
    type WinGet = fn(&WindowStats) -> f64;
    let window_fams: [(&str, WinGet); 7] = [
        ("window_requests", |w| w.requests as f64),
        ("window_requests_per_second", |w| w.rps),
        ("window_sheds", |w| w.sheds as f64),
        ("window_sheds_per_second", |w| w.shed_rps),
        ("window_latency_p50_seconds", |w| w.p50_s),
        ("window_latency_p99_seconds", |w| w.p99_s),
        ("window_slo_miss_rate", |w| w.slo_miss_rate()),
    ];
    if models.iter().any(|(_, s)| !s.windows.is_empty()) {
        for (name, get) in window_fams {
            header(&mut out, name, "gauge");
            for (model, snap) in models {
                for w in &snap.windows {
                    out.push_str(&format!(
                        "tcbnn_{name}{} {}\n",
                        label_set(*model, &[("window", w.label())]),
                        get(w)
                    ));
                }
            }
        }
    }
    // request-latency histogram: cumulative counts over the non-empty
    // buckets' upper bounds, then the canonical +Inf
    header(&mut out, "request_latency_seconds", "histogram");
    for (model, snap) in models {
        let mut cum = 0u64;
        for (_, hi, c) in &snap.latency_buckets {
            cum += c;
            out.push_str(&format!(
                "tcbnn_request_latency_seconds_bucket{} {cum}\n",
                label_set(*model, &[("le", hi.to_string())])
            ));
        }
        out.push_str(&format!(
            "tcbnn_request_latency_seconds_bucket{} {}\n",
            label_set(*model, &[("le", "+Inf".to_string())]),
            snap.latency.n
        ));
        out.push_str(&format!(
            "tcbnn_request_latency_seconds_sum{} {}\n",
            label_set(*model, &[]),
            snap.latency.mean * snap.latency.n as f64
        ));
        out.push_str(&format!(
            "tcbnn_request_latency_seconds_count{} {}\n",
            label_set(*model, &[]),
            snap.latency.n
        ));
    }
    // labeled attribution families — headers only when some model has
    // samples (an empty family block is legal but noise)
    if models.iter().any(|(_, s)| !s.cost_drift.is_empty()) {
        header(&mut out, "cost_drift_ratio", "gauge");
        for (model, snap) in models {
            for (scheme, ratio, _) in &snap.cost_drift {
                out.push_str(&format!(
                    "tcbnn_cost_drift_ratio{} {ratio}\n",
                    label_set(*model, &[("scheme", scheme.clone())])
                ));
            }
        }
        header(&mut out, "cost_drift_samples", "gauge");
        for (model, snap) in models {
            for (scheme, _, samples) in &snap.cost_drift {
                out.push_str(&format!(
                    "tcbnn_cost_drift_samples{} {samples}\n",
                    label_set(*model, &[("scheme", scheme.clone())])
                ));
            }
        }
    }
    if models.iter().any(|(_, s)| !s.repacks_by_scheme.is_empty()) {
        header(&mut out, "repack_ops_total", "counter");
        for (model, snap) in models {
            for (scheme, ops, _) in &snap.repacks_by_scheme {
                out.push_str(&format!(
                    "tcbnn_repack_ops_total{} {ops}\n",
                    label_set(*model, &[("scheme", scheme.clone())])
                ));
            }
        }
        header(&mut out, "repack_bytes_total", "counter");
        for (model, snap) in models {
            for (scheme, _, bytes) in &snap.repacks_by_scheme {
                out.push_str(&format!(
                    "tcbnn_repack_bytes_total{} {bytes}\n",
                    label_set(*model, &[("scheme", scheme.clone())])
                ));
            }
        }
    }
    if models.iter().any(|(_, s)| !s.repack_edges.is_empty()) {
        let edge_labels = |e: &RepackEdge, model: Option<&str>| {
            label_set(
                model,
                &[
                    ("layer", e.layer.to_string()),
                    ("src", e.src.clone()),
                    ("dst", e.dst.clone()),
                ],
            )
        };
        header(&mut out, "repack_edge_ops_total", "counter");
        for (model, snap) in models {
            for e in &snap.repack_edges {
                out.push_str(&format!(
                    "tcbnn_repack_edge_ops_total{} {}\n",
                    edge_labels(e, *model),
                    e.ops
                ));
            }
        }
        header(&mut out, "repack_edge_bytes_total", "counter");
        for (model, snap) in models {
            for e in &snap.repack_edges {
                out.push_str(&format!(
                    "tcbnn_repack_edge_bytes_total{} {}\n",
                    edge_labels(e, *model),
                    e.bytes
                ));
            }
        }
        header(&mut out, "repack_edge_seconds_total", "counter");
        for (model, snap) in models {
            for e in &snap.repack_edges {
                out.push_str(&format!(
                    "tcbnn_repack_edge_seconds_total{} {}\n",
                    edge_labels(e, *model),
                    e.secs
                ));
            }
        }
    }
    if models.iter().any(|(_, s)| !s.shards.is_empty()) {
        header(&mut out, "shard_requests_total", "counter");
        for (model, snap) in models {
            for s in &snap.shards {
                out.push_str(&format!(
                    "tcbnn_shard_requests_total{} {}\n",
                    label_set(*model, &[("shard", s.shard.to_string())]),
                    s.requests
                ));
            }
        }
        header(&mut out, "shard_batches_total", "counter");
        for (model, snap) in models {
            for s in &snap.shards {
                out.push_str(&format!(
                    "tcbnn_shard_batches_total{} {}\n",
                    label_set(*model, &[("shard", s.shard.to_string())]),
                    s.batches
                ));
            }
        }
        header(&mut out, "shard_steals_total", "counter");
        for (model, snap) in models {
            for s in &snap.shards {
                out.push_str(&format!(
                    "tcbnn_shard_steals_total{} {}\n",
                    label_set(*model, &[("shard", s.shard.to_string())]),
                    s.steals
                ));
            }
        }
    }
    if models.iter().any(|(_, s)| !s.layers.is_empty()) {
        let layer_labels = |l: &LayerAttr, model: Option<&str>| {
            label_set(
                model,
                &[
                    ("layer", l.index.to_string()),
                    ("tag", l.tag.clone()),
                    ("scheme", l.scheme.clone()),
                ],
            )
        };
        header(&mut out, "layer_calls_total", "counter");
        for (model, snap) in models {
            for l in &snap.layers {
                out.push_str(&format!(
                    "tcbnn_layer_calls_total{} {}\n",
                    layer_labels(l, *model),
                    l.calls
                ));
            }
        }
        header(&mut out, "layer_seconds_total", "counter");
        for (model, snap) in models {
            for l in &snap.layers {
                out.push_str(&format!(
                    "tcbnn_layer_seconds_total{} {}\n",
                    layer_labels(l, *model),
                    l.secs
                ));
            }
        }
        header(&mut out, "layer_predicted_seconds_total", "counter");
        for (model, snap) in models {
            for l in &snap.layers {
                out.push_str(&format!(
                    "tcbnn_layer_predicted_seconds_total{} {}\n",
                    layer_labels(l, *model),
                    l.predicted_s
                ));
            }
        }
        header(&mut out, "layer_drift_ratio", "gauge");
        for (model, snap) in models {
            for l in &snap.layers {
                out.push_str(&format!(
                    "tcbnn_layer_drift_ratio{} {}\n",
                    layer_labels(l, *model),
                    l.drift()
                ));
            }
        }
    }
    if models.iter().any(|(_, s)| !s.health.is_empty()) {
        header(&mut out, "shard_up", "gauge");
        for (model, snap) in models {
            for h in &snap.health {
                out.push_str(&format!(
                    "tcbnn_shard_up{} {}\n",
                    label_set(*model, &[("shard", h.shard.to_string())]),
                    if h.is_up() { 1 } else { 0 }
                ));
            }
        }
        header(&mut out, "shard_health_state", "gauge");
        for (model, snap) in models {
            for h in &snap.health {
                out.push_str(&format!(
                    "tcbnn_shard_health_state{} 1\n",
                    label_set(
                        *model,
                        &[
                            ("shard", h.shard.to_string()),
                            ("state", h.state.clone()),
                            ("reason", h.reason.clone()),
                        ]
                    )
                ));
            }
        }
        header(&mut out, "shard_last_batch_age_seconds", "gauge");
        for (model, snap) in models {
            for h in &snap.health {
                out.push_str(&format!(
                    "tcbnn_shard_last_batch_age_seconds{} {}\n",
                    label_set(*model, &[("shard", h.shard.to_string())]),
                    h.last_batch_age_s
                ));
            }
        }
        header(&mut out, "shard_queue_depth", "gauge");
        for (model, snap) in models {
            for h in &snap.health {
                out.push_str(&format!(
                    "tcbnn_shard_queue_depth{} {}\n",
                    label_set(*model, &[("shard", h.shard.to_string())]),
                    h.queue_depth
                ));
            }
        }
    }
    out
}

fn req_f64(v: &Value, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| format!("missing/non-numeric field {key:?}"))
}

fn req_u64(v: &Value, key: &str) -> Result<u64, String> {
    let x = req_f64(v, key)?;
    if x >= 0.0 && x.fract() == 0.0 {
        Ok(x as u64)
    } else {
        Err(format!("field {key:?} is not a non-negative integer: {x}"))
    }
}

/// Like [`req_u64`] but an absent key reads as 0 — for counters added
/// after `MIN_OBS_SCHEMA` (v2/v3 documents lack `priority_sheds`).
fn opt_u64(v: &Value, key: &str) -> Result<u64, String> {
    match v.get(key) {
        None => Ok(0),
        Some(_) => req_u64(v, key),
    }
}

fn req_str(v: &Value, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing/non-string field {key:?}"))
}

fn arr<'a>(v: &'a Value, key: &str) -> Result<&'a [Value], String> {
    v.get(key)
        .and_then(Value::as_arr)
        .ok_or_else(|| format!("missing/non-array field {key:?}"))
}

/// Like [`arr`] but an absent key reads as an empty array — for fields
/// added after `MIN_OBS_SCHEMA` (v2 documents lack `windows`/`health`).
fn arr_opt<'a>(v: &'a Value, key: &str) -> Result<&'a [Value], String> {
    match v.get(key) {
        None => Ok(&[]),
        Some(x) => x
            .as_arr()
            .ok_or_else(|| format!("non-array field {key:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            requests: 11,
            batches: 2,
            throughput_rps: 1234.5,
            padding_frac: 0.3125,
            latency: Summary::from_quantiles(
                11, 1.27e-3, 4.0e-4, 1e-3, 2e-3, 1.02e-3, 1.9e-3, 1.95e-3, 2e-3,
            ),
            latency_buckets: vec![(0.96e-3, 1.05e-3, 8), (1.92e-3, 2.1e-3, 3)],
            engine_rows: 16,
            engine_busy_s: 0.004,
            plan_cache_hits: 3,
            plan_cache_misses: 5,
            replans: 1,
            cost_drift: vec![("FASTPATH".to_string(), 1.1, 12)],
            repacks_by_scheme: vec![("FASTPATH".to_string(), 3, 12288)],
            repack_edges: vec![RepackEdge {
                layer: 3,
                src: "Blocked64".to_string(),
                dst: "Row32".to_string(),
                ops: 3,
                bytes: 12288,
                secs: 1.5e-5,
            }],
            layers: vec![LayerAttr {
                index: 0,
                tag: "1024FC".to_string(),
                scheme: "FASTPATH".to_string(),
                calls: 2,
                secs: 0.003,
                predicted_s: 0.001,
            }],
            traces_pushed: 2,
            traces_dropped: 0,
            traces_capacity: 256,
            max_batch_rows: 8,
            sheds: 7,
            priority_sheds: 3,
            steals: 2,
            slo_hits: 9,
            slo_misses: 2,
            shards: vec![
                ShardAttr { shard: 0, requests: 6, batches: 1, steals: 2 },
                ShardAttr { shard: 1, requests: 5, batches: 1, steals: 0 },
            ],
            windows: vec![WindowStats {
                window_s: 10.0,
                requests: 4,
                sheds: 1,
                slo_hits: 3,
                slo_misses: 1,
                rps: 0.4,
                shed_rps: 0.1,
                p50_s: 1.0e-3,
                p99_s: 2.0e-3,
            }],
            health: vec![
                ShardHealthAttr {
                    shard: 0,
                    state: "healthy".to_string(),
                    reason: String::new(),
                    last_batch_age_s: 0.5,
                    queue_depth: 2,
                },
                ShardHealthAttr {
                    shard: 1,
                    state: "stalled".to_string(),
                    reason: "no heartbeat for 1.2s".to_string(),
                    last_batch_age_s: 1.2,
                    queue_depth: 7,
                },
            ],
        }
    }

    #[test]
    fn json_round_trips_exactly() {
        let snap = sample();
        let doc = snap.to_json();
        let text = doc.to_string();
        let parsed = Value::parse(&text).expect("valid JSON");
        assert_eq!(parsed, doc, "engine::json round-trip");
        let back = Snapshot::from_json(&parsed).expect("parses back");
        assert_eq!(back, snap, "struct round-trip");
        // the attribution payloads survive the trip
        assert_eq!(back.layers[0].tag, "1024FC");
        assert_eq!(back.repack_edges[0].bytes, 12288);
    }

    #[test]
    fn from_json_rejects_wrong_schema() {
        let mut snap = sample().to_json();
        if let Value::Obj(fields) = &mut snap {
            fields[0].1 = Value::Num(99.0);
        }
        assert!(Snapshot::from_json(&snap).is_err());
    }

    #[test]
    fn report_keeps_the_documented_line_format() {
        let r = sample().render_report();
        assert!(r.contains("requests=11"), "{r}");
        assert!(r.contains("batches=2"), "{r}");
        assert!(r.contains("p50=1.020ms"), "{r}");
        assert!(r.contains("padding=31.2%"), "{r}");
        assert!(r.contains("engine=4000 img/s"), "{r}");
        assert!(r.contains("plan_cache=3h/5m"), "{r}");
        assert!(r.contains("repack=3ops/12288B"), "{r}");
        assert!(r.contains("replans=1"), "{r}");
        assert!(r.contains("sheds=7"), "{r}");
        assert!(r.contains("priority_sheds=3"), "{r}");
        assert!(r.contains("steals=2"), "{r}");
        assert!(r.contains("slo_hit=81.8%"), "{r}");
        assert!(r.contains("drift[FASTPATH]=1.10x"), "{r}");
        assert!(r.contains("layer_drift[1024FC]=3.00x"), "{r}");
    }

    #[test]
    fn prometheus_exposes_every_scalar_family() {
        let snap = sample();
        let prom = snap.to_prometheus();
        for (name, value) in snap.scalars() {
            let line = format!("tcbnn_{name} {value}");
            assert!(prom.contains(&line), "missing {line:?} in:\n{prom}");
        }
        assert!(prom.contains("tcbnn_request_latency_seconds_bucket{le=\"+Inf\"} 11"));
        assert!(prom.contains(
            "tcbnn_layer_seconds_total{layer=\"0\",tag=\"1024FC\",scheme=\"FASTPATH\"}"
        ));
        assert!(prom.contains(
            "tcbnn_repack_edge_bytes_total{layer=\"3\",src=\"Blocked64\",dst=\"Row32\"} 12288"
        ));
        assert!(prom.contains("tcbnn_shard_requests_total{shard=\"0\"} 6"));
        assert!(prom.contains("tcbnn_shard_steals_total{shard=\"0\"} 2"));
    }

    #[test]
    fn from_json_accepts_v2_documents() {
        // a PR-8 era dump: schema 2, no windows/health keys, and no
        // priority_sheds counter inside the fleet object
        let mut doc = sample().to_json();
        if let Value::Obj(fields) = &mut doc {
            fields[0].1 = Value::Num(2.0);
            fields.retain(|(k, _)| k != "windows" && k != "health");
            if let Some((_, Value::Obj(fleet))) =
                fields.iter_mut().find(|(k, _)| k == "fleet")
            {
                fleet.retain(|(k, _)| k != "priority_sheds");
            }
        }
        let snap = Snapshot::from_json(&doc).expect("v2 still parses");
        assert_eq!(snap.requests, 11);
        assert!(snap.windows.is_empty(), "v3 fields default empty");
        assert!(snap.health.is_empty());
        assert_eq!(snap.priority_sheds, 0, "v4 counter defaults to 0");
    }

    #[test]
    fn from_json_accepts_v3_documents_without_priority_sheds() {
        let mut doc = sample().to_json();
        if let Value::Obj(fields) = &mut doc {
            fields[0].1 = Value::Num(3.0);
            if let Some((_, Value::Obj(fleet))) =
                fields.iter_mut().find(|(k, _)| k == "fleet")
            {
                fleet.retain(|(k, _)| k != "priority_sheds");
            }
        }
        let snap = Snapshot::from_json(&doc).expect("v3 still parses");
        assert_eq!(snap.sheds, 7, "other fleet counters intact");
        assert_eq!(snap.priority_sheds, 0, "absent counter reads as 0");
    }

    #[test]
    fn prometheus_has_help_and_renders_window_and_health_families() {
        let prom = sample().to_prometheus();
        assert!(prom.contains("# HELP tcbnn_requests_total "), "{prom}");
        assert!(prom.contains("# TYPE tcbnn_requests_total counter"));
        assert!(prom.contains("# HELP tcbnn_window_requests_per_second "));
        assert!(
            prom.contains("tcbnn_window_requests_per_second{window=\"10s\"} 0.4"),
            "{prom}"
        );
        assert!(prom
            .contains("tcbnn_window_latency_p99_seconds{window=\"10s\"} 0.002"));
        assert!(prom.contains("tcbnn_window_slo_miss_rate{window=\"10s\"} 0.25"));
        assert!(prom.contains("tcbnn_shard_up{shard=\"0\"} 1"));
        assert!(prom.contains("tcbnn_shard_up{shard=\"1\"} 0"));
        assert!(prom.contains(
            "tcbnn_shard_health_state{shard=\"1\",state=\"stalled\",\
             reason=\"no heartbeat for 1.2s\"} 1"
        ));
        assert!(prom.contains("tcbnn_shard_queue_depth{shard=\"1\"} 7"));
        // satellite: min/max are scalar families now
        assert!(prom.contains("tcbnn_latency_min_seconds 0.001"));
        assert!(prom.contains("tcbnn_latency_max_seconds 0.002"));
    }

    #[test]
    fn prometheus_escapes_label_values() {
        let snap = Snapshot {
            cost_drift: vec![("BAD\"NAME\\".to_string(), 1.0, 1)],
            ..Default::default()
        };
        let prom = snap.to_prometheus();
        assert!(
            prom.contains("tcbnn_cost_drift_ratio{scheme=\"BAD\\\"NAME\\\\\"} 1"),
            "{prom}"
        );
    }

    #[test]
    fn fleet_rendering_is_family_major_with_model_labels() {
        let a = sample();
        let b = Snapshot { requests: 3, ..Default::default() };
        let prom = render_prometheus_fleet(&[
            ("mnist".to_string(), a),
            ("cifar".to_string(), b),
        ]);
        assert!(prom.contains("tcbnn_requests_total{model=\"mnist\"} 11"));
        assert!(prom.contains("tcbnn_requests_total{model=\"cifar\"} 3"));
        // one header per family even with two models, and the family's
        // samples directly follow it (exposition forbids re-opening a
        // family block)
        assert_eq!(
            prom.matches("# TYPE tcbnn_requests_total counter").count(),
            1
        );
        let idx = prom.find("# TYPE tcbnn_requests_total counter").unwrap();
        let lines: Vec<&str> = prom[idx..].lines().take(3).collect();
        assert!(lines[1].starts_with("tcbnn_requests_total{model=\"mnist\"}"));
        assert!(lines[2].starts_with("tcbnn_requests_total{model=\"cifar\"}"));
        // labeled families compose the model label with their own
        assert!(prom.contains(
            "tcbnn_layer_seconds_total{model=\"mnist\",layer=\"0\",\
             tag=\"1024FC\",scheme=\"FASTPATH\"}"
        ));
    }

    #[test]
    fn absorb_engine_grafts_engine_side_fields() {
        let eng = sample();
        let mut srv = Snapshot { requests: 100, batches: 9, ..Default::default() };
        srv.absorb_engine(&eng);
        assert_eq!(srv.requests, 100, "server counters kept");
        assert_eq!(srv.engine_rows, 16, "engine counters grafted");
        assert_eq!(srv.layers.len(), 1);
        assert_eq!(srv.repack_edges.len(), 1);
        assert_eq!(srv.plan_cache_hits, 3);
    }

    #[test]
    fn empty_snapshot_is_serializable_and_sane() {
        let snap = Snapshot::default();
        assert_eq!(snap.engine_img_s(), 0.0);
        let text = snap.to_json().to_string();
        let back = Snapshot::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);
        assert!(snap.render_report().contains("requests=0"));
        assert!(!snap.render_report().contains("engine="));
        // satellite regression: a zero-request snapshot renders 0 for
        // min/max in every face — never the histogram's init sentinel
        assert_eq!(snap.latency.min, 0.0);
        assert_eq!(snap.latency.max, 0.0);
        let prom = snap.to_prometheus();
        assert!(prom.contains("tcbnn_latency_min_seconds 0\n"), "{prom}");
        assert!(prom.contains("tcbnn_latency_max_seconds 0\n"), "{prom}");
        let json = snap.to_json().to_string();
        assert!(json.contains("\"min_s\":0"), "{json}");
        assert!(json.contains("\"max_s\":0"), "{json}");
    }
}
