//! Rolling-window telemetry: rates and quantiles over the *last N
//! seconds*, not since process start.
//!
//! The cumulative counters in `coordinator::Metrics` answer "what has
//! this process done since boot" — useless for operating a running
//! fleet, where the question is "what is it doing *now*".  This module
//! adds windowed views with the same bounded-memory contract as the
//! rest of `obs`:
//!
//! * [`WindowedCounter`] — a ring of per-epoch counters rotated by a
//!   coarse clock tick.  Recording is two relaxed atomic ops on the
//!   hot path; rotation (once per epoch) takes a tiny mutex.
//! * [`WindowedHistogram`] — the same ring with a full
//!   [`LogHistogram`] per epoch, merged on read into one histogram
//!   covering the window.  Constant memory: `SLOTS` histograms,
//!   ~`SLOTS * 3KB`, forever.
//! * [`Windows`] — the bundle `coordinator::Metrics` embeds: request /
//!   shed / SLO counters plus a latency histogram, summarized into
//!   [`WindowStats`] rows (one per reporting window, 10s and 60s by
//!   default) that `Snapshot` carries and `/metrics` exposes.
//!
//! Epoch geometry: 2-second epochs, 33 slots — enough to serve a 60s
//! window (30 full epochs + the current partial one) with margin.  A
//! slot is reused only after its epoch has aged out of every window,
//! so merged reads never mix a stale epoch into a fresh one: each slot
//! stores the epoch id it belongs to and readers filter by it.
//!
//! All record/read methods take an explicit `now: Instant` (`*_at`
//! variants) so tests can drive the clock deterministically; the
//! convenience wrappers use `Instant::now()`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::obs::hist::LogHistogram;

/// Epoch length: the rotation tick.  Coarse on purpose — rotation is
/// the only synchronized step.
pub const EPOCH: Duration = Duration::from_secs(2);
/// Ring slots: 60s window = 30 full epochs + the current partial one,
/// plus margin so an in-progress rotation never clobbers a slot a
/// reader still needs.
pub const SLOTS: usize = 33;
/// The reporting windows `Windows::stats_all` summarizes (and
/// `/metrics` exposes as the `window` label).
pub const REPORT_WINDOWS: [Duration; 2] =
    [Duration::from_secs(10), Duration::from_secs(60)];

/// Maps instants onto epoch ids (monotone, starts at 0).
#[derive(Clone, Copy, Debug)]
struct Clock {
    start: Instant,
    epoch: Duration,
}

impl Clock {
    fn epoch_id(&self, now: Instant) -> u64 {
        let dt = now.saturating_duration_since(self.start);
        (dt.as_nanos() / self.epoch.as_nanos().max(1)) as u64
    }

    /// Epochs a window spans, counting the current partial epoch.
    fn window_epochs(&self, window: Duration) -> u64 {
        let e = self.epoch.as_nanos().max(1);
        let w = window.as_nanos();
        (((w + e - 1) / e) as u64).max(1)
    }

    /// The denominator for a windowed rate: the window, clamped to the
    /// time actually elapsed (so an early scrape is not understated),
    /// floored at 1ms (so a scrape right after start is not a division
    /// by ~zero).
    fn rate_denom(&self, window: Duration, now: Instant) -> f64 {
        let elapsed = now.saturating_duration_since(self.start);
        window.min(elapsed).max(Duration::from_millis(1)).as_secs_f64()
    }
}

struct CounterSlot {
    /// epoch id this slot's count belongs to
    epoch: AtomicU64,
    count: AtomicU64,
}

/// A windowed event counter: `record` on the hot path, `count(window)`
/// / `rate(window)` on the scrape path.
pub struct WindowedCounter {
    clock: Clock,
    slots: Vec<CounterSlot>,
    /// serializes slot rotation (cold: once per epoch per slot)
    rotate: Mutex<()>,
    /// cumulative total (all epochs ever) — lets one counter serve
    /// both the windowed and the lifetime view
    total: AtomicU64,
}

impl WindowedCounter {
    pub fn new() -> WindowedCounter {
        WindowedCounter::with_geometry(Instant::now(), EPOCH, SLOTS)
    }

    /// Test constructor: explicit start / epoch / slot count.
    pub fn with_geometry(
        start: Instant,
        epoch: Duration,
        slots: usize,
    ) -> WindowedCounter {
        assert!(slots >= 2, "windowed counter needs at least two slots");
        WindowedCounter {
            clock: Clock { start, epoch },
            slots: (0..slots)
                .map(|_| CounterSlot {
                    // sentinel: no slot pre-claims epoch 0 except slot 0,
                    // whose count starts at 0 anyway
                    epoch: AtomicU64::new(u64::MAX),
                    count: AtomicU64::new(0),
                })
                .collect(),
            rotate: Mutex::new(()),
            total: AtomicU64::new(0),
        }
    }

    pub fn add(&self, n: u64) {
        self.add_at(n, Instant::now());
    }

    pub fn add_at(&self, n: u64, now: Instant) {
        let e = self.clock.epoch_id(now);
        let slot = &self.slots[(e % self.slots.len() as u64) as usize];
        if slot.epoch.load(Ordering::Acquire) != e {
            // cold path: claim the slot for this epoch under the lock
            let _g = self.rotate.lock().unwrap();
            if slot.epoch.load(Ordering::Acquire) != e {
                slot.count.store(0, Ordering::Relaxed);
                slot.epoch.store(e, Ordering::Release);
            }
        }
        slot.count.fetch_add(n, Ordering::Relaxed);
        self.total.fetch_add(n, Ordering::Relaxed);
    }

    /// Lifetime total across every epoch ever recorded.
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn count(&self, window: Duration) -> u64 {
        self.count_at(window, Instant::now())
    }

    /// Events recorded in the last `window` (epoch-granular: includes
    /// the current partial epoch and the full epochs before it).
    pub fn count_at(&self, window: Duration, now: Instant) -> u64 {
        let e_now = self.clock.epoch_id(now);
        let k = self.clock.window_epochs(window);
        let oldest = e_now.saturating_sub(k.saturating_sub(1));
        self.slots
            .iter()
            .filter(|s| {
                let se = s.epoch.load(Ordering::Acquire);
                se != u64::MAX && se >= oldest && se <= e_now
            })
            .map(|s| s.count.load(Ordering::Relaxed))
            .sum()
    }

    pub fn rate(&self, window: Duration) -> f64 {
        self.rate_at(window, Instant::now())
    }

    /// Events per second over the last `window` (denominator clamps to
    /// the elapsed time so early reads are not understated).
    pub fn rate_at(&self, window: Duration, now: Instant) -> f64 {
        self.count_at(window, now) as f64 / self.clock.rate_denom(window, now)
    }
}

impl Default for WindowedCounter {
    fn default() -> Self {
        WindowedCounter::new()
    }
}

struct HistSlot {
    epoch: AtomicU64,
    hist: LogHistogram,
}

/// A windowed latency histogram: per-epoch [`LogHistogram`]s, merged
/// on read into one histogram covering the window.
pub struct WindowedHistogram {
    clock: Clock,
    slots: Vec<HistSlot>,
    rotate: Mutex<()>,
}

impl WindowedHistogram {
    pub fn new() -> WindowedHistogram {
        WindowedHistogram::with_geometry(Instant::now(), EPOCH, SLOTS)
    }

    pub fn with_geometry(
        start: Instant,
        epoch: Duration,
        slots: usize,
    ) -> WindowedHistogram {
        assert!(slots >= 2, "windowed histogram needs at least two slots");
        WindowedHistogram {
            clock: Clock { start, epoch },
            slots: (0..slots)
                .map(|_| HistSlot {
                    epoch: AtomicU64::new(u64::MAX),
                    hist: LogHistogram::new(),
                })
                .collect(),
            rotate: Mutex::new(()),
        }
    }

    pub fn record(&self, secs: f64) {
        self.record_at(secs, Instant::now());
    }

    pub fn record_at(&self, secs: f64, now: Instant) {
        let e = self.clock.epoch_id(now);
        let slot = &self.slots[(e % self.slots.len() as u64) as usize];
        if slot.epoch.load(Ordering::Acquire) != e {
            let _g = self.rotate.lock().unwrap();
            if slot.epoch.load(Ordering::Acquire) != e {
                slot.hist.reset();
                slot.epoch.store(e, Ordering::Release);
            }
        }
        slot.hist.record(secs);
    }

    pub fn merged(&self, window: Duration) -> LogHistogram {
        self.merged_at(window, Instant::now())
    }

    /// One histogram covering the last `window` — fresh each call, so
    /// the per-epoch slots stay untouched for later reads.
    pub fn merged_at(&self, window: Duration, now: Instant) -> LogHistogram {
        let e_now = self.clock.epoch_id(now);
        let k = self.clock.window_epochs(window);
        let oldest = e_now.saturating_sub(k.saturating_sub(1));
        let out = LogHistogram::new();
        for s in &self.slots {
            let se = s.epoch.load(Ordering::Acquire);
            if se != u64::MAX && se >= oldest && se <= e_now {
                out.merge(&s.hist);
            }
        }
        out
    }
}

impl Default for WindowedHistogram {
    fn default() -> Self {
        WindowedHistogram::new()
    }
}

/// One reporting window's summary — what `Snapshot.windows` carries
/// and `/metrics` renders with a `window="<N>s"` label.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowStats {
    /// window length in seconds (the label: 10, 60)
    pub window_s: f64,
    /// requests completed in the window
    pub requests: u64,
    /// admission sheds in the window
    pub sheds: u64,
    /// SLO verdicts in the window
    pub slo_hits: u64,
    pub slo_misses: u64,
    /// windowed rates (events / min(window, elapsed))
    pub rps: f64,
    pub shed_rps: f64,
    /// windowed latency quantiles (0 when no requests landed)
    pub p50_s: f64,
    pub p99_s: f64,
}

impl WindowStats {
    /// SLO miss fraction over the window's verdicts (0 when none).
    pub fn slo_miss_rate(&self) -> f64 {
        let n = self.slo_hits + self.slo_misses;
        if n == 0 {
            0.0
        } else {
            self.slo_misses as f64 / n as f64
        }
    }

    /// The `window` label value: "10s", "60s".
    pub fn label(&self) -> String {
        format!("{}s", self.window_s.round() as u64)
    }
}

/// The windowed-telemetry bundle `coordinator::Metrics` embeds.
pub struct Windows {
    requests: WindowedCounter,
    sheds: WindowedCounter,
    slo_hits: WindowedCounter,
    slo_misses: WindowedCounter,
    latency: WindowedHistogram,
}

impl Windows {
    pub fn new() -> Windows {
        Windows::with_geometry(Instant::now(), EPOCH, SLOTS)
    }

    pub fn with_geometry(start: Instant, epoch: Duration, slots: usize) -> Windows {
        Windows {
            requests: WindowedCounter::with_geometry(start, epoch, slots),
            sheds: WindowedCounter::with_geometry(start, epoch, slots),
            slo_hits: WindowedCounter::with_geometry(start, epoch, slots),
            slo_misses: WindowedCounter::with_geometry(start, epoch, slots),
            latency: WindowedHistogram::with_geometry(start, epoch, slots),
        }
    }

    /// `n` requests completed; each latency lands in the window's
    /// histogram.
    pub fn record_requests_at(&self, latencies: &[f64], now: Instant) {
        self.requests.add_at(latencies.len() as u64, now);
        for &l in latencies {
            self.latency.record_at(l, now);
        }
    }

    pub fn record_requests(&self, latencies: &[f64]) {
        self.record_requests_at(latencies, Instant::now());
    }

    pub fn record_shed_at(&self, now: Instant) {
        self.sheds.add_at(1, now);
    }

    pub fn record_shed(&self) {
        self.record_shed_at(Instant::now());
    }

    pub fn record_slo_at(&self, hit: bool, now: Instant) {
        if hit {
            self.slo_hits.add_at(1, now);
        } else {
            self.slo_misses.add_at(1, now);
        }
    }

    pub fn record_slo(&self, hit: bool) {
        self.record_slo_at(hit, Instant::now());
    }

    /// Summarize one window.
    pub fn stats_at(&self, window: Duration, now: Instant) -> WindowStats {
        let merged = self.latency.merged_at(window, now);
        WindowStats {
            window_s: window.as_secs_f64(),
            requests: self.requests.count_at(window, now),
            sheds: self.sheds.count_at(window, now),
            slo_hits: self.slo_hits.count_at(window, now),
            slo_misses: self.slo_misses.count_at(window, now),
            rps: self.requests.rate_at(window, now),
            shed_rps: self.sheds.rate_at(window, now),
            p50_s: merged.quantile(0.50),
            p99_s: merged.quantile(0.99),
        }
    }

    pub fn stats(&self, window: Duration) -> WindowStats {
        self.stats_at(window, Instant::now())
    }

    /// The standard reporting windows ([`REPORT_WINDOWS`]: 10s, 60s).
    pub fn stats_all_at(&self, now: Instant) -> Vec<WindowStats> {
        REPORT_WINDOWS.iter().map(|w| self.stats_at(*w, now)).collect()
    }

    pub fn stats_all(&self) -> Vec<WindowStats> {
        self.stats_all_at(Instant::now())
    }
}

impl Default for Windows {
    fn default() -> Self {
        Windows::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn counter_counts_within_window_and_ages_out() {
        let start = t0();
        let c = WindowedCounter::with_geometry(start, Duration::from_secs(2), 33);
        c.add_at(3, start);
        c.add_at(2, start + Duration::from_secs(1));
        // both land in epoch 0; a 10s window at t=1s sees all 5
        let now = start + Duration::from_secs(1);
        assert_eq!(c.count_at(Duration::from_secs(10), now), 5);
        assert_eq!(c.total(), 5);
        // 70s later the events are outside both windows...
        let late = start + Duration::from_secs(70);
        assert_eq!(c.count_at(Duration::from_secs(10), late), 0);
        assert_eq!(c.count_at(Duration::from_secs(60), late), 0);
        // ...but the lifetime total stands
        assert_eq!(c.total(), 5);
    }

    #[test]
    fn counter_rate_clamps_denominator_to_elapsed() {
        let start = t0();
        let c = WindowedCounter::with_geometry(start, Duration::from_secs(2), 33);
        let now = start + Duration::from_secs(1);
        c.add_at(50, now);
        // 1s elapsed: a 10s window must not divide by 10
        let r = c.rate_at(Duration::from_secs(10), now);
        assert!((r - 50.0).abs() < 1e-9, "rate {r}");
        // at t=20s the same 50 events are inside a 60s window at 50/20
        let later = start + Duration::from_secs(20);
        let r60 = c.rate_at(Duration::from_secs(60), later);
        assert!((r60 - 2.5).abs() < 1e-9, "rate {r60}");
    }

    #[test]
    fn counter_slot_reuse_resets_stale_epochs() {
        let start = t0();
        // tiny ring: 1s epochs, 4 slots -> slot 0 is reused at epoch 4
        let c = WindowedCounter::with_geometry(start, Duration::from_secs(1), 4);
        c.add_at(7, start); // epoch 0, slot 0
        let reuse = start + Duration::from_secs(4); // epoch 4, slot 0 again
        c.add_at(1, reuse);
        // the stale 7 must be gone from the slot, not merged
        assert_eq!(c.count_at(Duration::from_secs(1), reuse), 1);
        assert_eq!(c.total(), 8, "lifetime total unaffected by reuse");
    }

    #[test]
    fn histogram_merges_only_window_epochs() {
        let start = t0();
        let h =
            WindowedHistogram::with_geometry(start, Duration::from_secs(2), 33);
        h.record_at(1e-3, start);
        h.record_at(2e-3, start + Duration::from_secs(1));
        let now = start + Duration::from_secs(1);
        let m = h.merged_at(Duration::from_secs(10), now);
        assert_eq!(m.count(), 2);
        assert_eq!(m.min_secs(), 1e-3);
        assert_eq!(m.max_secs(), 2e-3);
        // outside the window: empty merge, zero quantiles (no sentinel)
        let late = start + Duration::from_secs(70);
        let empty = h.merged_at(Duration::from_secs(10), late);
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.quantile(0.99), 0.0);
        assert_eq!(empty.min_secs(), 0.0);
        assert_eq!(empty.max_secs(), 0.0);
    }

    #[test]
    fn histogram_slot_reuse_resets_the_epoch_histogram() {
        let start = t0();
        let h = WindowedHistogram::with_geometry(start, Duration::from_secs(1), 4);
        for _ in 0..10 {
            h.record_at(5e-3, start);
        }
        let reuse = start + Duration::from_secs(4);
        h.record_at(1e-3, reuse);
        let m = h.merged_at(Duration::from_secs(1), reuse);
        assert_eq!(m.count(), 1, "stale epoch data wiped on reuse");
        assert_eq!(m.max_secs(), 1e-3);
    }

    #[test]
    fn windows_bundle_summarizes_rates_quantiles_and_slo() {
        let start = t0();
        let w = Windows::with_geometry(start, Duration::from_secs(2), 33);
        let now = start + Duration::from_secs(10);
        w.record_requests_at(&[1e-3, 1e-3, 4e-3, 4e-3], now);
        w.record_shed_at(now);
        w.record_slo_at(true, now);
        w.record_slo_at(false, now);
        let s = w.stats_at(Duration::from_secs(10), now);
        assert_eq!(s.window_s, 10.0);
        assert_eq!(s.label(), "10s");
        assert_eq!(s.requests, 4);
        assert_eq!(s.sheds, 1);
        assert_eq!(s.slo_hits, 1);
        assert_eq!(s.slo_misses, 1);
        assert!((s.slo_miss_rate() - 0.5).abs() < 1e-12);
        assert!((s.rps - 0.4).abs() < 1e-9, "rps {}", s.rps);
        assert!(s.p50_s > 0.0 && s.p99_s >= s.p50_s);
        // p99 lands near the 4ms samples (~9% bucket resolution)
        assert!((s.p99_s - 4e-3).abs() < 4e-3 * 0.15, "p99 {}", s.p99_s);
        let all = w.stats_all_at(now);
        assert_eq!(all.len(), REPORT_WINDOWS.len());
        assert_eq!(all[0].label(), "10s");
        assert_eq!(all[1].label(), "60s");
    }

    #[test]
    fn empty_windows_summarize_to_zeros() {
        let w = Windows::new();
        let s = w.stats(Duration::from_secs(10));
        assert_eq!(s.requests, 0);
        assert_eq!(s.rps, 0.0);
        assert_eq!(s.p50_s, 0.0);
        assert_eq!(s.p99_s, 0.0);
        assert_eq!(s.slo_miss_rate(), 0.0);
    }

    #[test]
    fn concurrent_recording_is_exact_within_an_epoch() {
        let start = t0();
        let c = WindowedCounter::with_geometry(start, Duration::from_secs(60), 4);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.add_at(1, start);
                    }
                });
            }
        });
        assert_eq!(c.count_at(Duration::from_secs(60), start), 80_000);
        assert_eq!(c.total(), 80_000);
    }
}
