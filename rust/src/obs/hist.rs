//! `LogHistogram`: a fixed-bucket log-scale latency histogram.
//!
//! The serving metrics used to keep every request latency in a
//! `Vec<f64>` — unbounded growth under the north-star's "millions of
//! users" load.  This histogram replaces it with a *constant-size*
//! structure: `SUB_BUCKETS` buckets per power-of-two octave over a
//! nanosecond domain, each an `AtomicU64` counter.  Recording is
//! lock-free (relaxed atomics — per-bucket counts and the total are
//! exact under concurrency because `fetch_add` never loses an
//! increment), quantiles interpolate inside the landing bucket (so
//! p50/p90/p99 are exact to within one bucket's width, ~9% relative
//! with 8 sub-buckets per octave), and two histograms with the same
//! geometry merge by bucket-wise addition.
//!
//! Domain: [1ns, 2^OCTAVES ns ≈ 18 minutes).  Anything slower clamps
//! into the last bucket; the reported max is still exact because
//! min/max are tracked separately in integer nanoseconds.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::stats::Summary;

/// Sub-buckets per power-of-two octave.  8 gives a bucket width of
/// 2^(1/8) ≈ 1.09x — quantiles exact to within ~9%.
const SUB_BUCKETS: usize = 8;
/// Powers of two covered: 2^40 ns ≈ 1100 s.
const OCTAVES: usize = 40;
const N_BUCKETS: usize = SUB_BUCKETS * OCTAVES;

/// Fixed-footprint concurrent latency histogram (seconds in,
/// log-spaced nanosecond buckets inside).
pub struct LogHistogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    /// exact integer total (each sample rounded to whole nanoseconds),
    /// so the mean survives concurrency without torn f64 adds
    sum_ns: AtomicU64,
    /// f64 bits of the sum of squared seconds (CAS loop; feeds stddev
    /// only, where a torn retry costs nothing)
    sumsq_s2: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            sumsq_s2: AtomicU64::new(0f64.to_bits()),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one latency in seconds.  Non-finite and negative samples
    /// are dropped; zero clamps to 1ns (the first bucket).
    pub fn record(&self, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        let ns = (secs * 1e9).round().max(1.0) as u64; // saturates at u64::MAX
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        let sq = secs * secs;
        let mut cur = self.sumsq_s2.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + sq).to_bits();
            match self.sumsq_s2.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact total of the recorded samples in seconds (integer
    /// nanosecond accumulation — no float-order nondeterminism).
    pub fn sum_secs(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn min_secs(&self) -> f64 {
        match self.min_ns.load(Ordering::Relaxed) {
            u64::MAX => 0.0,
            ns => ns as f64 / 1e9,
        }
    }

    pub fn max_secs(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// The q-quantile (q in [0, 1]) in seconds, interpolated inside
    /// the landing bucket and clamped to the exact observed [min, max].
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).max(1.0);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= target {
                let (lo, hi) = bucket_bounds_secs(i);
                let frac = (target - cum as f64) / c as f64;
                let v = lo + (hi - lo) * frac;
                return v.clamp(self.min_secs(), self.max_secs());
            }
            cum += c;
        }
        self.max_secs()
    }

    /// `Summary` over the recorded distribution — same shape the old
    /// Vec-backed `latency_summary()` returned, so callers don't churn.
    /// Percentiles are bucket-interpolated (~9% resolution); n, mean,
    /// min, and max are exact.
    pub fn summary(&self) -> Summary {
        let n = self.count();
        if n == 0 {
            return Summary::default();
        }
        let mean = self.sum_secs() / n as f64;
        let sumsq = f64::from_bits(self.sumsq_s2.load(Ordering::Relaxed));
        let var = (sumsq / n as f64 - mean * mean).max(0.0);
        Summary::from_quantiles(
            n as usize,
            mean,
            var.sqrt(),
            self.min_secs(),
            self.max_secs(),
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.95),
            self.quantile(0.99),
        )
    }

    /// Bucket-wise merge of another histogram into this one (same
    /// fixed geometry by construction).
    pub fn merge(&self, other: &LogHistogram) {
        for (b, o) in self.buckets.iter().zip(&other.buckets) {
            let c = o.load(Ordering::Relaxed);
            if c > 0 {
                b.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min_ns
            .fetch_min(other.min_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns
            .fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        let osq = f64::from_bits(other.sumsq_s2.load(Ordering::Relaxed));
        let mut cur = self.sumsq_s2.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + osq).to_bits();
            match self.sumsq_s2.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Clear every bucket and restore the empty-histogram sentinels
    /// (`min_ns = u64::MAX`, which `min_secs` maps to 0, and
    /// `max_ns = 0`) — so a reused window epoch reports `0` min/max,
    /// never a stale value or a leaked sentinel.  Not atomic with
    /// respect to concurrent `record`s: a racing sample may land
    /// before or after the wipe, which windowed telemetry tolerates
    /// (it lands in this epoch or is dropped — never double-counted).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.sumsq_s2.store(0f64.to_bits(), Ordering::Relaxed);
        self.min_ns.store(u64::MAX, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
    }

    /// The non-empty buckets as `(lo_secs, hi_secs, count)` — what the
    /// exporter serializes (bounded: at most `N_BUCKETS` rows).
    pub fn nonzero_buckets(&self) -> Vec<(f64, f64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then(|| {
                    let (lo, hi) = bucket_bounds_secs(i);
                    (lo, hi, c)
                })
            })
            .collect()
    }

    /// The structure's memory footprint — a compile-time constant,
    /// which is the whole point: recording never grows it.
    pub fn footprint_bytes(&self) -> usize {
        std::mem::size_of::<LogHistogram>()
    }
}

/// Bucket index of a (non-zero) nanosecond value: `SUB_BUCKETS` even
/// subdivisions of each power-of-two octave, clamped into range.
fn bucket_index(ns: u64) -> usize {
    let idx = ((ns as f64).log2() * SUB_BUCKETS as f64).floor() as usize;
    idx.min(N_BUCKETS - 1)
}

/// `[lo, hi)` of bucket `i`, in seconds.
fn bucket_bounds_secs(i: usize) -> (f64, f64) {
    let lo = 2f64.powf(i as f64 / SUB_BUCKETS as f64) / 1e9;
    let hi = 2f64.powf((i + 1) as f64 / SUB_BUCKETS as f64) / 1e9;
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_resolution_is_one_eighth_octave() {
        // consecutive bucket bounds differ by 2^(1/8)
        let (lo, hi) = bucket_bounds_secs(160);
        assert!((hi / lo - 2f64.powf(0.125)).abs() < 1e-12);
        // 1ms lands where log2(1e6)*8 floors
        assert_eq!(bucket_index(1_000_000), 159);
        // out-of-range clamps instead of indexing out of bounds
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn summary_matches_exact_stats_within_resolution() {
        let h = LogHistogram::new();
        for _ in 0..8 {
            h.record(0.001);
        }
        for _ in 0..3 {
            h.record(0.002);
        }
        let s = h.summary();
        assert_eq!(s.n, 11);
        assert!((s.mean - 14e-3 / 11.0).abs() < 1e-12, "mean exact: {}", s.mean);
        assert_eq!(s.min, 0.001);
        assert_eq!(s.max, 0.002);
        // percentiles are bucket-resolution (~9%) approximations
        assert!((s.p50 - 0.001).abs() < 0.001 * 0.1, "p50 {}", s.p50);
        assert!((s.p99 - 0.002).abs() < 0.002 * 0.1, "p99 {}", s.p99);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn empty_and_degenerate_inputs_are_sane() {
        let h = LogHistogram::new();
        assert_eq!(h.summary().n, 0);
        assert_eq!(h.quantile(0.5), 0.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-1.0);
        assert_eq!(h.count(), 0);
        h.record(0.0); // clamps to the 1ns bucket
        assert_eq!(h.count(), 1);
        assert_eq!(h.nonzero_buckets().len(), 1);
    }

    #[test]
    fn footprint_is_constant_under_load() {
        let h = LogHistogram::new();
        let before = h.footprint_bytes();
        for i in 0..10_000 {
            h.record(1e-6 * (1 + i % 1000) as f64);
        }
        assert_eq!(h.footprint_bytes(), before);
        assert!(before < 8192, "bounded: {before} bytes");
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn empty_histogram_never_leaks_min_max_sentinels() {
        // satellite regression: a model that served zero requests must
        // render min/max as 0, not the u64::MAX init sentinel
        let h = LogHistogram::new();
        assert_eq!(h.min_secs(), 0.0, "empty min renders 0, not sentinel");
        assert_eq!(h.max_secs(), 0.0);
        let s = h.summary();
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.p99, 0.0);
        // ...and a reset must restore exactly that state, not leave a
        // stale min/max or a zeroed min sentinel
        h.record(0.004);
        h.record(0.001);
        assert_eq!(h.min_secs(), 0.001);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_secs(), 0.0);
        assert_eq!(h.min_secs(), 0.0, "reset restores the empty-min path");
        assert_eq!(h.max_secs(), 0.0);
        assert!(h.nonzero_buckets().is_empty());
        assert_eq!(h.summary(), crate::util::stats::Summary::default());
        // recording after reset behaves like a fresh histogram (the
        // min sentinel was restored, so the first sample sets min)
        h.record(0.002);
        assert_eq!(h.min_secs(), 0.002);
        assert_eq!(h.max_secs(), 0.002);
    }

    #[test]
    fn merging_an_empty_histogram_does_not_disturb_min_max() {
        let a = LogHistogram::new();
        let empty = LogHistogram::new();
        a.record(0.003);
        a.merge(&empty);
        assert_eq!(a.count(), 1);
        assert_eq!(a.min_secs(), 0.003, "empty-merge leaves min alone");
        assert_eq!(a.max_secs(), 0.003);
        // merging INTO an empty histogram adopts the source's min/max
        empty.merge(&a);
        assert_eq!(empty.min_secs(), 0.003);
        assert_eq!(empty.max_secs(), 0.003);
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = LogHistogram::new();
        let b = LogHistogram::new();
        a.record(0.001);
        b.record(0.004);
        b.record(0.002);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min_secs(), 0.001);
        assert_eq!(a.max_secs(), 0.004);
        assert!((a.sum_secs() - 0.007).abs() < 1e-12);
        assert_eq!(a.nonzero_buckets().iter().map(|(_, _, c)| c).sum::<u64>(), 3);
    }
}
