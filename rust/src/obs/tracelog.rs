//! Sampled request-scoped trace log: one JSON line per sampled
//! request, written as the fleet serves.
//!
//! The [`TraceRing`](crate::obs::TraceRing) keeps the newest N *batch*
//! traces in memory; this module complements it with a durable,
//! *request*-scoped view — where did request 48291's 9ms go: queue,
//! steal migration, batch assembly, or the forward pass?  Lines are
//! sampled 1-in-N (the first request is always sampled, so short runs
//! still produce a file) and rendered through `engine::json`, so the
//! schema is exactly what `Value::parse` reads back:
//!
//! ```json
//! {"model":"mnist","req":7,"shard":1,"batch_seq":3,"rows":6,
//!  "padded":8,"queue_s":0.0011,"steals":1,"assemble_s":0.00002,
//!  "execute_s":0.0019,"e2e_s":0.0032}
//! ```
//!
//! All fields are finite numbers or strings — the writer clamps
//! non-finite durations to 0 rather than emit invalid JSON.  Writing
//! happens on the worker thread after the batch's waiters are
//! answered, buffered through a `BufWriter` behind one mutex; at the
//! default 1-in-16 sampling the lock is off the per-request path
//! entirely for 15 of 16 requests (the sample counter is atomic).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::engine::json::Value;

/// One sampled request's timing decomposition.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTrace {
    /// served model name
    pub model: String,
    /// request id (minted at `Fleet::submit`)
    pub req: u64,
    /// shard whose worker executed the batch
    pub shard: usize,
    /// the executing worker's batch sequence number
    pub batch_seq: u64,
    /// real rows in the batch / padded bucket size
    pub rows: usize,
    pub padded: usize,
    /// this request's own queue wait (enqueue -> batch formation)
    pub queue_s: f64,
    /// times the request migrated between sibling shards
    pub steals: u64,
    /// batch assembly (copy + padding) — shared by the whole batch
    pub assemble_s: f64,
    /// the model's forward call — shared by the whole batch
    pub execute_s: f64,
    /// end-to-end: enqueue -> response sent
    pub e2e_s: f64,
}

impl RequestTrace {
    fn to_json(&self) -> Value {
        let f = |x: f64| Value::Num(if x.is_finite() { x } else { 0.0 });
        Value::Obj(vec![
            ("model".to_string(), Value::Str(self.model.clone())),
            ("req".to_string(), Value::Num(self.req as f64)),
            ("shard".to_string(), Value::Num(self.shard as f64)),
            ("batch_seq".to_string(), Value::Num(self.batch_seq as f64)),
            ("rows".to_string(), Value::Num(self.rows as f64)),
            ("padded".to_string(), Value::Num(self.padded as f64)),
            ("queue_s".to_string(), f(self.queue_s)),
            ("steals".to_string(), Value::Num(self.steals as f64)),
            ("assemble_s".to_string(), f(self.assemble_s)),
            ("execute_s".to_string(), f(self.execute_s)),
            ("e2e_s".to_string(), f(self.e2e_s)),
        ])
    }
}

/// Sampled JSONL writer (see module docs).
pub struct TraceWriter {
    out: Mutex<BufWriter<File>>,
    sample_every: u64,
    seen: AtomicU64,
    written: AtomicU64,
}

impl TraceWriter {
    /// Open `path` for writing (truncates), sampling 1 request in
    /// `sample_every` (clamped to at least 1 = every request).
    pub fn create<P: AsRef<Path>>(
        path: P,
        sample_every: u64,
    ) -> std::io::Result<TraceWriter> {
        let file = File::create(path)?;
        Ok(TraceWriter {
            out: Mutex::new(BufWriter::new(file)),
            sample_every: sample_every.max(1),
            seen: AtomicU64::new(0),
            written: AtomicU64::new(0),
        })
    }

    /// Offer one request trace; writes it when the sampler selects it
    /// (request 1, N+1, 2N+1, ... of those offered).  Write errors are
    /// swallowed — tracing must never take down serving.
    pub fn observe(&self, t: &RequestTrace) {
        let n = self.seen.fetch_add(1, Ordering::Relaxed);
        if n % self.sample_every != 0 {
            return;
        }
        let line = t.to_json().to_string();
        let mut out = self.out.lock().unwrap();
        if writeln!(out, "{line}").is_ok() {
            self.written.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Requests offered to the sampler.
    pub fn seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    /// Lines actually written.
    pub fn written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }

    /// Flush buffered lines to disk (also happens on drop).
    pub fn flush(&self) {
        let _ = self.out.lock().unwrap().flush();
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(req: u64) -> RequestTrace {
        RequestTrace {
            model: "m".to_string(),
            req,
            shard: 1,
            batch_seq: 3,
            rows: 6,
            padded: 8,
            queue_s: 1.1e-3,
            steals: 1,
            assemble_s: 2e-5,
            execute_s: 1.9e-3,
            e2e_s: 3.2e-3,
        }
    }

    #[test]
    fn writes_sampled_jsonl_that_round_trips() {
        let path = std::env::temp_dir()
            .join(format!("tcbnn-tracelog-{}.jsonl", std::process::id()));
        let w = TraceWriter::create(&path, 4).unwrap();
        for req in 0..10 {
            w.observe(&trace(req));
        }
        assert_eq!(w.seen(), 10);
        assert_eq!(w.written(), 3, "1-in-4 of 10: requests 0, 4, 8");
        w.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for (i, line) in lines.iter().enumerate() {
            let v = Value::parse(line).expect("valid engine::json");
            assert_eq!(v.get("req").and_then(Value::as_usize), Some(i * 4));
            assert_eq!(v.get("model").and_then(Value::as_str), Some("m"));
            for key in [
                "shard", "batch_seq", "rows", "padded", "queue_s", "steals",
                "assemble_s", "execute_s", "e2e_s",
            ] {
                assert!(
                    v.get(key).and_then(Value::as_f64).is_some(),
                    "line {i} missing {key}: {line}"
                );
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_finite_durations_clamp_to_zero() {
        let path = std::env::temp_dir()
            .join(format!("tcbnn-tracelog-nan-{}.jsonl", std::process::id()));
        let w = TraceWriter::create(&path, 1).unwrap();
        let mut t = trace(0);
        t.queue_s = f64::NAN;
        t.execute_s = f64::INFINITY;
        w.observe(&t);
        w.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(text.trim()).expect("still valid JSON");
        assert_eq!(v.get("queue_s").and_then(Value::as_f64), Some(0.0));
        assert_eq!(v.get("execute_s").and_then(Value::as_f64), Some(0.0));
        let _ = std::fs::remove_file(&path);
    }
}
