//! Span recording for served batches: where did a request's time go?
//!
//! Each executed batch leaves one [`BatchTrace`] — the request ids it
//! carried plus an ordered span list: queue wait (oldest request),
//! batch assembly, then one span per plan layer (scheme, shape tag,
//! measured seconds, activation bytes) with explicit layout-repack
//! ops interleaved before their consuming layer.  Repack time is
//! *contained* in the consuming layer's span (the conversion runs
//! inside its timed region), so summing only the `Layer` spans covers
//! the whole forward pass without double counting.
//!
//! Traces live in a fixed-capacity ring: pushing over capacity evicts
//! the oldest trace and counts the drop.  The ring never grows — the
//! same bounded-memory contract as `obs::hist`.

use std::collections::VecDeque;
use std::sync::Mutex;

/// What a span measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// time the batch's oldest request spent queued before formation
    Queue,
    /// a request migrated between sibling shards by work stealing;
    /// `secs` is its wait at steal time (informational — contained in
    /// the batch's queue wait, not additive with `Queue`)
    Steal,
    /// batch formation: pops, input concatenation, tail padding
    Assemble,
    /// the model's whole forward call for the batch (wraps the `Layer`
    /// spans; informational, not additive with them)
    Execute,
    /// one plan layer's execution (repack time included when an
    /// explicit edge feeds it)
    Layer,
    /// an explicit layout-repack op (nested inside its consuming
    /// layer's span — informational, not additive with `Layer`)
    Repack,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::Steal => "steal",
            SpanKind::Assemble => "assemble",
            SpanKind::Execute => "execute",
            SpanKind::Layer => "layer",
            SpanKind::Repack => "repack",
        }
    }
}

/// One timed region of a batch's lifetime.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    /// `Layer`: "L<i>/<tag>/<scheme>"; `Repack`: "L<i>/<src>-><dst>"
    pub label: String,
    pub secs: f64,
    /// bytes the span touched (activation payload for layers, streamed
    /// bytes for repacks, input floats for assembly; 0 for queue wait)
    pub bytes: u64,
}

impl Span {
    pub fn queue(secs: f64) -> Span {
        Span { kind: SpanKind::Queue, label: "queue-wait".to_string(), secs, bytes: 0 }
    }

    /// A request stolen from a sibling shard; `label` names the donor
    /// (e.g. "steal<-shard1"), `secs` is the request's wait so far.
    pub fn steal(label: String, secs: f64) -> Span {
        Span { kind: SpanKind::Steal, label, secs, bytes: 0 }
    }

    pub fn assemble(secs: f64, bytes: u64) -> Span {
        Span {
            kind: SpanKind::Assemble,
            label: "batch-assembly".to_string(),
            secs,
            bytes,
        }
    }

    pub fn layer(label: String, secs: f64, bytes: u64) -> Span {
        Span { kind: SpanKind::Layer, label, secs, bytes }
    }

    pub fn repack(label: String, secs: f64, bytes: u64) -> Span {
        Span { kind: SpanKind::Repack, label, secs, bytes }
    }

    /// The whole forward call; `bytes` is the batch's input payload.
    pub fn execute(secs: f64, bytes: u64) -> Span {
        Span {
            kind: SpanKind::Execute,
            label: "model-execute".to_string(),
            secs,
            bytes,
        }
    }
}

/// One served batch's trace.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchTrace {
    /// batch sequence number (the server's batch counter at record time)
    pub seq: u64,
    /// request ids the batch carried (padding rows have no id)
    pub ids: Vec<u64>,
    /// ordered spans: queue, assemble, then layers with repacks
    /// interleaved
    pub spans: Vec<Span>,
}

impl BatchTrace {
    /// Seconds covered by `Layer` spans (the forward pass; repack
    /// spans are nested inside layers and intentionally not added).
    pub fn layer_secs(&self) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.kind == SpanKind::Layer)
            .map(|s| s.secs)
            .sum()
    }
}

/// Fixed-capacity trace ring with drop counting.
pub struct TraceRing {
    inner: Mutex<RingInner>,
    capacity: usize,
}

struct RingInner {
    buf: VecDeque<BatchTrace>,
    pushed: u64,
    dropped: u64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        assert!(capacity > 0, "trace ring needs at least one slot");
        TraceRing {
            inner: Mutex::new(RingInner {
                buf: VecDeque::with_capacity(capacity),
                pushed: 0,
                dropped: 0,
            }),
            capacity,
        }
    }

    /// Record one batch trace; evicts (and counts) the oldest when
    /// full.  The ring never grows past its capacity.
    pub fn push(&self, trace: BatchTrace) {
        let mut r = self.inner.lock().unwrap();
        if r.buf.len() == self.capacity {
            r.buf.pop_front();
            r.dropped += 1;
        }
        r.buf.push_back(trace);
        r.pushed += 1;
    }

    /// Total traces ever pushed.
    pub fn pushed(&self) -> u64 {
        self.inner.lock().unwrap().pushed
    }

    /// Traces evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the retained traces, oldest first.
    pub fn snapshot(&self) -> Vec<BatchTrace> {
        self.inner.lock().unwrap().buf.iter().cloned().collect()
    }

    /// The retained trace that served request `id`, if it has not been
    /// evicted.
    pub fn find_request(&self, id: u64) -> Option<BatchTrace> {
        self.inner
            .lock()
            .unwrap()
            .buf
            .iter()
            .rev()
            .find(|t| t.ids.contains(&id))
            .cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seq: u64) -> BatchTrace {
        BatchTrace {
            seq,
            ids: vec![seq * 10, seq * 10 + 1],
            spans: vec![Span::queue(1e-6), Span::layer("L0/t/F".into(), 2e-6, 64)],
        }
    }

    #[test]
    fn push_and_find() {
        let r = TraceRing::new(4);
        assert!(r.is_empty());
        r.push(trace(1));
        r.push(trace(2));
        assert_eq!(r.len(), 2);
        assert_eq!(r.pushed(), 2);
        assert_eq!(r.dropped(), 0);
        let t = r.find_request(21).expect("request 21 traced");
        assert_eq!(t.seq, 2);
        assert!(r.find_request(99).is_none());
        assert!((t.layer_secs() - 2e-6).abs() < 1e-15);
    }

    #[test]
    fn overflow_evicts_oldest_and_counts_drops() {
        let r = TraceRing::new(3);
        for seq in 0..5 {
            r.push(trace(seq));
        }
        assert_eq!(r.len(), 3, "never over capacity");
        assert_eq!(r.pushed(), 5);
        assert_eq!(r.dropped(), 2);
        let kept: Vec<u64> = r.snapshot().iter().map(|t| t.seq).collect();
        assert_eq!(kept, vec![2, 3, 4], "oldest evicted first");
        assert!(r.find_request(0).is_none(), "evicted trace unfindable");
        assert!(r.find_request(40).is_some());
    }
}
