//! `/metrics` scrape server: a dependency-free blocking HTTP/1.1
//! endpoint over `std::net::TcpListener` — the same spirit as the
//! hand-rolled `engine::json` (no hyper offline, and none needed for
//! a scrape endpoint serving one short response per connection).
//!
//! Routes:
//!
//! * `GET /metrics` — Prometheus text exposition
//!   ([`render_prometheus_fleet`] over the source's live snapshots,
//!   `model` label per served model),
//! * `GET /snapshot.json` — the same snapshots as one JSON document
//!   (`{"schema":N,"models":[{"name":...,"snapshot":{...}}]}`),
//! * `GET /healthz` — 200 when every shard can make progress, 503
//!   when any shard's watchdog state is `stalled`, with a JSON body
//!   naming the offender.
//!
//! The protocol surface is deliberately tiny: one request per
//! connection (`Connection: close`), request line + headers read with
//! a 2s timeout and an 8KB cap, anything but `GET` answered 405.
//! Scrapers (Prometheus, curl, the integration test's raw-socket
//! client) need nothing more.
//!
//! The server pulls fresh data per request through [`ScrapeSource`] —
//! implemented by `serve::Fleet` (live per-model snapshots with
//! watchdog health grafted in) and trivially by any test double.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::engine::json::Value;
use crate::obs::export::{render_prometheus_fleet, Snapshot, OBS_SCHEMA};

/// What the scrape server serves: the current snapshot of every model
/// the process runs, freshly assembled per request.
pub trait ScrapeSource: Send + Sync {
    fn snapshots(&self) -> Vec<(String, Snapshot)>;
}

/// The running scrape server; dropping it (or calling
/// [`ScrapeServer::shutdown`]) stops the accept loop.
pub struct ScrapeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ScrapeServer {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and
    /// start the accept loop on a background thread.
    pub fn start(
        addr: &str,
        source: Arc<dyn ScrapeSource>,
    ) -> std::io::Result<ScrapeServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("tcbnn-scrape".to_string())
            .spawn(move || accept_loop(listener, source, stop2))?;
        Ok(ScrapeServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept() by connecting to ourselves; the loop
        // re-checks the stop flag before serving
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ScrapeServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    source: Arc<dyn ScrapeSource>,
    stop: Arc<AtomicBool>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            // serve inline: a scrape endpoint's request rate is the
            // scrape interval — no connection concurrency needed, and
            // a slow reader is bounded by the write timeout
            Ok(s) => handle_conn(s, source.as_ref()),
            Err(_) => continue,
        }
    }
}

fn handle_conn(mut stream: TcpStream, source: &dyn ScrapeSource) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let Some((method, path)) = read_request(&mut stream) else {
        respond(&mut stream, 400, "Bad Request", "text/plain", "bad request\n");
        return;
    };
    if method != "GET" {
        respond(
            &mut stream,
            405,
            "Method Not Allowed",
            "text/plain",
            "only GET is served\n",
        );
        return;
    }
    // strip any query string — scrape paths take no parameters
    let path = path.split('?').next().unwrap_or("");
    match path {
        "/metrics" => {
            let body = render_prometheus_fleet(&source.snapshots());
            respond(
                &mut stream,
                200,
                "OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &body,
            );
        }
        "/snapshot.json" => {
            let body = snapshot_document(&source.snapshots()).to_string();
            respond(&mut stream, 200, "OK", "application/json", &body);
        }
        "/healthz" => {
            let snaps = source.snapshots();
            let (healthy, body) = health_document(&snaps);
            let (code, reason) =
                if healthy { (200, "OK") } else { (503, "Service Unavailable") };
            respond(&mut stream, code, reason, "application/json", &body.to_string());
        }
        _ => respond(&mut stream, 404, "Not Found", "text/plain", "not found\n"),
    }
}

/// Read the request head (request line + headers, up to 8KB) and
/// return `(method, path)`.
fn read_request(stream: &mut TcpStream) -> Option<(String, String)> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk).ok()?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() >= 8192 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.lines().next()?.split_whitespace();
    let method = parts.next()?.to_string();
    let path = parts.next()?.to_string();
    Some((method, path))
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// The `/snapshot.json` document.
fn snapshot_document(snaps: &[(String, Snapshot)]) -> Value {
    Value::Obj(vec![
        ("schema".to_string(), Value::Num(OBS_SCHEMA as f64)),
        (
            "models".to_string(),
            Value::Arr(
                snaps
                    .iter()
                    .map(|(name, s)| {
                        Value::Obj(vec![
                            ("name".to_string(), Value::Str(name.clone())),
                            ("snapshot".to_string(), s.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The `/healthz` verdict + document: healthy iff no shard of any
/// model reports a `stalled` watchdog state.  Models without health
/// data (no watchdog running) count as healthy — absence of a monitor
/// is not an outage.
fn health_document(snaps: &[(String, Snapshot)]) -> (bool, Value) {
    let healthy =
        snaps.iter().all(|(_, s)| s.health.iter().all(|h| h.is_up()));
    let doc = Value::Obj(vec![
        ("healthy".to_string(), Value::Bool(healthy)),
        (
            "models".to_string(),
            Value::Arr(
                snaps
                    .iter()
                    .map(|(name, s)| {
                        Value::Obj(vec![
                            ("name".to_string(), Value::Str(name.clone())),
                            (
                                "shards".to_string(),
                                Value::Arr(
                                    s.health
                                        .iter()
                                        .map(|h| {
                                            Value::Obj(vec![
                                                (
                                                    "shard".to_string(),
                                                    Value::Num(h.shard as f64),
                                                ),
                                                (
                                                    "state".to_string(),
                                                    Value::Str(h.state.clone()),
                                                ),
                                                (
                                                    "reason".to_string(),
                                                    Value::Str(h.reason.clone()),
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    (healthy, doc)
}

/// Minimal blocking HTTP GET for demos and tests (the integration
/// test scrapes with it — no external HTTP crate offline).  Returns
/// `(status_code, body)`.
pub fn http_get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let status = raw
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "malformed status line",
            )
        })?;
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::ShardHealthAttr;
    use std::sync::Mutex;

    struct MockSource {
        snaps: Mutex<Vec<(String, Snapshot)>>,
    }

    impl ScrapeSource for MockSource {
        fn snapshots(&self) -> Vec<(String, Snapshot)> {
            self.snaps.lock().unwrap().clone()
        }
    }

    fn healthy_source() -> Arc<MockSource> {
        let snap = Snapshot {
            requests: 8,
            health: vec![ShardHealthAttr {
                shard: 0,
                state: "healthy".to_string(),
                reason: String::new(),
                last_batch_age_s: 0.01,
                queue_depth: 0,
            }],
            ..Default::default()
        };
        Arc::new(MockSource {
            snaps: Mutex::new(vec![("mnist".to_string(), snap)]),
        })
    }

    #[test]
    fn serves_metrics_snapshot_and_healthz() {
        let source = healthy_source();
        let srv =
            ScrapeServer::start("127.0.0.1:0", source.clone()).expect("bind");
        let addr = srv.local_addr();

        let (code, body) = http_get(addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(
            body.contains("tcbnn_requests_total{model=\"mnist\"} 8"),
            "{body}"
        );
        assert!(body.contains("# TYPE tcbnn_requests_total counter"));

        let (code, body) = http_get(addr, "/snapshot.json").unwrap();
        assert_eq!(code, 200);
        let doc = Value::parse(&body).expect("valid JSON");
        assert_eq!(
            doc.get("schema").and_then(Value::as_usize),
            Some(OBS_SCHEMA as usize)
        );
        let models = doc.get("models").and_then(Value::as_arr).unwrap();
        assert_eq!(models.len(), 1);
        let snap = models[0].get("snapshot").expect("snapshot key");
        let parsed = Snapshot::from_json(snap).expect("snapshot shape");
        assert_eq!(parsed.requests, 8);

        let (code, body) = http_get(addr, "/healthz").unwrap();
        assert_eq!(code, 200, "{body}");
        let doc = Value::parse(&body).unwrap();
        assert_eq!(doc.get("healthy"), Some(&Value::Bool(true)));

        let (code, _) = http_get(addr, "/nope").unwrap();
        assert_eq!(code, 404);

        srv.shutdown();
    }

    #[test]
    fn healthz_flips_503_when_a_shard_stalls() {
        let source = healthy_source();
        let srv =
            ScrapeServer::start("127.0.0.1:0", source.clone()).expect("bind");
        let addr = srv.local_addr();
        let (code, _) = http_get(addr, "/healthz").unwrap();
        assert_eq!(code, 200);
        // the source's next snapshot reports the shard stalled
        source.snaps.lock().unwrap()[0].1.health[0] = ShardHealthAttr {
            shard: 0,
            state: "stalled".to_string(),
            reason: "worker exited".to_string(),
            last_batch_age_s: 3.0,
            queue_depth: 9,
        };
        let (code, body) = http_get(addr, "/healthz").unwrap();
        assert_eq!(code, 503, "{body}");
        assert!(body.contains("\"state\":\"stalled\""), "{body}");
        assert!(body.contains("worker exited"), "{body}");
        // /metrics still serves during the outage (that's the point)
        let (code, body) = http_get(addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("tcbnn_shard_up{model=\"mnist\",shard=\"0\"} 0"));
        srv.shutdown();
    }

    #[test]
    fn non_get_is_405_and_shutdown_unblocks() {
        let source = healthy_source();
        let srv = ScrapeServer::start("127.0.0.1:0", source).expect("bind");
        let addr = srv.local_addr();
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 405"), "{resp}");
        // shutdown returns promptly even with no pending connection
        srv.shutdown();
    }
}
