//! Observability: bounded telemetry for the serving stack.
//!
//! Six pieces, one contract — *fixed memory under unbounded load*:
//!
//! * [`hist`] — a lock-free log-scale latency histogram
//!   ([`LogHistogram`]) that replaces the old unbounded per-request
//!   latency `Vec` inside `coordinator::Metrics`.
//! * [`trace`] — per-batch span recording ([`BatchTrace`] in a
//!   [`TraceRing`]): queue wait, steal migrations, batch assembly,
//!   the forward call, one span per plan layer, explicit repack ops
//!   interleaved.
//! * [`window`] — rolling-window telemetry ([`Windows`]): per-epoch
//!   counter/histogram rings merged on read, so `/metrics` reports
//!   10s/60s rates and quantiles alongside the cumulative totals.
//! * [`tracelog`] — a sampled JSONL request-trace log
//!   ([`TraceWriter`]): one line per sampled request decomposing its
//!   end-to-end time into queue / steal / assemble / execute.
//! * [`scrape`] — the dependency-free `/metrics` + `/snapshot.json` +
//!   `/healthz` HTTP endpoint ([`ScrapeServer`]) over any
//!   [`ScrapeSource`] (`serve::Fleet` implements it).
//! * [`export`] — the [`Snapshot`] struct that the human report, the
//!   JSON document, and the Prometheus text exposition all render
//!   from, carrying per-layer drift ([`LayerAttr`]), per-edge repack
//!   attribution ([`RepackEdge`]), rolling-window stats
//!   ([`WindowStats`]), and watchdog health ([`ShardHealthAttr`]).
//!
//! The timing source is single: `engine::executor` times each layer
//! once and feeds both `tuner::live::LiveCosts` (for re-planning) and
//! the per-layer attribution here (for reporting).  See
//! `docs/OBSERVABILITY.md`.

pub mod export;
pub mod hist;
pub mod scrape;
pub mod trace;
pub mod tracelog;
pub mod window;

pub use export::{
    render_prometheus_fleet, LayerAttr, RepackEdge, ShardAttr, ShardHealthAttr,
    Snapshot, MIN_OBS_SCHEMA, OBS_SCHEMA,
};
pub use hist::LogHistogram;
pub use scrape::{http_get, ScrapeServer, ScrapeSource};
pub use trace::{BatchTrace, Span, SpanKind, TraceRing};
pub use tracelog::{RequestTrace, TraceWriter};
pub use window::{WindowStats, WindowedCounter, WindowedHistogram, Windows};
