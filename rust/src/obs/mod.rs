//! Observability: bounded telemetry for the serving stack.
//!
//! Three pieces, one contract — *fixed memory under unbounded load*:
//!
//! * [`hist`] — a lock-free log-scale latency histogram
//!   ([`LogHistogram`]) that replaces the old unbounded per-request
//!   latency `Vec` inside `coordinator::Metrics`.
//! * [`trace`] — per-batch span recording ([`BatchTrace`] in a
//!   [`TraceRing`]): queue wait, batch assembly, one span per plan
//!   layer, explicit repack ops interleaved.
//! * [`export`] — the [`Snapshot`] struct that the human report, the
//!   JSON document, and the Prometheus text exposition all render
//!   from, carrying per-layer drift ([`LayerAttr`]) and per-edge
//!   repack attribution ([`RepackEdge`]).
//!
//! The timing source is single: `engine::executor` times each layer
//! once and feeds both `tuner::live::LiveCosts` (for re-planning) and
//! the per-layer attribution here (for reporting).  See
//! `docs/OBSERVABILITY.md`.

pub mod export;
pub mod hist;
pub mod trace;

pub use export::{LayerAttr, RepackEdge, ShardAttr, Snapshot, OBS_SCHEMA};
pub use hist::LogHistogram;
pub use trace::{BatchTrace, Span, SpanKind, TraceRing};
