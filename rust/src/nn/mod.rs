//! BNN network models (§6, Table 5) and their inference cost/execution.
//!
//! * `layer`  — layer specifications after the §6.1 inference rewrites
//!   (bn+sign folded to thresholds, pool as OR, fused thrd).
//! * `parser` — Table 5 network-structure strings ("(2x128C3)-MP2-...").
//! * `model`  — the six evaluation models + the ResNet-50/101/152 depth
//!   variants of Table 11.
//! * `cost`   — the `Scheme` key type and per-layer/model timing,
//!   dispatched through `kernels::backend::BackendRegistry` (each
//!   backend owns its Tables-6/7 trace face or host cost model).
//! * `forward`— functional packed-bit forward pass, registry-driven
//!   (`forward_with` picks the backend; used by tests and the cifar
//!   example; ImageNet-scale timing never executes bits).

pub mod cost;
pub mod forward;
pub mod layer;
pub mod model;
pub mod parser;

pub use cost::{model_cost, InferenceCost, LayerCost, ResidualMode, Scheme};
pub use layer::LayerSpec;
pub use model::{all_models, ModelDef};
