//! Per-layer inference timing for the Tables-6/7 scheme rows, plus the
//! sensitivity knobs of §7.5 (sync overhead, residual handling, batch).
//!
//! The whole network runs as ONE fused kernel (§6.2): a single launch,
//! with a cooperative-group grid barrier after every layer.  Each layer
//! contributes the kernel trace of its scheme-specific implementation.
//!
//! Since the `KernelBackend` redesign the scheme-specific trace and
//! host-model code lives with each backend in `kernels::backends`;
//! this module keeps the [`Scheme`] key type, the model-level
//! accounting ([`model_cost`]), and thin [`layer_secs`] /
//! [`layer_traces`] wrappers that dispatch through
//! `BackendRegistry::global()` — no per-scheme `match` remains here.

use std::fmt;

use crate::kernels::backend::BackendRegistry;
use crate::sim::{Engine, GpuModel, KernelTrace};

use super::layer::{Dims, LayerSpec};
use super::model::ModelDef;

/// Calibrated host constants for the `Scheme::Fastpath` cost model
/// (re-exported from the fastpath backend for compatibility).
pub use crate::kernels::backends::fastpath::host;

/// Tables-6/7 scheme rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Sbnn32,
    Sbnn32Fine,
    Sbnn64,
    Sbnn64Fine,
    /// BTC with the default (sequential) bit format
    Btc,
    /// BTC with the FSB format (§5.1)
    BtcFmt,
    /// host blocked-u64 XNOR-popcount backend (`kernels::fastpath`) —
    /// no GPU traces; costed by the backend's analytic host model
    Fastpath,
    /// host explicit-SIMD popcount backend (`kernels::simd`) — the
    /// fastpath's blocking with the inner product dispatched through a
    /// runtime-detected `PopcountEngine`; analytic host cost model
    Simd,
    /// host sparse backend (`kernels::backends::sparse`): CSR-of-bit-
    /// lines weights/adjacency, XNOR-popcount over *present* blocks
    /// only; cost face parameterized on stored-block counts
    Spmm,
    /// host fused sparse GCN backend: aggregate+combine in one pass
    /// with lazily-memoized per-node-block combine
    GcnFused,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Sbnn32 => "SBNN-32",
            Scheme::Sbnn32Fine => "SBNN-32-Fine",
            Scheme::Sbnn64 => "SBNN-64",
            Scheme::Sbnn64Fine => "SBNN-64-Fine",
            Scheme::Btc => "BTC",
            Scheme::BtcFmt => "BTC-FMT",
            Scheme::Fastpath => "FASTPATH",
            Scheme::Simd => "SIMD",
            Scheme::Spmm => "SPMM",
            Scheme::GcnFused => "GCN-FUSED",
        }
    }

    pub fn all() -> [Scheme; 10] {
        [
            Scheme::Sbnn32,
            Scheme::Sbnn32Fine,
            Scheme::Sbnn64,
            Scheme::Sbnn64Fine,
            Scheme::Btc,
            Scheme::BtcFmt,
            Scheme::Fastpath,
            Scheme::Simd,
            Scheme::Spmm,
            Scheme::GcnFused,
        ]
    }

    /// Whether this scheme executes on the serving host's cores (no
    /// GPU trace face; analytic/calibrated host cost model).
    pub fn is_host(&self) -> bool {
        matches!(
            self,
            Scheme::Fastpath | Scheme::Simd | Scheme::Spmm | Scheme::GcnFused
        )
    }

    /// Inverse of `name` (used by the engine's plan serialization and
    /// CLI flags).  Case-insensitive; an unknown name errors with the
    /// full list of valid scheme names.
    pub fn from_name(s: &str) -> Result<Scheme, UnknownScheme> {
        Scheme::all()
            .into_iter()
            .find(|sc| sc.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| UnknownScheme(s.to_string()))
    }
}

/// Error from [`Scheme::from_name`]: the offending name, displayed with
/// every valid scheme name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownScheme(pub String);

impl fmt::Display for UnknownScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown scheme {:?}; valid schemes: {}",
            self.0,
            Scheme::all().map(|s| s.name()).join(", ")
        )
    }
}

impl std::error::Error for UnknownScheme {}

/// Fig-26 residual-handling scenarios for the ResNet models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualMode {
    /// save + fetch real-valued residuals (normal operation)
    Full,
    /// save without fetching (Fig 26 scenario b)
    SaveOnly,
    /// fetch without saving (scenario c)
    FetchOnly,
    /// no residual traffic at all (scenario d)
    None,
}

/// One layer's simulated cost.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub tag: String,
    pub secs: f64,
    pub sync_secs: f64,
}

/// Whole-model cost.
#[derive(Clone, Debug)]
pub struct InferenceCost {
    pub model: String,
    pub scheme: Scheme,
    pub batch: usize,
    pub layers: Vec<LayerCost>,
    pub total_secs: f64,
    pub sync_secs: f64,
}

impl InferenceCost {
    pub fn throughput_fps(&self) -> f64 {
        self.batch as f64 / self.total_secs
    }
}

/// The kernel traces of one layer under `scheme`, in the fused-kernel
/// view (no per-layer launches).  `dims` is the layer's *input* dims;
/// `model_has_residuals` gates residual traffic exactly like
/// `model_cost` does for ResNet models.  Dispatches through the global
/// [`BackendRegistry`]; host backends (e.g. `Scheme::Fastpath`) have
/// no GPU trace face and return empty — see [`layer_secs`].
pub fn layer_traces(
    scheme: Scheme,
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> Vec<KernelTrace> {
    BackendRegistry::global()
        .get(scheme)
        .expect("every builtin scheme has a registered backend")
        .layer_traces(layer, dims, batch, residual, model_has_residuals)
}

/// Simulated seconds of one layer under `scheme` (compute only — the
/// per-layer cooperative sync and the one-off kernel launch overhead are
/// accounted at the model level).  This is the single source of truth
/// shared by [`model_cost`] and `engine::Planner`, dispatched through
/// the global [`BackendRegistry`].
pub fn layer_secs(
    engine: &Engine,
    scheme: Scheme,
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> f64 {
    BackendRegistry::global()
        .get(scheme)
        .expect("every builtin scheme has a registered backend")
        .layer_secs(engine, layer, dims, batch, residual, model_has_residuals)
}

/// Simulate one model under a scheme.
pub fn model_cost(
    model: &ModelDef,
    batch: usize,
    gpu: &GpuModel,
    scheme: Scheme,
    residual: ResidualMode,
    layer_sync: bool,
) -> InferenceCost {
    let engine = Engine::new(gpu);
    let mut dims = model.input;
    let mut layers = Vec::new();
    let mut total = 0.0;
    let mut sync_total = 0.0;
    let sync_secs_each = if layer_sync {
        gpu.secs(gpu.coop_sync_cycles)
    } else {
        0.0
    };
    // one fused kernel: a single launch overhead for the whole net
    total += gpu.launch_overhead_s;

    for l in &model.layers {
        let secs = layer_secs(
            &engine,
            scheme,
            l,
            dims,
            batch,
            residual,
            model.residual_blocks > 0,
        );
        total += secs + sync_secs_each;
        sync_total += sync_secs_each;
        layers.push(LayerCost { tag: l.tag(), secs, sync_secs: sync_secs_each });
        dims = dims.after(l);
    }
    InferenceCost {
        model: model.name.to_string(),
        scheme,
        batch,
        layers,
        total_secs: total,
        sync_secs: sync_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model;
    use crate::sim::{RTX2080, RTX2080TI};

    fn latency(m: &ModelDef, s: Scheme) -> f64 {
        model_cost(m, 8, &RTX2080TI, s, ResidualMode::Full, true).total_secs
    }

    #[test]
    fn from_name_is_case_insensitive_inverse_of_name() {
        for s in Scheme::all() {
            assert_eq!(Scheme::from_name(s.name()), Ok(s));
            assert_eq!(Scheme::from_name(&s.name().to_lowercase()), Ok(s));
        }
        assert_eq!(Scheme::from_name("fastpath"), Ok(Scheme::Fastpath));
        assert_eq!(Scheme::from_name("btc-fmt"), Ok(Scheme::BtcFmt));
        let err = Scheme::from_name("WARP-9").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("WARP-9"), "{msg}");
        for s in Scheme::all() {
            assert!(msg.contains(s.name()), "{msg} missing {}", s.name());
        }
    }

    #[test]
    fn fastpath_costs_finite_and_batch_scalable() {
        // the host schemes have no GPU traces but must still produce
        // sane, monotone costs for every Table-5 model
        for m in model::all_models() {
            for s in Scheme::all().into_iter().filter(Scheme::is_host) {
                let lat = model_cost(&m, 8, &RTX2080TI, s, ResidualMode::Full, true);
                assert!(
                    lat.total_secs.is_finite() && lat.total_secs > 0.0,
                    "{} {}",
                    m.name,
                    s.name()
                );
                let tp = model_cost(&m, 128, &RTX2080TI, s, ResidualMode::Full, true);
                assert!(
                    tp.throughput_fps() > lat.throughput_fps(),
                    "{} {}: host fps must grow with batch",
                    m.name,
                    s.name()
                );
            }
        }
        for s in Scheme::all() {
            let traces = layer_traces(
                s,
                &LayerSpec::BinFc { d_in: 1024, d_out: 1024 },
                crate::nn::layer::Dims { hw: 0, feat: 1024 },
                8,
                ResidualMode::Full,
                false,
            );
            // GPU schemes have kernel traces; host schemes (fastpath,
            // SIMD) have none by construction
            assert_eq!(traces.is_empty(), s.is_host(), "{}", s.name());
        }
    }

    #[test]
    fn btc_beats_sbnn_on_all_six_models() {
        // the paper's headline: BTC-FMT ~2.2x faster than SBNN-64-Fine
        for m in model::all_models() {
            let sbnn = latency(&m, Scheme::Sbnn64Fine);
            let btc = latency(&m, Scheme::BtcFmt);
            assert!(
                btc < sbnn,
                "{}: btc {btc} !< sbnn64fine {sbnn}",
                m.name
            );
        }
    }

    #[test]
    fn fmt_no_slower_than_default_btc() {
        for m in model::all_models() {
            let d = latency(&m, Scheme::Btc);
            let f = latency(&m, Scheme::BtcFmt);
            assert!(f <= d * 1.02, "{}: fmt {f} vs btc {d}", m.name);
        }
    }

    #[test]
    fn first_layer_dominates_imagenet_models() {
        // Fig 24: first layer is the largest single contributor for the
        // ImageNet models (>= 35%)
        for m in [model::imagenet_alexnet(), model::imagenet_vgg16(), model::imagenet_resnet18()] {
            let c = model_cost(&m, 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
            let first = c.layers[0].secs;
            let frac = first / c.total_secs;
            assert!(frac > 0.2, "{}: first-layer share {frac}", m.name);
            let max_other = c.layers[1..]
                .iter()
                .map(|l| l.secs)
                .fold(0.0f64, f64::max);
            assert!(first > max_other, "{}: first not dominant", m.name);
        }
    }

    #[test]
    fn residual_overhead_order() {
        // Fig 26: full > save-only/fetch-only > none
        let m = model::imagenet_resnet18();
        let t = |r| model_cost(&m, 8, &RTX2080, Scheme::BtcFmt, r, true).total_secs;
        let full = t(ResidualMode::Full);
        let save = t(ResidualMode::SaveOnly);
        let none = t(ResidualMode::None);
        assert!(full > save && save > none);
        // Fig 26 magnitude: eliminating residuals gains ~9% latency
        let gain = (full - none) / full;
        assert!(gain > 0.01 && gain < 0.30, "gain {gain}");
    }

    #[test]
    fn sync_overhead_mid_models_highest() {
        // Table 10: sync overhead share is highest for the Cifar models
        let share = |m: &ModelDef| {
            let with = model_cost(m, 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
            (with.sync_secs) / with.total_secs
        };
        let cifar = share(&model::cifar_vgg());
        let mnist = share(&model::mnist_mlp());
        let imagenet = share(&model::imagenet_vgg16());
        assert!(cifar > imagenet, "cifar {cifar} vs imagenet {imagenet}");
        let _ = mnist; // mnist is tiny-but-shallow; no ordering claim
    }

    #[test]
    fn batch_scaling_saturates() {
        // Fig 25: throughput grows with batch then saturates
        let m = model::imagenet_resnet18();
        let fps = |b: usize| {
            model_cost(&m, b, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true)
                .throughput_fps()
        };
        let f8 = fps(8);
        let f128 = fps(128);
        let f512 = fps(512);
        // Table 6: BTC ResNet18 gains ~28% from batch 8 -> 512; Fig 25:
        // batch 128 is enough for ImageNet to reach the plateau
        assert!(f128 > f8 * 1.02, "f8 {f8} f128 {f128}");
        assert!(f512 >= f128 * 0.85, "f512 {f512} f128 {f128}");
        assert!(f512 < f128 * 1.5, "should be near saturation");
    }

    #[test]
    fn depth_scaling_linear_ish() {
        // Table 11: latency grows ~linearly with ResNet depth
        let t = |d: usize| {
            model_cost(&model::imagenet_resnet(d), 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true)
                .total_secs
        };
        let (t18, t50, t101, t152) = (t(18), t(50), t(101), t(152));
        assert!(t18 < t50 && t50 < t101 && t101 < t152);
        // paper Table 11: 18 -> 152 is ~8.7x on 2080; allow a wide band
        let ratio = t152 / t18;
        assert!(ratio > 3.0 && ratio < 20.0, "ratio {ratio}");
    }

    #[test]
    fn ti_faster_than_2080_at_throughput_batch() {
        // Tables 6 vs 7: the 2080Ti's extra SMs/bandwidth win once the
        // batch is large enough to fill the chip.
        let m = model::imagenet_resnet18();
        let ti = model_cost(&m, 512, &RTX2080TI, Scheme::BtcFmt, ResidualMode::Full, true);
        let g2080 = model_cost(&m, 512, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
        assert!(ti.total_secs < g2080.total_secs);
    }
}
