//! Per-layer inference timing for the Tables-6/7 scheme rows, plus the
//! sensitivity knobs of §7.5 (sync overhead, residual handling, batch).
//!
//! The whole network runs as ONE fused kernel (§6.2): a single launch,
//! with a cooperative-group grid barrier after every layer.  Each layer
//! contributes the kernel trace of its scheme-specific implementation.

use crate::kernels::bconv::{self, BconvProblem, BconvScheme};
use crate::kernels::bmm::{self, BmmProblem, BmmScheme};
use crate::kernels::IoMode;
use crate::sim::{Engine, GpuModel, KernelTrace};

use super::layer::{Dims, LayerSpec};
use super::model::ModelDef;

/// Tables-6/7 scheme rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Sbnn32,
    Sbnn32Fine,
    Sbnn64,
    Sbnn64Fine,
    /// BTC with the default (sequential) bit format
    Btc,
    /// BTC with the FSB format (§5.1)
    BtcFmt,
    /// host blocked-u64 XNOR-popcount backend (`kernels::fastpath`) —
    /// no GPU traces; costed by the calibrated host model below
    Fastpath,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Sbnn32 => "SBNN-32",
            Scheme::Sbnn32Fine => "SBNN-32-Fine",
            Scheme::Sbnn64 => "SBNN-64",
            Scheme::Sbnn64Fine => "SBNN-64-Fine",
            Scheme::Btc => "BTC",
            Scheme::BtcFmt => "BTC-FMT",
            Scheme::Fastpath => "FASTPATH",
        }
    }

    pub fn all() -> [Scheme; 7] {
        [
            Scheme::Sbnn32,
            Scheme::Sbnn32Fine,
            Scheme::Sbnn64,
            Scheme::Sbnn64Fine,
            Scheme::Btc,
            Scheme::BtcFmt,
            Scheme::Fastpath,
        ]
    }

    /// Inverse of `name` (used by the engine's plan serialization).
    pub fn from_name(s: &str) -> Option<Scheme> {
        Scheme::all().into_iter().find(|sc| sc.name() == s)
    }

    fn is_fine(&self) -> bool {
        matches!(self, Scheme::Sbnn32Fine | Scheme::Sbnn64Fine)
    }
}

/// Fig-26 residual-handling scenarios for the ResNet models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualMode {
    /// save + fetch real-valued residuals (normal operation)
    Full,
    /// save without fetching (Fig 26 scenario b)
    SaveOnly,
    /// fetch without saving (scenario c)
    FetchOnly,
    /// no residual traffic at all (scenario d)
    None,
}

/// One layer's simulated cost.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub tag: String,
    pub secs: f64,
    pub sync_secs: f64,
}

/// Whole-model cost.
#[derive(Clone, Debug)]
pub struct InferenceCost {
    pub model: String,
    pub scheme: Scheme,
    pub batch: usize,
    pub layers: Vec<LayerCost>,
    pub total_secs: f64,
    pub sync_secs: f64,
}

impl InferenceCost {
    pub fn throughput_fps(&self) -> f64 {
        self.batch as f64 / self.total_secs
    }
}

fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// Fine-grained SBNN: split each warp's work 4 ways for occupancy (the
/// "-Fine" rows): more, lighter warps plus atomic combine overhead.
fn make_fine(t: &mut KernelTrace) {
    t.grid_ctas *= 4;
    t.warp.intu_ops = t.warp.intu_ops / 4 + 32;
    t.warp.sfu_ops /= 4;
    t.warp.bulk_load_bytes /= 4;
    t.warp.bulk_store_bytes += 64; // partial-sum atomics
}

/// First-layer BWN trace (same for every scheme — BTC can't run it).
fn first_conv_trace(
    dims: Dims,
    batch: usize,
    o: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> KernelTrace {
    let c = dims.feat;
    let ohw = (dims.hw + 2 * pad - k) / stride + 1;
    let outputs = ohw * ohw * o * batch;
    let mut t = KernelTrace::new("first_conv");
    let warps = outputs.div_ceil(32).max(1);
    t.warps_per_cta = 8;
    t.grid_ctas = warps.div_ceil(8).max(1);
    // per warp: 32 outputs; per output K*K*C adds with bit extraction
    // from the shared-memory weight buffer (§6.1: extract each weight
    // bit, add or subtract the fp input element)
    let taps = k * k * c;
    t.warp.fp_ops = 32 * taps * 3; // extract + select + add/sub per tap
    // fp32 input window loads, partially cached across channel warps
    t.warp.bulk_load_bytes = (taps * 4 * 32 / 8).max(128);
    t.warp.bulk_store_bytes = 32 / 8; // thresholded bits out
    t.warp.cta_syncs = 1;
    let in_bytes = (dims.hw * dims.hw * c * batch * 4) as f64;
    t.compulsory_bytes = in_bytes + (outputs / 8) as f64;
    t.load_footprint_bytes = in_bytes;
    // the window walk is pixel-tiled: resident set stays small
    t.wave_bytes_per_cta = 64.0 * 1024.0;
    t
}

/// Residual save/fetch traffic for one block boundary (real-valued
/// residuals, §6.1: "these residuals are real-valued").
fn residual_trace(elems: usize, mode: ResidualMode) -> Option<KernelTrace> {
    let (save, fetch) = match mode {
        ResidualMode::Full => (true, true),
        ResidualMode::SaveOnly => (true, false),
        ResidualMode::FetchOnly => (false, true),
        ResidualMode::None => return None,
    };
    let mut t = KernelTrace::new("residual");
    let warps = (elems / 1024).max(1);
    t.warps_per_cta = 8;
    t.grid_ctas = warps.div_ceil(8).max(1);
    let per_warp = 1024 * 2; // residuals kept in fp16 (half the traffic)
    if save {
        t.warp.bulk_store_bytes += per_warp;
    }
    if fetch {
        t.warp.bulk_load_bytes += per_warp;
        t.warp.fp_ops += 1024; // add into the activation
    }
    t.compulsory_bytes = (elems * 2 * ((save as usize) + (fetch as usize))) as f64;
    Some(t)
}

/// The scheme-specific BinConv traces.
fn bin_conv_traces(
    scheme: Scheme,
    dims: Dims,
    batch: usize,
    o: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Vec<KernelTrace> {
    match scheme {
        Scheme::Btc | Scheme::BtcFmt => {
            let p = BconvProblem {
                hw: dims.hw,
                n: round_up(batch, 8),
                c: round_up(dims.feat, 128),
                o: round_up(o, 8),
                k,
                stride,
                pad,
            };
            let s: Box<dyn BconvScheme> = if scheme == Scheme::Btc {
                Box::new(bconv::btc::BconvDesign1)
            } else {
                Box::new(bconv::btc::BconvDesign2)
            };
            s.traces(p, IoMode::BnnSpecific)
        }
        _ => {
            let word = if matches!(scheme, Scheme::Sbnn32 | Scheme::Sbnn32Fine) {
                32
            } else {
                64
            };
            let p = BconvProblem {
                hw: dims.hw,
                n: batch,
                c: round_up(dims.feat, word),
                o: round_up(o, 32),
                k,
                stride,
                pad,
            };
            let mut traces =
                bconv::bstc::BstcBconv::new(word).traces(p, IoMode::BnnSpecific);
            if scheme.is_fine() {
                traces.iter_mut().for_each(make_fine);
            }
            traces
        }
    }
}

/// The scheme-specific FC traces.
fn fc_traces(scheme: Scheme, batch: usize, d_in: usize, d_out: usize) -> Vec<KernelTrace> {
    match scheme {
        Scheme::Btc | Scheme::BtcFmt => {
            let p = BmmProblem {
                m: round_up(batch, 8),
                n: round_up(d_out, 128),
                k: round_up(d_in, 128),
            };
            let s: Box<dyn BmmScheme> = if scheme == Scheme::Btc {
                Box::new(bmm::btc::Design1)
            } else {
                Box::new(bmm::btc::Design3)
            };
            s.traces(p, IoMode::BnnSpecific)
        }
        _ => {
            let word = if matches!(scheme, Scheme::Sbnn32 | Scheme::Sbnn32Fine) {
                32
            } else {
                64
            };
            let p = BmmProblem {
                m: round_up(batch, word),
                n: round_up(d_out, word),
                k: round_up(d_in, word),
            };
            let fine = scheme.is_fine();
            bmm::bstc::BstcBmm::new(word, fine).traces(p, IoMode::BnnSpecific)
        }
    }
}

/// Calibrated host constants for the `Scheme::Fastpath` cost model —
/// the blocked u64 backend in `kernels::fastpath` runs on the serving
/// host's cores, not the GPU, so its cost is modeled analytically
/// instead of through `sim::KernelTrace`.  Constants are deliberately
/// conservative multi-core laptop/server numbers; refresh them against
/// `cargo bench --bench bench_kernels` when the host class changes.
pub mod host {
    /// u64 XOR+POPC+accumulate word ops per second (all cores, blocked).
    pub const WORD_OPS_PER_SEC: f64 = 6.0e9;
    /// f32 multiply-accumulates per second (the first BWN layer).
    pub const FP_OPS_PER_SEC: f64 = 8.0e9;
    /// streamed bytes per second (packing, pooling, residual traffic).
    pub const BYTES_PER_SEC: f64 = 1.2e10;
    /// scoped fork/join + repack latency per parallel section.
    pub const DISPATCH_SECS: f64 = 3.0e-6;
}

/// Host-model seconds for one layer under `Scheme::Fastpath`.
fn fastpath_layer_secs(
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> f64 {
    let out_hw = |k: usize, stride: usize, pad: usize| -> usize {
        (dims.hw + 2 * pad - k) / stride + 1
    };
    match *layer {
        LayerSpec::FirstConv { c, o, k, stride, pad } => {
            let ohw = out_hw(k, stride, pad);
            let fp = (ohw * ohw * batch * o * k * k * c) as f64;
            fp / host::FP_OPS_PER_SEC + host::DISPATCH_SECS
        }
        LayerSpec::BinConv { o, k, stride, pad, residual: is_res, .. } => {
            // filters beyond the fastpath tap limit cannot run there:
            // cost them infinite so no plan ever selects the scheme
            if k * k > crate::kernels::fastpath::bconv::MAX_TAPS {
                return f64::INFINITY;
            }
            let c = dims.feat;
            let ohw = out_hw(k, stride, pad);
            let words = (ohw * ohw * batch * o * k * k * c.div_ceil(64)) as f64;
            // im2row build + output repack are streamed bytes
            let stream = (ohw * ohw * batch * (k * k * c.div_ceil(8) + o)) as f64;
            let mut secs = words / host::WORD_OPS_PER_SEC
                + stream / host::BYTES_PER_SEC
                + host::DISPATCH_SECS;
            if is_res && model_has_residuals && residual != ResidualMode::None {
                let out_dims = dims.after(layer);
                // fp16 residual save/fetch, same accounting as the GPU path
                let xfers = match residual {
                    ResidualMode::Full => 2,
                    ResidualMode::SaveOnly | ResidualMode::FetchOnly => 1,
                    ResidualMode::None => 0,
                };
                secs += (out_dims.flat() * batch * 2 * xfers) as f64
                    / host::BYTES_PER_SEC;
            }
            secs
        }
        LayerSpec::BinFc { d_in, d_out } | LayerSpec::FinalFc { d_in, d_out } => {
            let words = (batch * d_out * d_in.div_ceil(64)) as f64;
            words / host::WORD_OPS_PER_SEC + host::DISPATCH_SECS
        }
        LayerSpec::Pool => {
            // 4 packed loads + 1 store per output word
            let bytes = (dims.flat() * batch).div_ceil(8) as f64;
            bytes * 5.0 / host::BYTES_PER_SEC + host::DISPATCH_SECS
        }
    }
}

/// The kernel traces of one layer under `scheme`, in the fused-kernel
/// view (no per-layer launches).  `dims` is the layer's *input* dims;
/// `model_has_residuals` gates residual traffic exactly like
/// `model_cost` does for ResNet models.  This is the single source of
/// truth shared by `model_cost` and `engine::Planner`.
///
/// `Scheme::Fastpath` runs on the host, not the GPU: it has no kernel
/// traces (this returns empty) and is costed analytically — see
/// [`layer_secs`].
pub fn layer_traces(
    scheme: Scheme,
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> Vec<KernelTrace> {
    if scheme == Scheme::Fastpath {
        return Vec::new();
    }
    let mut traces: Vec<KernelTrace> = match *layer {
        LayerSpec::FirstConv { o, k, stride, pad, .. } => {
            vec![first_conv_trace(dims, batch, o, k, stride, pad)]
        }
        LayerSpec::BinConv { o, k, stride, pad, residual: is_res, pool: _, .. } => {
            let mut v = bin_conv_traces(scheme, dims, batch, o, k, stride, pad);
            if is_res && model_has_residuals {
                let out_dims = dims.after(layer);
                let elems = out_dims.flat() * batch;
                if let Some(rt) = residual_trace(elems, residual) {
                    v.push(rt);
                }
            }
            v
        }
        LayerSpec::BinFc { d_in, d_out } => fc_traces(scheme, batch, d_in, d_out),
        LayerSpec::FinalFc { d_in, d_out } => {
            // real-valued output: int store + bn, no output binarize
            let mut v = fc_traces(scheme, batch, d_in, round_up(d_out, 8));
            for t in &mut v {
                t.warp.bulk_store_bytes += 8 * 4; // int32 out per tile
                t.warp.fp_ops += 64; // bn scale/shift
            }
            v
        }
        LayerSpec::Pool => {
            let mut t = KernelTrace::new("pool");
            let elems = dims.flat() * batch / 8; // packed bytes
            t.grid_ctas = (elems / 4096).max(1);
            t.warps_per_cta = 8;
            t.warp.bulk_load_bytes = 4096;
            t.warp.bulk_store_bytes = 1024;
            t.warp.intu_ops = 3 * 1024;
            vec![t]
        }
    };
    // the fused kernel has no per-layer launches
    for t in &mut traces {
        t.launches = 0;
    }
    traces
}

/// Simulated seconds of one layer under `scheme` (compute only — the
/// per-layer cooperative sync and the one-off kernel launch overhead are
/// accounted at the model level).
pub fn layer_secs(
    engine: &Engine,
    scheme: Scheme,
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> f64 {
    if scheme == Scheme::Fastpath {
        return fastpath_layer_secs(layer, dims, batch, residual, model_has_residuals);
    }
    layer_traces(scheme, layer, dims, batch, residual, model_has_residuals)
        .iter()
        .map(|t| engine.cost(t).total_secs)
        .sum()
}

/// Simulate one model under a scheme.
pub fn model_cost(
    model: &ModelDef,
    batch: usize,
    gpu: &GpuModel,
    scheme: Scheme,
    residual: ResidualMode,
    layer_sync: bool,
) -> InferenceCost {
    let engine = Engine::new(gpu);
    let mut dims = model.input;
    let mut layers = Vec::new();
    let mut total = 0.0;
    let mut sync_total = 0.0;
    let sync_secs_each = if layer_sync {
        gpu.secs(gpu.coop_sync_cycles)
    } else {
        0.0
    };
    // one fused kernel: a single launch overhead for the whole net
    total += gpu.launch_overhead_s;

    for l in &model.layers {
        let secs = layer_secs(
            &engine,
            scheme,
            l,
            dims,
            batch,
            residual,
            model.residual_blocks > 0,
        );
        total += secs + sync_secs_each;
        sync_total += sync_secs_each;
        layers.push(LayerCost { tag: l.tag(), secs, sync_secs: sync_secs_each });
        dims = dims.after(l);
    }
    InferenceCost {
        model: model.name.to_string(),
        scheme,
        batch,
        layers,
        total_secs: total,
        sync_secs: sync_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model;
    use crate::sim::{RTX2080, RTX2080TI};

    fn latency(m: &ModelDef, s: Scheme) -> f64 {
        model_cost(m, 8, &RTX2080TI, s, ResidualMode::Full, true).total_secs
    }

    #[test]
    fn fastpath_costs_finite_and_batch_scalable() {
        // the host scheme has no GPU traces but must still produce
        // sane, monotone costs for every Table-5 model
        for m in model::all_models() {
            let lat =
                model_cost(&m, 8, &RTX2080TI, Scheme::Fastpath, ResidualMode::Full, true);
            assert!(
                lat.total_secs.is_finite() && lat.total_secs > 0.0,
                "{}",
                m.name
            );
            let tp = model_cost(
                &m,
                128,
                &RTX2080TI,
                Scheme::Fastpath,
                ResidualMode::Full,
                true,
            );
            assert!(
                tp.throughput_fps() > lat.throughput_fps(),
                "{}: fastpath fps must grow with batch",
                m.name
            );
        }
        assert_eq!(Scheme::from_name("FASTPATH"), Some(Scheme::Fastpath));
        for s in Scheme::all() {
            if s != Scheme::Fastpath {
                assert!(
                    !layer_traces(
                        s,
                        &LayerSpec::BinFc { d_in: 1024, d_out: 1024 },
                        crate::nn::layer::Dims { hw: 0, feat: 1024 },
                        8,
                        ResidualMode::Full,
                        false,
                    )
                    .is_empty()
                );
            }
        }
        // fastpath has no GPU kernel traces by construction
        assert!(layer_traces(
            Scheme::Fastpath,
            &LayerSpec::BinFc { d_in: 1024, d_out: 1024 },
            crate::nn::layer::Dims { hw: 0, feat: 1024 },
            8,
            ResidualMode::Full,
            false,
        )
        .is_empty());
    }

    #[test]
    fn btc_beats_sbnn_on_all_six_models() {
        // the paper's headline: BTC-FMT ~2.2x faster than SBNN-64-Fine
        for m in model::all_models() {
            let sbnn = latency(&m, Scheme::Sbnn64Fine);
            let btc = latency(&m, Scheme::BtcFmt);
            assert!(
                btc < sbnn,
                "{}: btc {btc} !< sbnn64fine {sbnn}",
                m.name
            );
        }
    }

    #[test]
    fn fmt_no_slower_than_default_btc() {
        for m in model::all_models() {
            let d = latency(&m, Scheme::Btc);
            let f = latency(&m, Scheme::BtcFmt);
            assert!(f <= d * 1.02, "{}: fmt {f} vs btc {d}", m.name);
        }
    }

    #[test]
    fn first_layer_dominates_imagenet_models() {
        // Fig 24: first layer is the largest single contributor for the
        // ImageNet models (>= 35%)
        for m in [model::imagenet_alexnet(), model::imagenet_vgg16(), model::imagenet_resnet18()] {
            let c = model_cost(&m, 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
            let first = c.layers[0].secs;
            let frac = first / c.total_secs;
            assert!(frac > 0.2, "{}: first-layer share {frac}", m.name);
            let max_other = c.layers[1..]
                .iter()
                .map(|l| l.secs)
                .fold(0.0f64, f64::max);
            assert!(first > max_other, "{}: first not dominant", m.name);
        }
    }

    #[test]
    fn residual_overhead_order() {
        // Fig 26: full > save-only/fetch-only > none
        let m = model::imagenet_resnet18();
        let t = |r| model_cost(&m, 8, &RTX2080, Scheme::BtcFmt, r, true).total_secs;
        let full = t(ResidualMode::Full);
        let save = t(ResidualMode::SaveOnly);
        let none = t(ResidualMode::None);
        assert!(full > save && save > none);
        // Fig 26 magnitude: eliminating residuals gains ~9% latency
        let gain = (full - none) / full;
        assert!(gain > 0.01 && gain < 0.30, "gain {gain}");
    }

    #[test]
    fn sync_overhead_mid_models_highest() {
        // Table 10: sync overhead share is highest for the Cifar models
        let share = |m: &ModelDef| {
            let with = model_cost(m, 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
            (with.sync_secs) / with.total_secs
        };
        let cifar = share(&model::cifar_vgg());
        let mnist = share(&model::mnist_mlp());
        let imagenet = share(&model::imagenet_vgg16());
        assert!(cifar > imagenet, "cifar {cifar} vs imagenet {imagenet}");
        let _ = mnist; // mnist is tiny-but-shallow; no ordering claim
    }

    #[test]
    fn batch_scaling_saturates() {
        // Fig 25: throughput grows with batch then saturates
        let m = model::imagenet_resnet18();
        let fps = |b: usize| {
            model_cost(&m, b, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true)
                .throughput_fps()
        };
        let f8 = fps(8);
        let f128 = fps(128);
        let f512 = fps(512);
        // Table 6: BTC ResNet18 gains ~28% from batch 8 -> 512; Fig 25:
        // batch 128 is enough for ImageNet to reach the plateau
        assert!(f128 > f8 * 1.02, "f8 {f8} f128 {f128}");
        assert!(f512 >= f128 * 0.85, "f512 {f512} f128 {f128}");
        assert!(f512 < f128 * 1.5, "should be near saturation");
    }

    #[test]
    fn depth_scaling_linear_ish() {
        // Table 11: latency grows ~linearly with ResNet depth
        let t = |d: usize| {
            model_cost(&model::imagenet_resnet(d), 8, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true)
                .total_secs
        };
        let (t18, t50, t101, t152) = (t(18), t(50), t(101), t(152));
        assert!(t18 < t50 && t50 < t101 && t101 < t152);
        // paper Table 11: 18 -> 152 is ~8.7x on 2080; allow a wide band
        let ratio = t152 / t18;
        assert!(ratio > 3.0 && ratio < 20.0, "ratio {ratio}");
    }

    #[test]
    fn ti_faster_than_2080_at_throughput_batch() {
        // Tables 6 vs 7: the 2080Ti's extra SMs/bandwidth win once the
        // batch is large enough to fill the chip.
        let m = model::imagenet_resnet18();
        let ti = model_cost(&m, 512, &RTX2080TI, Scheme::BtcFmt, ResidualMode::Full, true);
        let g2080 = model_cost(&m, 512, &RTX2080, Scheme::BtcFmt, ResidualMode::Full, true);
        assert!(ti.total_secs < g2080.total_secs);
    }
}
