//! Layer specifications (inference view, after the §6.1 rewrites).

use crate::sparse::AdjSpec;

/// One layer of a BNN model, in inference form: every hidden layer
/// consumes and produces packed bits; bn+sign pairs are a threshold
/// (`thrd`) fused into the producing layer; max-pool is an OR fused
/// after the threshold.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// First conv layer (BWN): fp input x binarized weights (§6.1 —
    /// cannot use BTC).  Output is thresholded to bits.
    FirstConv { c: usize, o: usize, k: usize, stride: usize, pad: usize },
    /// Binarized convolution (+ fused thrd, optional OR-pool).
    BinConv {
        c: usize,
        o: usize,
        k: usize,
        stride: usize,
        pad: usize,
        pool: bool,
        /// ends a 2-conv residual block (ResNet shortcut injection)
        residual: bool,
    },
    /// Binarized fully-connected layer (+ fused thrd).
    BinFc { d_in: usize, d_out: usize },
    /// Binary GCN layer (BitGNN): per-node Eq-2 combine against dense
    /// +/-1 weights (`d_in -> d_out` per node), binarize, then masked
    /// aggregation over the graph adjacency (+ fused thrd).  The
    /// activation is flat `nodes * d_in` bits in, `nodes * d_out` bits
    /// out; `d_in`/`d_out` must be multiples of 64 so node rows stay
    /// u64-aligned.  Adjacency is regenerated from `adj` wherever
    /// weights materialize; `nnz_blocks` is its realized stored-block
    /// count — the sparsity the cost faces and plan tags key on.
    BinGcn {
        nodes: usize,
        d_in: usize,
        d_out: usize,
        adj: AdjSpec,
        nnz_blocks: usize,
    },
    /// Final FC layer: binarized weights, real-valued output + bn (§6.1:
    /// bn cannot become thrd here).
    FinalFc { d_in: usize, d_out: usize },
    /// Standalone 2x2 OR max-pool (when not fusable into a conv).
    Pool,
}

impl LayerSpec {
    /// Short display tag ("128C3/2p", "1024FC", ...).
    pub fn tag(&self) -> String {
        match self {
            LayerSpec::FirstConv { o, k, stride, .. } => {
                format!("{o}C{k}/{stride}*")
            }
            LayerSpec::BinConv { o, k, stride, pool, residual, .. } => {
                let mut s = format!("{o}C{k}");
                if *stride != 1 {
                    s.push_str(&format!("/{stride}"));
                }
                if *pool {
                    s.push('p');
                }
                if *residual {
                    s.push('r');
                }
                s
            }
            LayerSpec::BinFc { d_out, .. } => format!("{d_out}FC"),
            LayerSpec::BinGcn { nodes, d_out, nnz_blocks, .. } => {
                // nnz in the tag: a density change re-tags the layer,
                // which re-fingerprints any cached plan
                format!("{d_out}G{nodes}n{nnz_blocks}")
            }
            LayerSpec::FinalFc { d_out, .. } => format!("{d_out}out"),
            LayerSpec::Pool => "P2".to_string(),
        }
    }

    /// Weight bits of this layer (model-size accounting).
    pub fn weight_bits(&self) -> usize {
        match self {
            LayerSpec::FirstConv { c, o, k, .. }
            | LayerSpec::BinConv { c, o, k, .. } => k * k * c * o,
            LayerSpec::BinFc { d_in, d_out } | LayerSpec::FinalFc { d_in, d_out } => {
                d_in * d_out
            }
            LayerSpec::BinGcn { d_in, d_out, .. } => d_in * d_out,
            LayerSpec::Pool => 0,
        }
    }
}

/// Spatial/feature dims flowing between layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims {
    /// spatial extent (h == w); 0 for flattened FC stages
    pub hw: usize,
    /// channels (conv) or features (fc)
    pub feat: usize,
}

impl Dims {
    /// Dims after applying `layer`.
    pub fn after(&self, layer: &LayerSpec) -> Dims {
        match layer {
            LayerSpec::FirstConv { o, k, stride, pad, .. } => Dims {
                hw: (self.hw + 2 * pad - k) / stride + 1,
                feat: *o,
            },
            LayerSpec::BinConv { o, k, stride, pad, pool, .. } => {
                let mut hw = (self.hw + 2 * pad - k) / stride + 1;
                if *pool {
                    hw /= 2;
                }
                Dims { hw, feat: *o }
            }
            LayerSpec::BinFc { d_out, .. } | LayerSpec::FinalFc { d_out, .. } => {
                Dims { hw: 0, feat: *d_out }
            }
            LayerSpec::BinGcn { nodes, d_out, .. } => {
                Dims { hw: 0, feat: nodes * d_out }
            }
            LayerSpec::Pool => Dims { hw: self.hw / 2, feat: self.feat },
        }
    }

    /// Flattened feature count (conv -> fc transition).
    pub fn flat(&self) -> usize {
        if self.hw == 0 {
            self.feat
        } else {
            self.hw * self.hw * self.feat
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_flow() {
        let d = Dims { hw: 32, feat: 3 };
        let c1 = LayerSpec::FirstConv { c: 3, o: 128, k: 3, stride: 1, pad: 1 };
        let d1 = d.after(&c1);
        assert_eq!(d1, Dims { hw: 32, feat: 128 });
        let c2 = LayerSpec::BinConv {
            c: 128, o: 128, k: 3, stride: 1, pad: 1, pool: true, residual: false,
        };
        let d2 = d1.after(&c2);
        assert_eq!(d2, Dims { hw: 16, feat: 128 });
        assert_eq!(d2.flat(), 16 * 16 * 128);
    }

    #[test]
    fn tags() {
        assert_eq!(
            LayerSpec::BinConv { c: 1, o: 256, k: 3, stride: 2, pad: 1, pool: false, residual: true }.tag(),
            "256C3/2r"
        );
        assert_eq!(LayerSpec::BinFc { d_in: 1, d_out: 1024 }.tag(), "1024FC");
    }

    #[test]
    fn weight_accounting() {
        let l = LayerSpec::BinConv { c: 128, o: 256, k: 3, stride: 1, pad: 1, pool: false, residual: false };
        assert_eq!(l.weight_bits(), 3 * 3 * 128 * 256);
    }

    #[test]
    fn stride_and_dims() {
        let d = Dims { hw: 224, feat: 3 };
        let c = LayerSpec::FirstConv { c: 3, o: 128, k: 11, stride: 4, pad: 0 };
        // AlexNet: (224 - 11)/4 + 1 = 54
        assert_eq!(d.after(&c).hw, 54);
    }
}
