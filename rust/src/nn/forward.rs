//! Functional packed-bit forward pass (small-model execution path).
//!
//! Executes a `ModelDef` on real data with real bit arithmetic — used by
//! tests and the cifar example to demonstrate the full §6 pipeline
//! (thrd -> bconv -> thrd -> OR-pool -> ... -> fc -> bn) in rust.
//! ImageNet-scale *timing* comes from `cost`, not from executing bits.
//!
//! There is ONE entry point, [`forward_with`], dispatched through a
//! [`BackendRegistry`]: the binarized conv/FC kernels come from the
//! registered backend of the chosen [`Scheme`].  Every backend computes
//! exact integer Eq-2 arithmetic, so the output bits are identical for
//! every scheme — [`forward`] is just the convenience wrapper over the
//! global registry.  (The old `forward_fastpath` is gone; call
//! `forward_with(.., Scheme::Fastpath)` instead.)

use std::sync::Arc;

use crate::bitops::{BitMatrix, BitTensor4, Layout, SparseBitMatrix, TensorLayout};
use crate::kernels::backend::{BackendRegistry, ExecCtx};
use crate::kernels::bconv::BconvProblem;
use crate::sparse;
use crate::util::Rng;

use super::cost::Scheme;
use super::layer::LayerSpec;
use super::model::ModelDef;

/// Weights for one layer.
#[derive(Clone, Debug)]
pub enum LayerWeights {
    /// first conv: +/-1 weights as floats (BWN), per-channel thresholds
    FirstConv { w_pm1: Vec<f32>, thresh: Vec<f32> },
    /// binarized conv: KKOC packed filter + per-channel thresholds
    BinConv { filter: BitTensor4, thresh: Vec<f32> },
    /// binarized fc: packed weight rows (d_out x d_in/32) + thresholds
    BinFc { w: BitMatrix, thresh: Vec<f32> },
    /// binary GCN: shared adjacency (regenerated from the layer's
    /// `AdjSpec`, so it is spec-determined, not a stored weight),
    /// packed combine weights (d_out x d_in/32), per-feature thresholds
    BinGcn { adj: Arc<SparseBitMatrix>, w: BitMatrix, thresh: Vec<f32> },
    /// final fc: packed weights + bn scale/shift
    FinalFc { w: BitMatrix, gamma: Vec<f32>, beta: Vec<f32> },
    Pool,
}

/// All weights of a model.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub layers: Vec<LayerWeights>,
}

/// Random +/-1 weights with zero thresholds (pipeline smoke weights).
pub fn random_weights(model: &ModelDef, rng: &mut Rng) -> ModelWeights {
    let mut dims = model.input;
    let mut layers = Vec::new();
    for l in &model.layers {
        layers.push(match *l {
            LayerSpec::FirstConv { c, o, k, .. } => LayerWeights::FirstConv {
                w_pm1: rng.pm1_vec(k * k * c * o),
                thresh: vec![0.0; o],
            },
            LayerSpec::BinConv { c, o, k, .. } => LayerWeights::BinConv {
                filter: BitTensor4::random([k, k, o, c], TensorLayout::Kkoc, rng),
                thresh: vec![0.0; o],
            },
            LayerSpec::BinFc { d_in, d_out } => LayerWeights::BinFc {
                w: BitMatrix::random(d_out, d_in, Layout::RowMajor, rng),
                thresh: vec![0.0; d_out],
            },
            LayerSpec::BinGcn { nodes, d_in, d_out, adj, .. } => LayerWeights::BinGcn {
                adj: Arc::new(sparse::generate(adj, nodes)),
                w: BitMatrix::random(d_out, d_in, Layout::RowMajor, rng),
                thresh: vec![0.0; d_out],
            },
            LayerSpec::FinalFc { d_in, d_out } => LayerWeights::FinalFc {
                w: BitMatrix::random(d_out, d_in, Layout::RowMajor, rng),
                gamma: vec![0.05; d_out],
                beta: vec![0.0; d_out],
            },
            LayerSpec::Pool => LayerWeights::Pool,
        });
        dims = dims.after(l);
    }
    ModelWeights { layers }
}

/// Activation state between layers.
enum Act {
    /// packed bits in HWNC
    Bits(BitTensor4),
    /// packed bit rows per image (batch x features)
    Flat(BitMatrix),
}

impl Act {
    /// flatten HWNC bits into per-image packed rows (h, w, c order).
    fn flatten(self, batch: usize) -> BitMatrix {
        match self {
            Act::Flat(m) => m,
            Act::Bits(t) => {
                let [h, w, n, c] = t.dims;
                assert_eq!(n, batch);
                let feat = h * w * c;
                let mut out = BitMatrix::zeros(batch, feat, Layout::RowMajor);
                for ni in 0..n {
                    let mut idx = 0usize;
                    for hi in 0..h {
                        for wi in 0..w {
                            for ci in 0..c {
                                if t.get(hi, wi, ni, ci) {
                                    out.set(ni, idx, true);
                                }
                                idx += 1;
                            }
                        }
                    }
                }
                out
            }
        }
    }
}

/// The current activation as packed rows: flatten HWNC bits, pass flat
/// rows through, or binarize a flat fp input (first-layer MLPs — the
/// same `>= 0` rule the engine executor applies).
fn flat_rows(
    act: Option<Act>,
    fp_input: &mut Option<Vec<f32>>,
    batch: usize,
    d_in: usize,
) -> BitMatrix {
    match act {
        Some(a) => a.flatten(batch),
        None => {
            let x = fp_input.take().expect("first layer needs fp input");
            assert_eq!(x.len(), batch * d_in, "flat input size");
            BitMatrix::from_f32(batch, d_in, &x, Layout::RowMajor)
        }
    }
}

/// 2x2 OR pool on an HWNC bit tensor.
fn or_pool(t: &BitTensor4) -> BitTensor4 {
    let [h, w, n, _c] = t.dims;
    let mut out = BitTensor4::zeros([h / 2, w / 2, n, t.dims[3]], TensorLayout::Hwnc);
    for hi in 0..h / 2 {
        for wi in 0..w / 2 {
            for ni in 0..n {
                let words: Vec<u32> = t
                    .inner(2 * hi, 2 * wi, ni)
                    .iter()
                    .zip(t.inner(2 * hi + 1, 2 * wi, ni))
                    .zip(t.inner(2 * hi, 2 * wi + 1, ni))
                    .zip(t.inner(2 * hi + 1, 2 * wi + 1, ni))
                    .map(|(((a, b), c), d)| a | b | c | d)
                    .collect();
                out.inner_mut(hi, wi, ni).copy_from_slice(&words);
            }
        }
    }
    out
}

/// Run the model on a batch of fp32 NHWC (or flat) inputs -> logits,
/// through the global registry's default scheme backend.
pub fn forward(
    model: &ModelDef,
    weights: &ModelWeights,
    input: &[f32],
    batch: usize,
) -> Vec<f32> {
    forward_with(model, weights, input, batch, BackendRegistry::global(), Scheme::Btc)
}

/// The single registry-driven forward entry point: binarized conv/FC
/// layers execute through `registry`'s backend for `scheme`.  All
/// backends are exact integer Eq-2 arithmetic over the same bits (and
/// the first BWN layer keeps one fixed f32 accumulation order), so the
/// output is bit-identical for every registered scheme.
///
/// This is the *reference* path: weights are re-prepared through the
/// backend on every call (clones/repacks included) and layers run
/// serial.  Hot paths build an `EngineExecutor`, which prepares once
/// and executes allocation-free.
///
/// Panics if `scheme` has no registered backend or a layer shape is
/// rejected by the backend's `prepare_*` (the serving path surfaces
/// these as `Result`s at `EngineExecutor` build time instead).
pub fn forward_with(
    model: &ModelDef,
    weights: &ModelWeights,
    input: &[f32],
    batch: usize,
    registry: &BackendRegistry,
    scheme: Scheme,
) -> Vec<f32> {
    let backend = registry.get(scheme).unwrap_or_else(|| {
        panic!("scheme {} has no registered backend", scheme.name())
    });
    // the reference path runs serial: it is the slow, obvious oracle
    // the engine executor (and the bench ratios normalized against
    // "naive") are measured against — results are thread-count
    // independent anyway, since every backend is exact integer math
    let threads = 1;
    let mut dims = model.input;
    // initial activation
    let mut act: Option<Act> = None;
    let mut fp_input: Option<Vec<f32>> = Some(input.to_vec());

    for (l, wts) in model.layers.iter().zip(&weights.layers) {
        match (l, wts) {
            (
                LayerSpec::FirstConv { c, o, k, stride, pad },
                LayerWeights::FirstConv { w_pm1, thresh },
            ) => {
                // fp cross-correlation (NHWC input, KKCO weights), then
                // threshold into packed HWNC bits
                let x = fp_input.take().expect("first layer needs fp input");
                let h = dims.hw;
                let ohw = (h + 2 * pad - k) / stride + 1;
                let mut bits =
                    BitTensor4::zeros([ohw, ohw, batch, *o], TensorLayout::Hwnc);
                for ni in 0..batch {
                    for op in 0..ohw {
                        for oq in 0..ohw {
                            for oi in 0..*o {
                                let mut acc = 0.0f32;
                                for r in 0..*k {
                                    for s in 0..*k {
                                        let i = (op * stride + r) as isize - *pad as isize;
                                        let j = (oq * stride + s) as isize - *pad as isize;
                                        if i < 0 || i >= h as isize || j < 0 || j >= h as isize {
                                            continue;
                                        }
                                        for ci in 0..*c {
                                            let xv = x[((ni * h + i as usize) * h
                                                + j as usize)
                                                * c
                                                + ci];
                                            let wv = w_pm1
                                                [((r * k + s) * c + ci) * o + oi];
                                            acc += xv * wv;
                                        }
                                    }
                                }
                                if acc >= thresh[oi] {
                                    bits.set(op, oq, ni, oi, true);
                                }
                            }
                        }
                    }
                }
                act = Some(Act::Bits(bits));
            }
            (
                LayerSpec::BinConv { o, k, stride, pad, pool, .. },
                LayerWeights::BinConv { filter, thresh },
            ) => {
                let t = match act.take().unwrap() {
                    Act::Bits(t) => t,
                    Act::Flat(_) => panic!("conv after flatten"),
                };
                let p = BconvProblem {
                    hw: dims.hw,
                    n: batch,
                    c: dims.feat,
                    o: *o,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                };
                let prepared = backend
                    .prepare_conv(filter, p)
                    .unwrap_or_else(|e| panic!("{}: prepare conv: {e}", scheme.name()));
                let mut scratch = vec![0u64; prepared.scratch_words(p)];
                let mut ints = vec![0i32; p.out_elems()];
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                prepared.bconv(&t.data, p, &mut ints, &mut ctx);
                let ohw = p.out_hw();
                let mut bits =
                    BitTensor4::zeros([ohw, ohw, batch, *o], TensorLayout::Hwnc);
                for op in 0..ohw {
                    for oq in 0..ohw {
                        for ni in 0..batch {
                            for oi in 0..*o {
                                let v = ints[((op * ohw + oq) * batch + ni) * o + oi];
                                if (v as f32) >= thresh[oi] {
                                    bits.set(op, oq, ni, oi, true);
                                }
                            }
                        }
                    }
                }
                let bits = if *pool { or_pool(&bits) } else { bits };
                act = Some(Act::Bits(bits));
            }
            (LayerSpec::BinFc { d_in, d_out }, LayerWeights::BinFc { w, thresh }) => {
                let flat = flat_rows(act.take(), &mut fp_input, batch, *d_in);
                assert_eq!(flat.cols, *d_in);
                let prepared = backend
                    .prepare_fc(w)
                    .unwrap_or_else(|e| panic!("{}: prepare fc: {e}", scheme.name()));
                let mut scratch = vec![0u64; prepared.scratch_words(batch)];
                let mut v = vec![0i32; batch * d_out];
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                prepared.bmm(&flat.data, batch, &mut v, &mut ctx);
                let mut out = BitMatrix::zeros(batch, *d_out, Layout::RowMajor);
                for bi in 0..batch {
                    for j in 0..*d_out {
                        if (v[bi * d_out + j] as f32) >= thresh[j] {
                            out.set(bi, j, true);
                        }
                    }
                }
                act = Some(Act::Flat(out));
            }
            (
                LayerSpec::BinGcn { nodes, d_in, d_out, .. },
                LayerWeights::BinGcn { adj, w, thresh },
            ) => {
                let flat = flat_rows(act.take(), &mut fp_input, batch, nodes * d_in);
                assert_eq!(flat.cols, nodes * d_in);
                let prepared = backend
                    .prepare_gcn(adj, w)
                    .unwrap_or_else(|e| panic!("{}: prepare gcn: {e}", scheme.name()));
                let mut scratch = vec![0u64; prepared.scratch_words(batch)];
                let mut v = vec![0i32; batch * nodes * d_out];
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                prepared.gcn(&flat.data, batch, &mut v, &mut ctx);
                let mut out = BitMatrix::zeros(batch, nodes * d_out, Layout::RowMajor);
                for bi in 0..batch {
                    for j in 0..nodes * d_out {
                        if (v[bi * nodes * d_out + j] as f32) >= thresh[j % d_out] {
                            out.set(bi, j, true);
                        }
                    }
                }
                act = Some(Act::Flat(out));
            }
            (
                LayerSpec::FinalFc { d_in, d_out },
                LayerWeights::FinalFc { w, gamma, beta },
            ) => {
                let flat = flat_rows(act.take(), &mut fp_input, batch, *d_in);
                assert_eq!(flat.cols, *d_in);
                let prepared = backend
                    .prepare_fc(w)
                    .unwrap_or_else(|e| panic!("{}: prepare fc: {e}", scheme.name()));
                let mut scratch = vec![0u64; prepared.scratch_words(batch)];
                let mut v = vec![0i32; batch * d_out];
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                prepared.bmm(&flat.data, batch, &mut v, &mut ctx);
                let mut logits = vec![0.0f32; batch * d_out];
                for bi in 0..batch {
                    for j in 0..*d_out {
                        logits[bi * d_out + j] =
                            v[bi * d_out + j] as f32 * gamma[j] + beta[j];
                    }
                }
                return logits;
            }
            (LayerSpec::Pool, LayerWeights::Pool) => {
                let t = match act.take().unwrap() {
                    Act::Bits(t) => t,
                    Act::Flat(_) => panic!("pool after flatten"),
                };
                act = Some(Act::Bits(or_pool(&t)));
            }
            _ => panic!("layer/weight mismatch"),
        }
        dims = dims.after(l);
    }
    panic!("model did not end with FinalFc");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layer::Dims;
    use crate::nn::model::ModelDef;
    use crate::nn::parser;

    /// A tiny conv net for functional tests.
    fn tiny_model() -> ModelDef {
        let _ = parser::parse_structure("(1x32C3)-MP2").unwrap();
        ModelDef {
            name: "tiny",
            dataset: "synthetic",
            input: Dims { hw: 8, feat: 3 },
            classes: 4,
            layers: vec![
                LayerSpec::FirstConv { c: 3, o: 32, k: 3, stride: 1, pad: 1 },
                LayerSpec::BinConv {
                    c: 32, o: 32, k: 3, stride: 1, pad: 1, pool: true, residual: false,
                },
                LayerSpec::BinFc { d_in: 4 * 4 * 32, d_out: 64 },
                LayerSpec::FinalFc { d_in: 64, d_out: 4 },
            ],
            residual_blocks: 0,
        }
    }

    #[test]
    fn tiny_net_runs_end_to_end() {
        let m = tiny_model();
        let mut rng = Rng::new(5);
        let w = random_weights(&m, &mut rng);
        let batch = 8;
        let x: Vec<f32> = (0..batch * 8 * 8 * 3).map(|_| rng.next_f32() - 0.5).collect();
        let logits = forward(&m, &w, &x, batch);
        assert_eq!(logits.len(), batch * 4);
        assert!(logits.iter().all(|v| v.is_finite()));
        // different images should (almost surely) give different logits
        assert_ne!(logits[..4], logits[4..8]);
    }

    #[test]
    fn every_registered_scheme_is_bit_identical() {
        let m = tiny_model();
        let mut rng = Rng::new(8);
        let w = random_weights(&m, &mut rng);
        let x: Vec<f32> = (0..8 * 8 * 8 * 3).map(|_| rng.next_f32() - 0.5).collect();
        let reg = BackendRegistry::global();
        let want = forward(&m, &w, &x, 8);
        for s in reg.schemes() {
            assert_eq!(
                forward_with(&m, &w, &x, 8, reg, s),
                want,
                "scheme {}",
                s.name()
            );
        }
    }

    #[test]
    fn mlp_forward_binarizes_flat_fp_input() {
        let m = crate::nn::model::mnist_mlp();
        let mut rng = Rng::new(12);
        let w = random_weights(&m, &mut rng);
        let batch = 4;
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32() - 0.5).collect();
        let a = forward(&m, &w, &x, batch);
        assert_eq!(a.len(), batch * 10);
        assert!(a.iter().all(|v| v.is_finite()));
        // registry-uniform here too
        let reg = BackendRegistry::global();
        assert_eq!(forward_with(&m, &w, &x, batch, reg, Scheme::Fastpath), a);
    }

    #[test]
    fn gcn_forward_is_scheme_identical() {
        // tiny BitGNN: one hop + readout; every registered backend
        // (including both sparse schemes) must produce identical logits
        let spec = crate::sparse::AdjSpec {
            kind: crate::sparse::AdjKind::PowerLaw,
            degree: 3,
            seed: 9,
        };
        let nodes = 32;
        let nnz_blocks = crate::sparse::generate(spec, nodes).nnz_blocks();
        let m = ModelDef {
            name: "tiny-gcn",
            dataset: "synthetic",
            input: Dims { hw: 0, feat: nodes * 64 },
            classes: 4,
            layers: vec![
                LayerSpec::BinGcn { nodes, d_in: 64, d_out: 64, adj: spec, nnz_blocks },
                LayerSpec::BinFc { d_in: nodes * 64, d_out: 64 },
                LayerSpec::FinalFc { d_in: 64, d_out: 4 },
            ],
            residual_blocks: 0,
        };
        let mut rng = Rng::new(31);
        let w = random_weights(&m, &mut rng);
        let batch = 3;
        let x: Vec<f32> =
            (0..batch * nodes * 64).map(|_| rng.next_f32() - 0.5).collect();
        let reg = BackendRegistry::global();
        let want = forward(&m, &w, &x, batch);
        assert_eq!(want.len(), batch * 4);
        assert!(want.iter().all(|v| v.is_finite()));
        for s in reg.schemes() {
            assert_eq!(forward_with(&m, &w, &x, batch, reg, s), want, "scheme {}", s.name());
        }
    }

    #[test]
    fn forward_is_deterministic() {
        let m = tiny_model();
        let mut rng = Rng::new(6);
        let w = random_weights(&m, &mut rng);
        let x: Vec<f32> = (0..8 * 8 * 8 * 3).map(|_| rng.next_f32()).collect();
        assert_eq!(forward(&m, &w, &x, 8), forward(&m, &w, &x, 8));
    }

    #[test]
    fn or_pool_matches_max_semantics() {
        let mut rng = Rng::new(7);
        let t = BitTensor4::random([4, 4, 2, 32], TensorLayout::Hwnc, &mut rng);
        let p = or_pool(&t);
        for hi in 0..2 {
            for wi in 0..2 {
                for ni in 0..2 {
                    for ci in 0..32 {
                        let any = t.get(2 * hi, 2 * wi, ni, ci)
                            || t.get(2 * hi + 1, 2 * wi, ni, ci)
                            || t.get(2 * hi, 2 * wi + 1, ni, ci)
                            || t.get(2 * hi + 1, 2 * wi + 1, ni, ci);
                        assert_eq!(p.get(hi, wi, ni, ci), any);
                    }
                }
            }
        }
    }
}
