//! The evaluation models (Table 5) + ResNet depth variants (Table 11),
//! plus two BitGNN graph-convolution models exercising the sparse
//! adjacency path.

use anyhow::Result;

use crate::sparse::{self, AdjKind, AdjSpec};

use super::layer::{Dims, LayerSpec};
use super::parser::{parse_structure, Unit};

/// A complete model definition.
#[derive(Clone, Debug)]
pub struct ModelDef {
    pub name: &'static str,
    pub dataset: &'static str,
    /// input (h=w, channels)
    pub input: Dims,
    pub classes: usize,
    pub layers: Vec<LayerSpec>,
    /// number of 2-conv residual blocks (ResNet models)
    pub residual_blocks: usize,
}

impl ModelDef {
    /// Build from a Table-5 structure string.  `resnet` marks every
    /// second binarized conv as a residual-block end.
    pub fn from_structure(
        name: &'static str,
        dataset: &'static str,
        input: Dims,
        classes: usize,
        structure: &str,
        resnet: bool,
    ) -> Result<ModelDef> {
        let units = parse_structure(structure)?;
        let mut layers = Vec::new();
        let mut dims = input;
        let mut first_conv_done = false;
        let mut bin_conv_count = 0usize;
        let mut residual_blocks = 0usize;
        for u in units.iter() {
            match *u {
                Unit::Conv { o, k, stride } => {
                    let pad = if k == 3 { 1 } else { 0 };
                    // ResNet stage transitions downsample (type-A
                    // shortcut with stride-2 first conv of the stage)
                    let stride = if resnet && first_conv_done && o > dims.feat && stride == 1 {
                        2
                    } else {
                        stride
                    };
                    if !first_conv_done {
                        layers.push(LayerSpec::FirstConv {
                            c: dims.feat, o, k, stride, pad,
                        });
                        first_conv_done = true;
                    } else {
                        bin_conv_count += 1;
                        let residual = resnet && bin_conv_count % 2 == 0;
                        if residual {
                            residual_blocks += 1;
                        }
                        layers.push(LayerSpec::BinConv {
                            c: dims.feat, o, k, stride, pad, pool: false, residual,
                        });
                    }
                    dims = dims.after(layers.last().unwrap());
                }
                Unit::Pool { .. } => {
                    // fuse into the previous binarized conv when possible
                    if let Some(LayerSpec::BinConv { pool, .. }) = layers.last_mut() {
                        *pool = true;
                    } else {
                        layers.push(LayerSpec::Pool);
                    }
                    dims = Dims { hw: dims.hw / 2, feat: dims.feat };
                }
                Unit::Fc { d } => {
                    // ResNet models globally pool spatial to 1x1 before
                    // the FC stage (OR-pool halvings; §6.1 pooling)
                    if resnet && dims.hw > 1 {
                        while dims.hw > 1 {
                            layers.push(LayerSpec::Pool);
                            dims = Dims { hw: dims.hw / 2, feat: dims.feat };
                        }
                    }
                    let d_in = dims.flat();
                    layers.push(LayerSpec::BinFc { d_in, d_out: d });
                    dims = Dims { hw: 0, feat: d };
                }
                Unit::Group(_) => unreachable!("parser flattens groups"),
            }
        }
        // classifier head: final FC to `classes`, real-valued + bn (§6.1)
        layers.push(LayerSpec::FinalFc { d_in: dims.flat(), d_out: classes });
        Ok(ModelDef { name, dataset, input, classes, layers, residual_blocks })
    }

    /// Total weight bits (for the model-size column).
    pub fn weight_bits(&self) -> usize {
        self.layers.iter().map(|l| l.weight_bits()).sum()
    }

    pub fn conv_layers(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::FirstConv { .. } | LayerSpec::BinConv { .. }))
            .count()
    }

    pub fn fc_layers(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::BinFc { .. } | LayerSpec::FinalFc { .. }))
            .count()
    }
}

/// MNIST MLP (Table 5 row 1): 1024FC x3.
pub fn mnist_mlp() -> ModelDef {
    let mut m = ModelDef::from_structure(
        "MNIST-MLP",
        "MNIST",
        Dims { hw: 0, feat: 784 },
        10,
        "1024FC-1024FC-1024FC",
        false,
    )
    .unwrap();
    m.residual_blocks = 0;
    m
}

/// Cifar10 VGG-like (Table 5 row 2).
pub fn cifar_vgg() -> ModelDef {
    ModelDef::from_structure(
        "Cifar10-VGG",
        "Cifar10",
        Dims { hw: 32, feat: 3 },
        10,
        "(2x128C3)-MP2-(2x256C3)-MP2-(2x512C3)-MP2-(3x1024FC)",
        false,
    )
    .unwrap()
}

/// Cifar10 ResNet-14 (Table 5 row 3).
pub fn cifar_resnet14() -> ModelDef {
    ModelDef::from_structure(
        "Cifar10-ResNet14",
        "Cifar10",
        Dims { hw: 32, feat: 3 },
        10,
        "128C3/2-4x128C3-4x256C3-4x512C3-(2x512FC)",
        true,
    )
    .unwrap()
}

/// ImageNet AlexNet (Table 5 row 4).
pub fn imagenet_alexnet() -> ModelDef {
    ModelDef::from_structure(
        "ImageNet-AlexNet",
        "ImageNet",
        Dims { hw: 224, feat: 3 },
        1000,
        "(128C11/4)-P2-(256C5)-P2-(3x256C3)-P2-(3x4096FC)",
        false,
    )
    .unwrap()
}

/// ImageNet VGG-16 (Table 5 row 5).
pub fn imagenet_vgg16() -> ModelDef {
    ModelDef::from_structure(
        "ImageNet-VGG",
        "ImageNet",
        Dims { hw: 224, feat: 3 },
        1000,
        "(2x64C3)-P2-(2x128C3)-P2-(3x256C3)-P2-2x(3x512C3-P2)-(3x4096FC)",
        false,
    )
    .unwrap()
}

/// ImageNet ResNet-18 (Table 5 row 6).
pub fn imagenet_resnet18() -> ModelDef {
    ModelDef::from_structure(
        "ImageNet-ResNet18",
        "ImageNet",
        Dims { hw: 224, feat: 3 },
        1000,
        "64C7/4-4x64C3-4x128C3-4x256C3-4x512C3-(2x512FC)",
        true,
    )
    .unwrap()
}

/// Deeper ResNets for Table 11 (basic-block scaling of the paper's
/// ResNet template: stage repeats follow the standard 50/101/152
/// schedules, expressed with the paper's binarized basic blocks).
pub fn imagenet_resnet(depth: usize) -> ModelDef {
    let (name, stages): (&'static str, [usize; 4]) = match depth {
        18 => return imagenet_resnet18(),
        50 => ("ImageNet-ResNet50", [6, 8, 12, 6]),
        101 => ("ImageNet-ResNet101", [6, 8, 46, 6]),
        152 => ("ImageNet-ResNet152", [6, 16, 72, 6]),
        other => panic!("unsupported resnet depth {other}"),
    };
    let structure = format!(
        "64C7/4-{}x64C3-{}x128C3-{}x256C3-{}x512C3-(2x512FC)",
        stages[0], stages[1], stages[2], stages[3]
    );
    let structure: &'static str = Box::leak(structure.into_boxed_str());
    ModelDef::from_structure(
        name,
        "ImageNet",
        Dims { hw: 224, feat: 3 },
        1000,
        structure,
        true,
    )
    .unwrap()
}

/// Build a two-layer binary GCN (BitGNN): two BinGcn hops over a fixed
/// adjacency, a readout FC over the concatenated node features, and
/// the classifier head.  The adjacency is generated once here to
/// record its realized stored-block count (`nnz_blocks`) in the layer
/// spec — the sparsity the cost faces and plan fingerprints key on.
fn gcn_model(
    name: &'static str,
    dataset: &'static str,
    nodes: usize,
    d: usize,
    adj: AdjSpec,
) -> ModelDef {
    let nnz_blocks = sparse::generate(adj, nodes).nnz_blocks();
    let gcn = LayerSpec::BinGcn { nodes, d_in: d, d_out: d, adj, nnz_blocks };
    ModelDef {
        name,
        dataset,
        input: Dims { hw: 0, feat: nodes * d },
        classes: 10,
        layers: vec![
            gcn.clone(),
            gcn,
            LayerSpec::BinFc { d_in: nodes * d, d_out: 128 },
            LayerSpec::FinalFc { d_in: 128, d_out: 10 },
        ],
        residual_blocks: 0,
    }
}

/// Power-law (hub-clustered) BitGNN: block-sparse adjacency where the
/// sparse schemes win the layout DP.
pub fn gcn_powerlaw() -> ModelDef {
    gcn_model(
        "GCN-PowerLaw",
        "synthetic-graph",
        512,
        64,
        AdjSpec { kind: AdjKind::PowerLaw, degree: 6, seed: 1 },
    )
}

/// Grid-neighborhood BitGNN: block-dense adjacency where the dense
/// host schemes win — the other side of the density crossover.
pub fn gcn_grid() -> ModelDef {
    gcn_model(
        "GCN-Grid",
        "synthetic-graph",
        128,
        64,
        AdjSpec { kind: AdjKind::Grid, degree: 3, seed: 0 },
    )
}

/// The six Tables-6/7 models, in column order, plus the two BitGNN
/// graph models.
pub fn all_models() -> Vec<ModelDef> {
    vec![
        mnist_mlp(),
        cifar_vgg(),
        cifar_resnet14(),
        imagenet_alexnet(),
        imagenet_vgg16(),
        imagenet_resnet18(),
        gcn_powerlaw(),
        gcn_grid(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_models_build() {
        let models = all_models();
        assert_eq!(models.len(), 8);
        for m in &models {
            assert!(m.layers.len() >= 4, "{} too shallow", m.name);
            assert!(
                matches!(m.layers.last(), Some(LayerSpec::FinalFc { .. })),
                "{} must end with the classifier head",
                m.name
            );
        }
    }

    #[test]
    fn mlp_shape() {
        let m = mnist_mlp();
        assert_eq!(m.conv_layers(), 0);
        assert_eq!(m.fc_layers(), 4); // 3 hidden + head
        assert_eq!(m.classes, 10);
    }

    #[test]
    fn resnet14_counts() {
        let m = cifar_resnet14();
        // 13 convs + 2 FC + head
        assert_eq!(m.conv_layers(), 13);
        assert_eq!(m.fc_layers(), 3);
        assert_eq!(m.residual_blocks, 6); // 12 binarized convs / 2
    }

    #[test]
    fn resnet18_counts() {
        let m = imagenet_resnet18();
        assert_eq!(m.conv_layers(), 17);
        assert_eq!(m.residual_blocks, 8);
    }

    #[test]
    fn depth_scaling_monotone() {
        let l18 = imagenet_resnet(18).layers.len();
        let l50 = imagenet_resnet(50).layers.len();
        let l101 = imagenet_resnet(101).layers.len();
        let l152 = imagenet_resnet(152).layers.len();
        assert!(l18 < l50 && l50 < l101 && l101 < l152);
    }

    #[test]
    fn vgg16_fc_input_is_flattened() {
        let m = imagenet_vgg16();
        let fc = m
            .layers
            .iter()
            .find_map(|l| match l {
                LayerSpec::BinFc { d_in, d_out: 4096 } => Some(*d_in),
                _ => None,
            })
            .unwrap();
        // 224 / 2^5 = 7 spatial, 512 channels
        assert_eq!(fc, 7 * 7 * 512);
    }

    #[test]
    fn gcn_models_are_well_formed() {
        for m in [gcn_powerlaw(), gcn_grid()] {
            let mut d = m.input;
            let mut gcn_layers = 0usize;
            for l in &m.layers {
                if let LayerSpec::BinGcn { nodes, d_in, d_out, nnz_blocks, .. } = l {
                    // realized sparsity must be recorded, node rows
                    // must stay u64-aligned, and the incoming flat
                    // activation must match nodes * d_in
                    assert!(*nnz_blocks > 0, "{}", m.name);
                    assert_eq!(d_in % 64, 0);
                    assert_eq!(d_out % 64, 0);
                    assert_eq!(d.feat, nodes * d_in, "{}", m.name);
                    gcn_layers += 1;
                }
                d = d.after(l);
            }
            assert_eq!(gcn_layers, 2, "{}", m.name);
            assert_eq!(d.feat, m.classes, "{}", m.name);
        }
        // the two generators sit on opposite sides of the block-density
        // crossover: power-law stays block-sparse, grid is near-dense
        let pl = gcn_powerlaw();
        let gr = gcn_grid();
        let nnz = |m: &ModelDef| match m.layers[0] {
            LayerSpec::BinGcn { nodes, nnz_blocks, .. } => {
                nnz_blocks as f64 / (nodes * nodes.div_ceil(64)) as f64
            }
            _ => unreachable!(),
        };
        assert!(nnz(&pl) < 0.3, "power-law block density {}", nnz(&pl));
        assert!(nnz(&gr) > 0.6, "grid block density {}", nnz(&gr));
    }

    #[test]
    fn alexnet_dims_consistent() {
        let m = imagenet_alexnet();
        // walk dims through the network; must stay positive
        let mut d = m.input;
        for l in &m.layers {
            d = d.after(l);
            assert!(d.feat > 0);
        }
        assert_eq!(d.feat, 1000);
    }
}
