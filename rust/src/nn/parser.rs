//! Parser for Table-5 network-structure strings.
//!
//! Grammar (dash-separated segments, each optionally repeated):
//!   segment  := [N 'x'] unit
//!   unit     := '(' structure ')' | conv | fc | pool
//!   conv     := O 'C' K ['/' S]          e.g. "128C3", "64C7/4"
//!   fc       := D 'FC'                   e.g. "1024FC"
//!   pool     := 'P' K | 'MP' K           e.g. "P2", "MP2"
//!
//! Examples from the paper:
//!   "1024FC-1024FC-1024FC"
//!   "(2x128C3)-MP2-(2x256C3)-MP2-(2x512C3)-MP2-(3x1024FC)"
//!   "64C7/4-4x64C3-4x128C3-4x256C3-4x512C3-(2x512FC)"
//!   "(2x64C3)-P2-(2x128C3)-P2-(3x256C3)-P2-2x(3x512C3-P2)-(3x4096FC)"

use anyhow::{bail, Context, Result};

/// A parsed structural element (pre-layout; conv stride defaults to 1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Unit {
    Conv { o: usize, k: usize, stride: usize },
    Fc { d: usize },
    Pool { k: usize },
    Group(Vec<(usize, Unit)>),
}

/// Split a structure string into top-level dash-separated segments
/// (dashes inside parentheses don't split).
fn split_segments(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            '-' if depth == 0 => {
                if i > start {
                    out.push(&s[start..i]);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

/// Parse one segment into (repeat, unit).
fn parse_segment(seg: &str) -> Result<(usize, Unit)> {
    let seg = seg.trim();
    // optional leading "Nx" repeat (only when followed by more content)
    let (repeat, rest) = match seg.find('x') {
        Some(i) if seg[..i].chars().all(|c| c.is_ascii_digit()) && i > 0 => {
            (seg[..i].parse::<usize>()?, &seg[i + 1..])
        }
        _ => (1, seg),
    };
    let unit = parse_unit(rest)?;
    Ok((repeat, unit))
}

fn parse_unit(s: &str) -> Result<Unit> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(').and_then(|r| r.strip_suffix(')')) {
        let items = split_segments(inner)
            .into_iter()
            .map(parse_segment)
            .collect::<Result<Vec<_>>>()?;
        return Ok(Unit::Group(items));
    }
    if let Some(rest) = s.strip_prefix("MP").or_else(|| s.strip_prefix('P')) {
        if let Ok(k) = rest.parse::<usize>() {
            return Ok(Unit::Pool { k });
        }
    }
    if let Some(d) = s.strip_suffix("FC") {
        return Ok(Unit::Fc { d: d.parse().context("fc width")? });
    }
    if let Some(ci) = s.find('C') {
        let o: usize = s[..ci].parse().context("conv channels")?;
        let rest = &s[ci + 1..];
        let (k, stride) = match rest.split_once('/') {
            Some((k, st)) => (k.parse()?, st.parse()?),
            None => (rest.parse()?, 1),
        };
        return Ok(Unit::Conv { o, k, stride });
    }
    bail!("cannot parse unit {s:?}")
}

/// Parse a full Table-5 structure string into a flat unit list.
pub fn parse_structure(s: &str) -> Result<Vec<Unit>> {
    let mut flat = Vec::new();
    fn push(flat: &mut Vec<Unit>, repeat: usize, u: Unit) {
        for _ in 0..repeat {
            match &u {
                Unit::Group(items) => {
                    for (r, inner) in items {
                        push(flat, *r, inner.clone());
                    }
                }
                other => flat.push(other.clone()),
            }
        }
    }
    for seg in split_segments(s) {
        let (r, u) = parse_segment(seg)?;
        push(&mut flat, r, u);
    }
    Ok(flat)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_structure() {
        let units = parse_structure("1024FC-1024FC-1024FC").unwrap();
        assert_eq!(units.len(), 3);
        assert!(units.iter().all(|u| matches!(u, Unit::Fc { d: 1024 })));
    }

    #[test]
    fn cifar_vgg_structure() {
        let units = parse_structure(
            "(2x128C3)-MP2-(2x256C3)-MP2-(2x512C3)-MP2-(3x1024FC)",
        )
        .unwrap();
        // 2+1+2+1+2+1+3 = 12
        assert_eq!(units.len(), 12);
        assert_eq!(units[0], Unit::Conv { o: 128, k: 3, stride: 1 });
        assert_eq!(units[2], Unit::Pool { k: 2 });
        assert_eq!(units[11], Unit::Fc { d: 1024 });
    }

    #[test]
    fn resnet18_structure() {
        let units =
            parse_structure("64C7/4-4x64C3-4x128C3-4x256C3-4x512C3-(2x512FC)")
                .unwrap();
        assert_eq!(units.len(), 1 + 16 + 2);
        assert_eq!(units[0], Unit::Conv { o: 64, k: 7, stride: 4 });
        assert_eq!(units[1], Unit::Conv { o: 64, k: 3, stride: 1 });
        assert_eq!(units[17], Unit::Fc { d: 512 });
    }

    #[test]
    fn vgg16_nested_group() {
        let units = parse_structure(
            "(2x64C3)-P2-(2x128C3)-P2-(3x256C3)-P2-2x(3x512C3-P2)-(3x4096FC)",
        )
        .unwrap();
        // 2+1+2+1+3+1+2*(3+1)+3 = 21
        assert_eq!(units.len(), 21);
        assert_eq!(units[9], Unit::Pool { k: 2 });
        assert_eq!(units[10], Unit::Conv { o: 512, k: 3, stride: 1 });
        assert_eq!(units[13], Unit::Pool { k: 2 });
        assert_eq!(units[17], Unit::Pool { k: 2 });
    }

    #[test]
    fn alexnet_structure() {
        let units = parse_structure(
            "(128C11/4)-P2-(256C5)-P2-(3x256C3)-P2-(3x4096FC)",
        )
        .unwrap();
        // 1+1+1+1+3+1+3 = 11
        assert_eq!(units.len(), 11);
        assert_eq!(units[0], Unit::Conv { o: 128, k: 11, stride: 4 });
        assert_eq!(units[1], Unit::Pool { k: 2 });
        assert_eq!(units[2], Unit::Conv { o: 256, k: 5, stride: 1 });
    }

    #[test]
    fn garbage_rejected() {
        assert!(parse_structure("12Q3").is_err());
        assert!(parse_structure("C3").is_err());
    }
}
