//! The measured-calibration subsystem: fitted per-host cost profiles
//! and a live planner feedback loop.
//!
//! The paper's core finding is that which bit-kernel wins is *not*
//! analytically obvious — memory-access stride and data format flip
//! the ranking between schemes — and PhoneBit ships a per-device
//! tuning pass for the same reason: analytic models mispredict on real
//! hosts.  This module replaces the planner's hard-coded host cost
//! constants with measured, fitted, per-host profiles, in three parts:
//!
//! 1. **Microbench runner** ([`microbench`]) — runs each registered
//!    *host* backend's `bmm`/`bconv` kernels over a fixed grid of
//!    layer shapes (reusing `util::bench` timing and `util::stats`
//!    percentiles) and [`fit`]s the backend's cost-model coefficients
//!    by weighted least squares over the [`features`] regressors.
//! 2. **[`CalibrationProfile`]** ([`profile`]) — a schema-versioned
//!    JSON artifact keyed by a [`HostFingerprint`], persisted next to
//!    the engine's `PlanCache` (`PlanCache::profile_path`).  Planner
//!    cost queries go through a [`CostSource`] (`Analytic` |
//!    `Calibrated` | `Live`) instead of the registry's raw
//!    `layer_secs`; every plan embeds its source's `profile_id`, so
//!    cached plans are invalidated whenever the active profile
//!    changes.
//! 3. **Online feedback** ([`live`]) — the arena executor records
//!    per-layer measured latencies into the lock-free [`LiveCosts`]
//!    EWMA sink; `EngineModel` exposes the drift through coordinator
//!    `Metrics` and re-plans when a scheme's measured cost drifts past
//!    2x its prediction, converging a long-running server onto true
//!    host costs.
//!
//! A future backend (SIMD, NUMA) is self-calibrating on arrival: it
//! registers, the tuner detects its analytic host cost face
//! ([`microbench::is_host_backend`]), and the next `tuner` run fits it
//! a profile entry — no tuner changes.
//!
//! Run it: `cargo run --release --bin tuner -- --quick` (the CI
//! `tuner-smoke` job does exactly this and uploads the profile
//! artifact).  See `docs/ENGINE.md` ("Calibration & CostSource").

pub mod cost_source;
pub mod features;
pub mod fingerprint;
pub mod fit;
pub mod live;
pub mod microbench;
pub mod profile;

pub use cost_source::{CostSource, ANALYTIC_PROFILE_ID};
pub use features::{layer_features, Features};
pub use fingerprint::HostFingerprint;
pub use fit::{fit_coeffs, FitRow};
pub use live::LiveCosts;
pub use microbench::{Measurement, MicrobenchConfig, RepackMeasurement};
pub use profile::{repack_key, CalibrationProfile, SchemeCoeffs, PROFILE_SCHEMA};

use crate::kernels::backend::BackendRegistry;
use crate::nn::cost::ResidualMode;
use crate::nn::ModelDef;
use crate::sim::{Engine, GpuModel};

/// Fit a [`CalibrationProfile`] from microbench measurements: one
/// coefficient set per scheme (and per layout-conversion pair, from
/// [`microbench::run_repacks`]) with at least 3 usable grid rows.
pub fn fit_profile(
    fingerprint: HostFingerprint,
    measurements: &[Measurement],
    repack_measurements: &[RepackMeasurement],
) -> CalibrationProfile {
    let mut schemes: Vec<(String, SchemeCoeffs)> = Vec::new();
    for m in measurements {
        let name = m.scheme.name().to_string();
        if schemes.iter().any(|(n, _)| *n == name) {
            continue;
        }
        let rows: Vec<FitRow> = measurements
            .iter()
            .filter(|x| x.scheme == m.scheme)
            .map(Measurement::fit_row)
            .collect();
        if let Some(coeffs) = fit_coeffs(&rows) {
            schemes.push((name, coeffs));
        }
    }
    let mut repacks: Vec<(String, SchemeCoeffs)> = Vec::new();
    for m in repack_measurements {
        let key = repack_key(m.src, m.dst);
        if repacks.iter().any(|(n, _)| *n == key) {
            continue;
        }
        let rows: Vec<FitRow> = repack_measurements
            .iter()
            .filter(|x| x.src == m.src && x.dst == m.dst)
            .map(RepackMeasurement::fit_row)
            .collect();
        if let Some(mut coeffs) = fit_coeffs(&rows) {
            // a repack has no kernel terms: the word regressor is
            // identically 0 in every row (fitted to 0), and the fp
            // seed the kernel fitter carries is meaningless here
            coeffs.secs_per_fp_op = 0.0;
            repacks.push((key, coeffs));
        }
    }
    CalibrationProfile { fingerprint, schemes, repacks }
}

/// Outcome of comparing planner choices under two cost sources.
#[derive(Clone, Debug, Default)]
pub struct ConsistencyReport {
    /// layers examined
    pub layers: usize,
    /// layers where the analytic best beat the second best by > margin
    pub unambiguous: usize,
    /// unambiguous layers where the calibrated winner differed
    pub mismatches: Vec<String>,
}

impl ConsistencyReport {
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }
}

/// Compare per-layer planner choices under `source` against the
/// analytic baseline, over `models` at one batch size.  Only
/// *unambiguous* layers count — those where the analytic best beats
/// the analytic second-best by more than `margin` (e.g. 3.0): on those
/// a sane calibration must agree, while close calls are exactly where
/// measured data is allowed to flip the ranking.
pub fn consistency_vs_analytic(
    registry: &BackendRegistry,
    gpu: &GpuModel,
    source: &CostSource,
    models: &[ModelDef],
    batch: usize,
    margin: f64,
) -> ConsistencyReport {
    let engine = Engine::new(gpu);
    let mut report = ConsistencyReport::default();
    for m in models {
        let residual = ResidualMode::Full;
        let has_res = m.residual_blocks > 0;
        let mut dims = m.input;
        for (li, l) in m.layers.iter().enumerate() {
            report.layers += 1;
            let mut ranked: Vec<(crate::nn::Scheme, f64)> = registry
                .backends()
                .map(|b| {
                    (
                        b.scheme(),
                        b.layer_secs(&engine, l, dims, batch, residual, has_res),
                    )
                })
                .collect();
            ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let (best, best_secs) = ranked[0];
            // a single-backend registry has nothing to compare
            let second_secs = ranked.get(1).map(|r| r.1).unwrap_or(f64::NAN);
            if ranked.len() >= 2
                && best_secs > 0.0
                && best_secs.is_finite()
                && second_secs / best_secs > margin
            {
                report.unambiguous += 1;
                let (cal_best, _) = registry
                    .backends()
                    .map(|b| {
                        (
                            b.scheme(),
                            source.layer_secs(
                                b, &engine, l, dims, batch, residual, has_res,
                            ),
                        )
                    })
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .expect("non-empty registry");
                if cal_best != best {
                    report.mismatches.push(format!(
                        "{} layer {li} ({}): analytic {} (margin {:.1}x) vs \
                         calibrated {}",
                        m.name,
                        l.tag(),
                        best.name(),
                        second_secs / best_secs,
                        cal_best.name(),
                    ));
                }
            }
            dims = dims.after(l);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::all_models;
    use crate::sim::RTX2080TI;
    use std::sync::Arc;

    #[test]
    fn analytic_constants_are_self_consistent() {
        // a profile that IS the analytic model must agree with the
        // analytic source on every unambiguous layer of every model
        let reg = BackendRegistry::global();
        let profile = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(reg),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
            repacks: Vec::new(),
        });
        let source = CostSource::Calibrated(profile);
        let models = all_models();
        let r = consistency_vs_analytic(reg, &RTX2080TI, &source, &models, 8, 3.0);
        assert!(r.layers > 0);
        assert!(r.ok(), "mismatches: {:?}", r.mismatches);
    }

    #[test]
    fn fit_profile_groups_by_scheme() {
        use crate::nn::layer::{Dims, LayerSpec};
        use crate::nn::Scheme;
        let fp = HostFingerprint::detect(BackendRegistry::global());
        let mk = |scheme, d_in: usize, secs| Measurement {
            scheme,
            kind: "bmm",
            layer: LayerSpec::BinFc { d_in, d_out: 128 },
            dims: Dims { hw: 0, feat: d_in },
            batch: 8,
            secs,
        };
        // fastpath: consistent synthetic curve -> fitted; btc-fmt: only
        // two rows -> skipped
        let coeff = 2e-10;
        let ms = vec![
            mk(Scheme::Fastpath, 256, (8 * 128 * 4) as f64 * coeff + 1e-6),
            mk(Scheme::Fastpath, 512, (8 * 128 * 8) as f64 * coeff + 1e-6),
            mk(Scheme::Fastpath, 1024, (8 * 128 * 16) as f64 * coeff + 1e-6),
            mk(Scheme::Fastpath, 2048, (8 * 128 * 32) as f64 * coeff + 1e-6),
            mk(Scheme::BtcFmt, 256, 1e-5),
            mk(Scheme::BtcFmt, 512, 2e-5),
        ];
        let p = fit_profile(fp, &ms, &[]);
        assert_eq!(p.schemes.len(), 1);
        assert_eq!(p.schemes[0].0, "FASTPATH");
        assert!(p.repacks.is_empty());
        let c = p.coeffs(Scheme::Fastpath).unwrap();
        assert!((c.secs_per_word_op - coeff).abs() / coeff < 1e-6, "{c:?}");
        assert_eq!(c.samples, 4);
    }

    #[test]
    fn fit_profile_recovers_synthetic_repack_bandwidth() {
        use crate::layout::LayoutKind;
        let fp = HostFingerprint::detect(BackendRegistry::global());
        // secs = bytes * 8e-11 + 1.2e-6 over three image sizes
        let (b_rate, disp) = (8e-11, 1.2e-6);
        let mk = |lines: usize, bits: usize| {
            let bytes = lines * bits / 8 * 2; // approx src+dst traffic
            microbench::RepackMeasurement {
                src: LayoutKind::Row32,
                dst: LayoutKind::Blocked64,
                lines,
                bits,
                bytes,
                secs: bytes as f64 * b_rate + disp,
            }
        };
        let ms = vec![mk(64, 1024), mk(128, 2048), mk(256, 4096), mk(256, 8192)];
        let p = fit_profile(fp, &[], &ms);
        assert!(p.schemes.is_empty());
        assert_eq!(p.repacks.len(), 1);
        let c = p
            .repack_coeffs(LayoutKind::Row32, LayoutKind::Blocked64)
            .unwrap();
        assert!((c.secs_per_byte - b_rate).abs() / b_rate < 1e-6, "{c:?}");
        assert!((c.dispatch_secs - disp).abs() / disp < 1e-6, "{c:?}");
        assert_eq!(c.secs_per_word_op, 0.0, "word regressor is identically 0");
        assert_eq!(c.secs_per_fp_op, 0.0, "repacks have no fp term");
        // the fitted pair prices an edge; the reverse pair falls back
        let priced = p
            .repack_secs(LayoutKind::Row32, LayoutKind::Blocked64, 10_000)
            .unwrap();
        assert!((priced - (10_000.0 * b_rate + disp)).abs() / priced < 1e-9);
        assert!(p
            .repack_secs(LayoutKind::Blocked64, LayoutKind::Row32, 10_000)
            .is_none());
    }
}
