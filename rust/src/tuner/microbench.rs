//! The microbench runner: measures each registered *host* backend's
//! `bmm`/`bconv` kernels over a fixed grid of layer shapes, producing
//! the `(Features, seconds)` rows the fitter turns into a
//! [`CalibrationProfile`](super::profile::CalibrationProfile).
//!
//! Only host backends are measured — backends whose cost face is an
//! analytic host model (empty `layer_traces`, like the fastpath or any
//! future SIMD/NUMA backend).  The six GPU schemes keep their
//! simulated-Turing cost face: their scalar host execution here is a
//! semantic reference, not the thing the planner prices, so fitting a
//! host profile to them would silently replace GPU economics with CPU
//! economics.  A new host backend is picked up automatically the
//! moment it registers — no tuner changes needed.
//!
//! Timing reuses `util::bench::Bencher` (warmup + auto-scaled
//! iteration counts) and records the p50 of the sample summary
//! (`util::stats`): the median is robust against scheduler noise that
//! would otherwise leak into fitted rates.

use crate::bitops::{BitMatrix, BitTensor4, Layout, TensorLayout};
use crate::kernels::backend::{
    BackendRegistry, ExecCtx, KernelBackend, PreparedConv as _, PreparedFc as _,
    PreparedGcn as _,
};
use crate::kernels::bconv::BconvProblem;
use crate::layout::{repack, LayoutDesc, LayoutKind};
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sparse::{self, AdjKind, AdjSpec};
use crate::util::bench::Bencher;
use crate::util::threadpool::default_threads;
use crate::util::Rng;

use super::features::{layer_features, Features};
use super::fit::FitRow;

/// One measured grid cell.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub scheme: Scheme,
    /// "bmm" | "bconv"
    pub kind: &'static str,
    /// the equivalent layer spec (feeds feature extraction)
    pub layer: LayerSpec,
    /// the layer's input dims
    pub dims: Dims,
    pub batch: usize,
    /// measured p50 seconds per kernel call
    pub secs: f64,
}

impl Measurement {
    /// The fit row of this measurement.
    pub fn fit_row(&self) -> FitRow {
        FitRow {
            features: layer_features(
                &self.layer,
                self.dims,
                self.batch,
                ResidualMode::None,
                false,
            ),
            secs: self.secs,
        }
    }
}

/// One measured layout-conversion grid cell.
#[derive(Clone, Debug)]
pub struct RepackMeasurement {
    pub src: LayoutKind,
    pub dst: LayoutKind,
    /// image shape the conversion ran over
    pub lines: usize,
    pub bits: usize,
    /// streamed bytes (source image + destination image)
    pub bytes: usize,
    /// measured p50 seconds per conversion
    pub secs: f64,
}

impl RepackMeasurement {
    /// The fit row of this measurement (pure byte-streaming model:
    /// `secs = bytes * b + dispatch`, word/fp regressors identically 0
    /// so the fitter pins their coefficients to 0).
    pub fn fit_row(&self) -> FitRow {
        FitRow {
            features: Features {
                fp_ops: 0.0,
                word_ops: 0.0,
                stream_bytes: self.bytes as f64,
                sparse_block_ops: 0.0,
            },
            secs: self.secs,
        }
    }
}

/// Microbench configuration.
#[derive(Clone, Debug)]
pub struct MicrobenchConfig {
    /// short CI-friendly measurements + the reduced grid
    pub quick: bool,
    /// input-generation seed (deterministic workloads)
    pub seed: u64,
    /// scoped-worker count the kernels run with (what the executor will
    /// use in production)
    pub threads: usize,
}

impl Default for MicrobenchConfig {
    fn default() -> Self {
        MicrobenchConfig { quick: false, seed: 42, threads: default_threads() }
    }
}

impl MicrobenchConfig {
    pub fn quick() -> Self {
        MicrobenchConfig { quick: true, ..MicrobenchConfig::default() }
    }

    fn bencher(&self) -> Bencher {
        if self.quick {
            Bencher::quick()
        } else {
            Bencher { measure_secs: 0.5, warmup_secs: 0.1, max_samples: 100, quiet: true }
        }
    }
}

/// FC grid: (batch, d_out, d_in).  Chosen to spread `word_ops` over
/// ~2.5 orders of magnitude so the dispatch constant and the word rate
/// separate cleanly in the fit.
fn fc_grid(quick: bool) -> Vec<(usize, usize, usize)> {
    let mut g = vec![(8, 128, 256), (8, 512, 512), (32, 512, 512), (8, 1024, 1024)];
    if !quick {
        g.push((32, 1024, 1024));
        g.push((64, 1024, 2048));
    }
    g
}

/// Conv grid: (hw, c, o) at batch 8, k=3/s=1/p=1 — ResNet-18-interior
/// and CIFAR-interior shapes, where the byte-heavy im2row traffic makes
/// the byte rate observable.
fn conv_grid(quick: bool) -> Vec<(usize, usize, usize)> {
    let mut g = vec![(8, 32, 32), (14, 64, 64), (7, 128, 128)];
    if !quick {
        g.push((14, 128, 128));
        g.push((7, 256, 256));
    }
    g
}

/// GCN grid: (adjacency, nodes, d_in, d_out, batch).  Spans sparse
/// power-law and dense grid block densities so the fitted
/// per-stored-block rate separates from the dense combine term.
fn gcn_shapes(quick: bool) -> Vec<(AdjSpec, usize, usize, usize, usize)> {
    let mut g = vec![
        (AdjSpec { kind: AdjKind::PowerLaw, degree: 4, seed: 3 }, 128, 64, 64, 4),
        (AdjSpec { kind: AdjKind::PowerLaw, degree: 6, seed: 4 }, 256, 64, 128, 8),
        (AdjSpec { kind: AdjKind::Grid, degree: 2, seed: 0 }, 64, 64, 64, 4),
    ];
    if !quick {
        g.push((AdjSpec { kind: AdjKind::Grid, degree: 3, seed: 0 }, 128, 64, 64, 8));
        g.push((AdjSpec { kind: AdjKind::PowerLaw, degree: 8, seed: 5 }, 512, 64, 64, 8));
    }
    g
}

/// Repack grid: (lines, bits) image shapes spreading total bytes over
/// ~1.5 orders of magnitude so the byte rate and the dispatch constant
/// separate in the fit.
fn repack_grid(quick: bool) -> Vec<(usize, usize)> {
    let mut g = vec![(64, 1024), (128, 2048), (256, 4096)];
    if !quick {
        g.push((256, 8192));
    }
    g
}

/// Measure real conversion bandwidth for every registered layout pair
/// (`layout::repack::all_pairs()`) over the repack grid — the
/// measurements `fit_profile` turns into the profile's `repacks`
/// coefficients, so `Calibrated`/`Live` planners price layout edges
/// from this host's streaming speed instead of the analytic constants.
pub fn run_repacks(cfg: &MicrobenchConfig) -> Vec<RepackMeasurement> {
    let b = cfg.bencher();
    let mut rng = Rng::new(cfg.seed.wrapping_add(0x4c41_594f)); // "LAYO"
    let mut out = Vec::new();
    for (src, dst) in repack::all_pairs() {
        for (lines, bits) in repack_grid(cfg.quick) {
            let m = BitMatrix::random(lines, bits, Layout::RowMajor, &mut rng);
            let base = repack::BitImage::from_rows32(lines, bits, m.data);
            let src_img = repack::convert(&base, src);
            let name =
                format!("tuner/repack/{}/{lines}x{bits}", repack::pair_name(src, dst));
            let wpl32 = LayoutDesc::new(LayoutKind::Row32, lines, bits).words_per_line();
            // the hot executor pairs are measured over the no-alloc
            // row-slice helpers into pre-sized buffers — exactly the
            // arena path the fitted coefficients will price; the tiled
            // pairs (no executor hot path) measure the allocating
            // converter API, a conservative upper bound
            let r = match (src, dst) {
                (LayoutKind::Row32, LayoutKind::Blocked64) => {
                    let s32 = src_img.words.as_w32();
                    let mut d64 =
                        vec![0u64; LayoutDesc::new(dst, lines, bits).total_words()];
                    b.bench(&name, 1.0, || {
                        repack::rows32_to_rows64(s32, wpl32, &mut d64);
                        std::hint::black_box(&mut d64);
                    })
                }
                (LayoutKind::Blocked64, LayoutKind::Row32) => {
                    let s64 = src_img.words.as_w64();
                    let mut d32 =
                        vec![0u32; LayoutDesc::new(dst, lines, bits).total_words()];
                    b.bench(&name, 1.0, || {
                        repack::rows64_to_rows32(s64, wpl32, &mut d32);
                        std::hint::black_box(&mut d32);
                    })
                }
                _ => b.bench(&name, 1.0, || {
                    std::hint::black_box(repack::convert(&src_img, dst));
                }),
            };
            let bytes = src_img.desc.storage_bytes()
                + LayoutDesc::new(dst, lines, bits).storage_bytes();
            out.push(RepackMeasurement {
                src,
                dst,
                lines,
                bits,
                bytes,
                secs: r.summary.p50,
            });
        }
    }
    out
}

/// Whether `backend` is a *host* backend — no GPU trace face, costed by
/// an analytic host model — and therefore calibratable.
pub fn is_host_backend(backend: &dyn KernelBackend) -> bool {
    let probe = LayerSpec::BinFc { d_in: 256, d_out: 256 };
    backend
        .layer_traces(&probe, Dims { hw: 0, feat: 256 }, 8, ResidualMode::None, false)
        .is_empty()
}

/// Run the microbench grid over every host backend in `registry`.
/// Shapes a backend rejects at prepare time are skipped (a backend
/// with shape limits calibrates over the shapes it supports).
pub fn run(registry: &BackendRegistry, cfg: &MicrobenchConfig) -> Vec<Measurement> {
    let b = cfg.bencher();
    let mut out = Vec::new();
    for backend in registry.backends() {
        if !is_host_backend(backend) {
            continue;
        }
        out.extend(bench_fc(backend, cfg, &b));
        out.extend(bench_conv(backend, cfg, &b));
        // GCN shapes are measured ONLY on the sparse schemes: they are
        // the backends whose cost face carries a per-block term, and
        // feeding the dense backends' fits with GCN rows would poison
        // their word rate with aggregation work their dense FC/conv
        // faces never see.
        if matches!(backend.scheme(), Scheme::Spmm | Scheme::GcnFused) {
            out.extend(bench_gcn(backend, cfg, &b));
        }
    }
    out
}

fn bench_fc(
    backend: &dyn KernelBackend,
    cfg: &MicrobenchConfig,
    b: &Bencher,
) -> Vec<Measurement> {
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::new();
    for (batch, d_out, d_in) in fc_grid(cfg.quick) {
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, &mut rng);
        let a = BitMatrix::random(batch, d_in, Layout::RowMajor, &mut rng);
        let Ok(fc) = backend.prepare_fc(&w) else { continue };
        let mut scratch = vec![0u64; fc.scratch_words(batch)];
        let mut ints = vec![0i32; batch * d_out];
        let threads = cfg.threads;
        let r = b.bench(
            &format!("tuner/{}/bmm/b{batch}x{d_out}x{d_in}", backend.name()),
            1.0,
            || {
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                fc.bmm(&a.data, batch, &mut ints, &mut ctx);
                std::hint::black_box(&mut ints);
            },
        );
        out.push(Measurement {
            scheme: backend.scheme(),
            kind: "bmm",
            layer: LayerSpec::BinFc { d_in, d_out },
            dims: Dims { hw: 0, feat: d_in },
            batch,
            secs: r.summary.p50,
        });
    }
    out
}

fn bench_conv(
    backend: &dyn KernelBackend,
    cfg: &MicrobenchConfig,
    b: &Bencher,
) -> Vec<Measurement> {
    let mut rng = Rng::new(cfg.seed.wrapping_add(0x5eed));
    let mut out = Vec::new();
    for (hw, c, o) in conv_grid(cfg.quick) {
        let p = BconvProblem { hw, n: 8, c, o, k: 3, stride: 1, pad: 1 };
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        let Ok(conv) = backend.prepare_conv(&filter, p) else { continue };
        let mut scratch = vec![0u64; conv.scratch_words(p)];
        let mut ints = vec![0i32; p.out_elems()];
        let threads = cfg.threads;
        let r = b.bench(
            &format!("tuner/{}/bconv/hw{hw}c{c}o{o}", backend.name()),
            1.0,
            || {
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                conv.bconv(&input.data, p, &mut ints, &mut ctx);
                std::hint::black_box(&mut ints);
            },
        );
        out.push(Measurement {
            scheme: backend.scheme(),
            kind: "bconv",
            layer: LayerSpec::BinConv {
                c,
                o,
                k: 3,
                stride: 1,
                pad: 1,
                pool: false,
                residual: false,
            },
            dims: Dims { hw, feat: c },
            batch: p.n,
            secs: r.summary.p50,
        });
    }
    out
}

fn bench_gcn(
    backend: &dyn KernelBackend,
    cfg: &MicrobenchConfig,
    b: &Bencher,
) -> Vec<Measurement> {
    let mut rng = Rng::new(cfg.seed.wrapping_add(0x6cbb));
    let mut out = Vec::new();
    for (spec, nodes, d_in, d_out, batch) in gcn_shapes(cfg.quick) {
        let adj = sparse::generate(spec, nodes);
        let nnz_blocks = adj.nnz_blocks();
        let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, &mut rng);
        let x = BitMatrix::random(batch, nodes * d_in, Layout::RowMajor, &mut rng);
        let Ok(g) = backend.prepare_gcn(&adj, &w) else { continue };
        let mut scratch = vec![0u64; g.scratch_words(batch)];
        let mut ints = vec![0i32; batch * nodes * d_out];
        let threads = cfg.threads;
        let r = b.bench(
            &format!("tuner/{}/gcn/{}-{nodes}n", backend.name(), spec.tag()),
            1.0,
            || {
                let mut ctx = ExecCtx { words64: &mut scratch, threads };
                g.gcn(&x.data, batch, &mut ints, &mut ctx);
                std::hint::black_box(&mut ints);
            },
        );
        out.push(Measurement {
            scheme: backend.scheme(),
            kind: "gcn",
            layer: LayerSpec::BinGcn { nodes, d_in, d_out, adj: spec, nnz_blocks },
            dims: Dims { hw: 0, feat: nodes * d_in },
            batch,
            secs: r.summary.p50,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_the_host_schemes_probe_as_host_backends() {
        for b in BackendRegistry::global().backends() {
            assert_eq!(is_host_backend(b), b.scheme().is_host(), "{}", b.name());
        }
    }

    #[test]
    fn quick_run_measures_every_host_backend_grid() {
        let cfg = MicrobenchConfig {
            quick: true,
            seed: 7,
            // serial keeps this unit test cheap and deterministic-ish
            threads: 1,
        };
        let ms = run(BackendRegistry::global(), &cfg);
        // every host backend supports every dense grid shape, and the
        // two sparse schemes additionally run the GCN grid
        let hosts: Vec<Scheme> =
            Scheme::all().into_iter().filter(Scheme::is_host).collect();
        let want = hosts.len() * (fc_grid(true).len() + conv_grid(true).len())
            + 2 * gcn_shapes(true).len();
        assert_eq!(ms.len(), want);
        for m in &ms {
            assert!(m.scheme.is_host(), "{m:?}");
            assert!(m.secs.is_finite() && m.secs > 0.0, "{m:?}");
            let row = m.fit_row();
            assert!(row.features.word_ops > 0.0);
        }
        // all three kernel kinds and every host scheme present
        assert!(ms.iter().any(|m| m.kind == "bmm"));
        assert!(ms.iter().any(|m| m.kind == "bconv"));
        assert!(ms.iter().any(|m| m.kind == "gcn"));
        for s in hosts {
            assert!(ms.iter().any(|m| m.scheme == s), "{} missing", s.name());
        }
        // GCN rows appear only under the sparse schemes, and carry the
        // sparse-block regressor the fitter needs
        for m in ms.iter().filter(|m| m.kind == "gcn") {
            assert!(matches!(m.scheme, Scheme::Spmm | Scheme::GcnFused), "{m:?}");
            assert!(m.fit_row().features.sparse_block_ops > 0.0);
        }
    }

    #[test]
    fn repack_run_covers_every_pair_with_fittable_rows() {
        let cfg = MicrobenchConfig { quick: true, seed: 7, threads: 1 };
        let ms = run_repacks(&cfg);
        let grid = repack_grid(true).len();
        assert_eq!(ms.len(), repack::all_pairs().len() * grid);
        for (src, dst) in repack::all_pairs() {
            let rows: Vec<_> =
                ms.iter().filter(|m| m.src == src && m.dst == dst).collect();
            assert_eq!(rows.len(), grid, "{}", repack::pair_name(src, dst));
            for m in rows {
                assert!(m.secs.is_finite() && m.secs > 0.0, "{m:?}");
                assert!(m.bytes > 0);
                let row = m.fit_row();
                assert_eq!(row.features.word_ops, 0.0);
                assert!(row.features.stream_bytes > 0.0);
            }
        }
    }
}
