//! Shape features of one layer's host execution — the regressors every
//! fitted cost model is expressed over.
//!
//! The analytic fastpath host model (`nn::cost::host`) prices a layer
//! as `word_ops / WORD_OPS_PER_SEC + stream_bytes / BYTES_PER_SEC +
//! DISPATCH_SECS` (plus an fp term for the first BWN layer).  The
//! tuner keeps exactly that parameterization but *fits* the
//! coefficients per backend from measured microbench runs, so the
//! feature extraction here must mirror the analytic model's shape math
//! precisely: a calibrated profile is the analytic model with its
//! constants replaced, never a different curve.

use crate::nn::cost::ResidualMode;
use crate::nn::layer::{Dims, LayerSpec};

/// The regressors of one layer execution at one batch size.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Features {
    /// f32 multiply-accumulates (first BWN layer only).
    pub fp_ops: f64,
    /// u64 XOR+POPC+accumulate word operations (binarized layers).
    pub word_ops: f64,
    /// streamed bytes (im2row build, output repack, pooling, residual
    /// save/fetch traffic).
    pub stream_bytes: f64,
    /// stored 64-bit adjacency blocks touched by a sparse aggregation
    /// (BinGcn layers only — zero everywhere else, so dense backends
    /// fit with the column deactivated).
    pub sparse_block_ops: f64,
}

/// Extract the cost-model features of one layer.  `dims` is the
/// layer's *input* dims; `residual`/`model_has_residuals` gate the
/// residual traffic exactly like the analytic host model does.
pub fn layer_features(
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> Features {
    let out_hw = |k: usize, stride: usize, pad: usize| -> usize {
        (dims.hw + 2 * pad - k) / stride + 1
    };
    match *layer {
        LayerSpec::FirstConv { c, o, k, stride, pad } => {
            let ohw = out_hw(k, stride, pad);
            Features {
                fp_ops: (ohw * ohw * batch * o * k * k * c) as f64,
                ..Features::default()
            }
        }
        LayerSpec::BinConv { o, k, stride, pad, residual: is_res, .. } => {
            let c = dims.feat;
            let ohw = out_hw(k, stride, pad);
            let word_ops = (ohw * ohw * batch * o * k * k * c.div_ceil(64)) as f64;
            // im2row build + output repack are streamed bytes
            let mut stream_bytes =
                (ohw * ohw * batch * (k * k * c.div_ceil(8) + o)) as f64;
            if is_res && model_has_residuals && residual != ResidualMode::None {
                let out_dims = dims.after(layer);
                // fp16 residual save/fetch, same accounting as the
                // analytic host model
                let xfers = match residual {
                    ResidualMode::Full => 2,
                    ResidualMode::SaveOnly | ResidualMode::FetchOnly => 1,
                    ResidualMode::None => 0,
                };
                stream_bytes += (out_dims.flat() * batch * 2 * xfers) as f64;
            }
            Features { fp_ops: 0.0, word_ops, stream_bytes, sparse_block_ops: 0.0 }
        }
        LayerSpec::BinFc { d_in, d_out } | LayerSpec::FinalFc { d_in, d_out } => {
            Features {
                word_ops: (batch * d_out * d_in.div_ceil(64)) as f64,
                ..Features::default()
            }
        }
        LayerSpec::BinGcn { nodes, d_in, d_out, nnz_blocks, .. } => Features {
            // per-node combine is dense word work; the aggregation is
            // priced per stored adjacency block, which is what lets a
            // fitted sparse backend track density instead of nodes^2
            word_ops: (batch * nodes * d_out * d_in.div_ceil(64)) as f64,
            sparse_block_ops: (batch * d_out * nnz_blocks) as f64,
            stream_bytes: (batch * nodes * (d_in + d_out)) as f64 / 8.0,
            ..Features::default()
        },
        LayerSpec::Pool => Features {
            // 4 packed loads + 1 store per output word
            stream_bytes: (dims.flat() * batch).div_ceil(8) as f64 * 5.0,
            ..Features::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::cost::host;

    /// The feature extraction must reproduce the analytic fastpath host
    /// model exactly when evaluated with the analytic constants — the
    /// calibrated model is the same curve with fitted coefficients.
    #[test]
    fn features_reproduce_analytic_fastpath_model() {
        use crate::kernels::backend::BackendRegistry;
        use crate::nn::Scheme;
        use crate::sim::{Engine, RTX2080TI};

        let engine = Engine::new(&RTX2080TI);
        let backend = BackendRegistry::global().get(Scheme::Fastpath).unwrap();
        let cases: Vec<(LayerSpec, Dims)> = vec![
            (
                LayerSpec::FirstConv { c: 3, o: 64, k: 3, stride: 1, pad: 1 },
                Dims { hw: 16, feat: 3 },
            ),
            (
                LayerSpec::BinConv {
                    c: 70,
                    o: 40,
                    k: 3,
                    stride: 1,
                    pad: 1,
                    pool: false,
                    residual: false,
                },
                Dims { hw: 14, feat: 70 },
            ),
            (
                LayerSpec::BinConv {
                    c: 64,
                    o: 64,
                    k: 3,
                    stride: 2,
                    pad: 1,
                    pool: false,
                    residual: true,
                },
                Dims { hw: 8, feat: 64 },
            ),
            (LayerSpec::BinFc { d_in: 500, d_out: 300 }, Dims { hw: 0, feat: 500 }),
            (LayerSpec::FinalFc { d_in: 128, d_out: 10 }, Dims { hw: 0, feat: 128 }),
            (LayerSpec::Pool, Dims { hw: 8, feat: 64 }),
        ];
        for (layer, dims) in &cases {
            for (residual, has_res) in
                [(ResidualMode::Full, true), (ResidualMode::None, false)]
            {
                let f = layer_features(layer, *dims, 8, residual, has_res);
                let predicted = f.fp_ops / host::FP_OPS_PER_SEC
                    + f.word_ops / host::WORD_OPS_PER_SEC
                    + f.stream_bytes / host::BYTES_PER_SEC
                    + host::DISPATCH_SECS;
                let analytic =
                    backend.layer_secs(&engine, layer, *dims, 8, residual, has_res);
                let rel = (predicted - analytic).abs() / analytic;
                assert!(
                    rel < 1e-12,
                    "{layer:?} {residual:?}: features {predicted} vs analytic {analytic}"
                );
            }
        }
    }

    /// GCN features must mirror the sparse backends' analytic faces:
    /// `secs = word_ops/rate + sparse_block_ops*BLOCK_WORDS/rate +
    /// stream/B + DISPATCH`, so a fitted sparse profile is the same
    /// curve with measured coefficients.
    #[test]
    fn gcn_features_reproduce_analytic_sparse_model() {
        use crate::kernels::backend::BackendRegistry;
        use crate::kernels::backends::simd::host as simd_host;
        use crate::kernels::backends::sparse::host as sp_host;
        use crate::kernels::simd::PopcountEngine;
        use crate::nn::Scheme;
        use crate::sim::{Engine, RTX2080TI};
        use crate::sparse::{AdjKind, AdjSpec};

        let engine = Engine::new(&RTX2080TI);
        let reg = BackendRegistry::global();
        let layer = LayerSpec::BinGcn {
            nodes: 256,
            d_in: 64,
            d_out: 128,
            adj: AdjSpec { kind: AdjKind::PowerLaw, degree: 4, seed: 2 },
            nnz_blocks: 700,
        };
        let dims = Dims { hw: 0, feat: 256 * 64 };
        let f = layer_features(&layer, dims, 8, ResidualMode::None, false);
        assert!(f.sparse_block_ops > 0.0);
        let rate = simd_host::word_ops_per_sec(PopcountEngine::detect());
        for (scheme, block_words) in [
            (Scheme::Spmm, sp_host::SPMM_BLOCK_WORDS),
            (Scheme::GcnFused, sp_host::FUSED_BLOCK_WORDS),
        ] {
            let predicted = (f.word_ops + f.sparse_block_ops * block_words) / rate
                + f.stream_bytes / host::BYTES_PER_SEC
                + host::DISPATCH_SECS;
            let analytic = reg.get(scheme).unwrap().layer_secs(
                &engine,
                &layer,
                dims,
                8,
                ResidualMode::None,
                false,
            );
            let rel = (predicted - analytic).abs() / analytic;
            assert!(rel < 1e-12, "{scheme:?}: {predicted} vs {analytic}");
        }
    }

    #[test]
    fn features_scale_with_batch() {
        let l = LayerSpec::BinFc { d_in: 512, d_out: 512 };
        let d = Dims { hw: 0, feat: 512 };
        let f8 = layer_features(&l, d, 8, ResidualMode::None, false);
        let f32x = layer_features(&l, d, 32, ResidualMode::None, false);
        assert_eq!(f32x.word_ops, 4.0 * f8.word_ops);
    }
}
