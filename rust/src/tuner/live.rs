//! `LiveCosts`: the lock-free online feedback sink between the arena
//! executor and the planner.
//!
//! The executor records each backend layer's `(predicted, measured)`
//! seconds; the sink keeps one exponentially-weighted moving average of
//! the `measured / predicted` ratio per scheme.  `CostSource::Live`
//! multiplies the calibrated prior by this ratio, so a long-running
//! server converges on true host costs, and `EngineModel` re-plans
//! when a scheme's ratio drifts past its threshold (default 2x either
//! way).
//!
//! The sink sits on the request path, so it is wait-free for readers
//! and lock-free for writers: one `AtomicU64` of f64 bits per scheme,
//! updated with a compare-exchange loop.  A torn EWMA update under
//! contention costs at most one lost sample — irrelevant to a smoothed
//! drift estimate — and no executor thread ever blocks.
//!
//! There is exactly ONE timing source feeding this sink: the
//! executor's per-layer wall clock in `engine::executor` (`forward`
//! times every layer once).  The same measurement has two consumers —
//! this EWMA (per-scheme, against the ratio-free prior, for
//! re-planning) and the `obs` attribution (per-layer cumulative
//! seconds vs the plan's predictions, for `obs::export::Snapshot`).
//! Neither re-times anything, so the two views can never disagree
//! about what the hardware did.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::nn::cost::Scheme;

/// One slot per `Scheme` variant (fixed: registries key backends by
/// scheme, and `register` replaces in place, so the universe of keys is
/// `Scheme::all()`).
const N_SCHEMES: usize = 8;

/// Lock-free per-scheme EWMA of measured-over-predicted cost ratios.
#[derive(Debug)]
pub struct LiveCosts {
    /// f64 bits of the EWMA ratio; only meaningful once samples > 0
    ratios: [AtomicU64; N_SCHEMES],
    samples: [AtomicU64; N_SCHEMES],
    alpha: f64,
}

impl Default for LiveCosts {
    fn default() -> Self {
        LiveCosts::new()
    }
}

impl LiveCosts {
    /// Default smoothing (alpha = 0.25: ~4-sample memory, fast enough
    /// to cross a 2x drift threshold within a handful of batches).
    pub fn new() -> LiveCosts {
        LiveCosts::with_alpha(0.25)
    }

    pub fn with_alpha(alpha: f64) -> LiveCosts {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0, 1]");
        LiveCosts {
            ratios: std::array::from_fn(|_| AtomicU64::new(0)),
            samples: std::array::from_fn(|_| AtomicU64::new(0)),
            alpha,
        }
    }

    /// Record one executed layer: `predicted` seconds from the plan,
    /// `measured` wall seconds.  Degenerate inputs (non-finite or
    /// non-positive) are dropped; ratios clamp to [1e-6, 1e6] so a
    /// absurd prediction cannot poison the average with infinities.
    pub fn record(&self, scheme: Scheme, predicted: f64, measured: f64) {
        if !(predicted.is_finite() && predicted > 0.0)
            || !(measured.is_finite() && measured > 0.0)
        {
            return;
        }
        let r = (measured / predicted).clamp(1e-6, 1e6);
        let i = idx(scheme);
        let n = self.samples[i].fetch_add(1, Ordering::Relaxed);
        let mut cur = self.ratios[i].load(Ordering::Relaxed);
        loop {
            let old = f64::from_bits(cur);
            let new = if n == 0 { r } else { old + self.alpha * (r - old) };
            match self.ratios[i].compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// The EWMA measured/predicted ratio (1.0 until a sample arrives).
    pub fn ratio(&self, scheme: Scheme) -> f64 {
        let i = idx(scheme);
        if self.samples[i].load(Ordering::Relaxed) == 0 {
            1.0
        } else {
            f64::from_bits(self.ratios[i].load(Ordering::Relaxed))
        }
    }

    /// Samples recorded for `scheme`.
    pub fn samples(&self, scheme: Scheme) -> u64 {
        self.samples[idx(scheme)].load(Ordering::Relaxed)
    }

    /// Symmetric drift of `scheme`: `max(ratio, 1/ratio)` — 1.0 means
    /// the prediction is exact, 2.0 means off by 2x in either direction.
    pub fn drift(&self, scheme: Scheme) -> f64 {
        let r = self.ratio(scheme);
        r.max(1.0 / r)
    }

    /// `(scheme name, ewma ratio, samples)` for every scheme with data.
    pub fn snapshot(&self) -> Vec<(&'static str, f64, u64)> {
        Scheme::all()
            .into_iter()
            .filter(|s| self.samples(*s) > 0)
            .map(|s| (s.name(), self.ratio(s), self.samples(s)))
            .collect()
    }
}

fn idx(scheme: Scheme) -> usize {
    Scheme::all()
        .iter()
        .position(|s| *s == scheme)
        .expect("every scheme has a slot")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_count_matches_scheme_universe() {
        assert_eq!(N_SCHEMES, Scheme::all().len());
    }

    #[test]
    fn empty_reads_as_exact() {
        let l = LiveCosts::new();
        for s in Scheme::all() {
            assert_eq!(l.ratio(s), 1.0);
            assert_eq!(l.drift(s), 1.0);
            assert_eq!(l.samples(s), 0);
        }
        assert!(l.snapshot().is_empty());
    }

    #[test]
    fn ewma_converges_to_the_true_ratio() {
        let l = LiveCosts::new();
        for _ in 0..50 {
            l.record(Scheme::Fastpath, 1e-4, 3e-4); // consistently 3x slow
        }
        let r = l.ratio(Scheme::Fastpath);
        assert!((r - 3.0).abs() < 1e-9, "ratio {r}");
        assert!((l.drift(Scheme::Fastpath) - 3.0).abs() < 1e-9);
        // faster-than-predicted drifts symmetrically
        for _ in 0..200 {
            l.record(Scheme::Btc, 4e-4, 1e-4);
        }
        assert!((l.drift(Scheme::Btc) - 4.0).abs() < 1e-6);
        assert_eq!(l.snapshot().len(), 2);
    }

    #[test]
    fn degenerate_samples_are_dropped_and_ratios_clamped() {
        let l = LiveCosts::new();
        l.record(Scheme::Btc, 0.0, 1e-3);
        l.record(Scheme::Btc, f64::NAN, 1e-3);
        l.record(Scheme::Btc, 1e-3, f64::INFINITY);
        l.record(Scheme::Btc, 1e-3, -1.0);
        assert_eq!(l.samples(Scheme::Btc), 0);
        l.record(Scheme::Btc, 1e-30, 1e30);
        assert_eq!(l.ratio(Scheme::Btc), 1e6);
    }

    #[test]
    fn concurrent_recording_stays_sane() {
        let l = std::sync::Arc::new(LiveCosts::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let l = std::sync::Arc::clone(&l);
                s.spawn(move || {
                    for _ in 0..1000 {
                        l.record(Scheme::Sbnn32, 1e-4, 2e-4);
                    }
                });
            }
        });
        assert_eq!(l.samples(Scheme::Sbnn32), 4000);
        assert!((l.ratio(Scheme::Sbnn32) - 2.0).abs() < 1e-9);
    }
}
