//! Least-squares fitting of per-backend cost-model coefficients from
//! microbench measurements.
//!
//! Each measurement is `(Features, measured seconds)`; the model is
//! `secs = word_ops*a + sparse_block_ops*d + stream_bytes*b + c` (the
//! fp coefficient is not fit — the first BWN layer is
//! scheme-independent and never runs through a backend kernel, so it
//! keeps the analytic seed).  The fit minimizes *relative* squared
//! error (every row scaled by its measured seconds), so microsecond FC
//! layers and millisecond conv layers weigh equally, and clamps
//! coefficients to be non-negative with a tiny active-set loop: a
//! negative rate has no physical meaning and would let the planner
//! extrapolate below zero.  Columns with no support in the data (e.g.
//! `sparse_block_ops` for a dense backend that never ran a GCN
//! microbench) are deactivated up front and fitted to exactly 0.

use super::features::Features;
use super::profile::SchemeCoeffs;

/// One fit input row: the layer's features and its measured seconds.
#[derive(Clone, Copy, Debug)]
pub struct FitRow {
    pub features: Features,
    pub secs: f64,
}

/// Fit one backend's coefficients.  Returns `None` with fewer than 3
/// usable rows (the dense model has 3 free parameters; the sparse
/// column only activates when GCN rows are present) or when every row
/// is degenerate.
pub fn fit_coeffs(rows: &[FitRow]) -> Option<SchemeCoeffs> {
    let rows: Vec<FitRow> = rows
        .iter()
        .copied()
        .filter(|r| r.secs.is_finite() && r.secs > 0.0)
        .collect();
    if rows.len() < 3 {
        return None;
    }
    // relative-error scaling: design row [w, blk, s, 1]/secs, target 1
    let design: Vec<([f64; 4], f64)> = rows
        .iter()
        .map(|r| {
            (
                [
                    r.features.word_ops / r.secs,
                    r.features.sparse_block_ops / r.secs,
                    r.features.stream_bytes / r.secs,
                    1.0 / r.secs,
                ],
                1.0,
            )
        })
        .collect();
    // deactivate columns with no data at all (all-zero regressors):
    // their coefficient is unidentifiable and must be exactly 0
    let mut active = [true; 4];
    for j in 0..4 {
        if design.iter().all(|(row, _)| row[j] == 0.0) {
            active[j] = false;
        }
    }
    let mut x = [0.0f64; 4];
    // active-set loop: solve, drop the most negative coefficient, repeat
    for _ in 0..4 {
        x = solve_normal(&design, active)?;
        let mut worst = None;
        for (i, &xi) in x.iter().enumerate() {
            if active[i] && xi < 0.0 {
                match worst {
                    Some((_, w)) if xi >= w => {}
                    _ => worst = Some((i, xi)),
                }
            }
        }
        match worst {
            Some((i, _)) => {
                active[i] = false;
                x[i] = 0.0;
            }
            None => break,
        }
    }
    for (i, xi) in x.iter_mut().enumerate() {
        if !active[i] || !xi.is_finite() || *xi < 0.0 {
            *xi = 0.0;
        }
    }
    let gcn_samples = rows
        .iter()
        .filter(|r| r.features.sparse_block_ops > 0.0)
        .count();
    let coeffs = SchemeCoeffs {
        secs_per_word_op: x[0],
        secs_per_sparse_block: x[1],
        secs_per_byte: x[2],
        dispatch_secs: x[3],
        secs_per_fp_op: SchemeCoeffs::analytic().secs_per_fp_op,
        samples: rows.len(),
        gcn_samples,
        rel_rmse: rel_rmse(&rows, x),
    };
    coeffs.is_sane().then_some(coeffs)
}

fn rel_rmse(rows: &[FitRow], x: [f64; 4]) -> f64 {
    let sum: f64 = rows
        .iter()
        .map(|r| {
            let pred = r.features.word_ops * x[0]
                + r.features.sparse_block_ops * x[1]
                + r.features.stream_bytes * x[2]
                + x[3];
            let rel = (pred - r.secs) / r.secs;
            rel * rel
        })
        .sum();
    (sum / rows.len() as f64).sqrt()
}

/// Solve the normal equations of a 4-column weighted least-squares
/// problem, restricted to `active` columns (inactive columns are pinned
/// to 0).  Columns are rescaled to unit magnitude before elimination so
/// the wildly different feature scales (word ops ~1e6, constant ~1e5)
/// do not wreck conditioning, and a tiny relative ridge keeps a
/// collinear grid solvable instead of exploding.
fn solve_normal(design: &[([f64; 4], f64)], active: [bool; 4]) -> Option<[f64; 4]> {
    const N: usize = 4;
    // column scales
    let mut scale = [0.0f64; N];
    for (row, _) in design {
        for j in 0..N {
            scale[j] = scale[j].max(row[j].abs());
        }
    }
    for s in &mut scale {
        if *s <= 0.0 {
            *s = 1.0;
        }
    }
    // normal matrix + rhs over scaled columns
    let mut a = [[0.0f64; N]; N];
    let mut b = [0.0f64; N];
    for (row, y) in design {
        let mut r = [0.0f64; N];
        for j in 0..N {
            r[j] = row[j] / scale[j];
        }
        for i in 0..N {
            for j in 0..N {
                a[i][j] += r[i] * r[j];
            }
            b[i] += r[i] * y;
        }
    }
    let trace: f64 = (0..N).map(|i| a[i][i]).sum();
    let ridge = 1e-12 * trace.max(1e-300);
    for (i, row) in a.iter_mut().enumerate() {
        row[i] += ridge;
        if !active[i] {
            // pin the column: identity row, zero rhs
            *row = [0.0; N];
            row[i] = 1.0;
            b[i] = 0.0;
        }
    }
    for (j, on) in active.iter().enumerate() {
        if !*on {
            for (i, row) in a.iter_mut().enumerate() {
                if i != j {
                    row[j] = 0.0;
                }
            }
        }
    }
    // Gaussian elimination with partial pivoting
    let mut x = b;
    for col in 0..N {
        let (pivot, max) = (col..N)
            .map(|r| (r, a[r][col].abs()))
            .fold((col, 0.0), |acc, v| if v.1 > acc.1 { v } else { acc });
        if max <= 0.0 {
            return None;
        }
        a.swap(col, pivot);
        x.swap(col, pivot);
        for r in (col + 1)..N {
            let f = a[r][col] / a[col][col];
            for c in col..N {
                a[r][c] -= f * a[col][c];
            }
            x[r] -= f * x[col];
        }
    }
    for col in (0..N).rev() {
        for r in 0..col {
            let f = a[r][col] / a[col][col];
            x[r] -= f * x[col];
        }
        x[col] /= a[col][col];
    }
    // unscale
    let mut out = [0.0f64; N];
    for j in 0..N {
        out[j] = x[j] / scale[j];
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(word: f64, bytes: f64, secs: f64) -> FitRow {
        FitRow {
            features: Features {
                fp_ops: 0.0,
                word_ops: word,
                stream_bytes: bytes,
                sparse_block_ops: 0.0,
            },
            secs,
        }
    }

    fn gcn_row(word: f64, blocks: f64, bytes: f64, secs: f64) -> FitRow {
        FitRow {
            features: Features {
                fp_ops: 0.0,
                word_ops: word,
                stream_bytes: bytes,
                sparse_block_ops: blocks,
            },
            secs,
        }
    }

    #[test]
    fn recovers_exact_synthetic_coefficients() {
        // secs = w*2e-10 + s*5e-11 + 3e-6 over a mixed fc/conv-like grid
        let (a, b, c) = (2e-10, 5e-11, 3e-6);
        let shapes = [
            (1.6e4, 0.0),
            (6.6e4, 0.0),
            (2.6e5, 0.0),
            (1.2e5, 2.1e5),
            (9.4e5, 9.0e5),
            (3.7e6, 2.4e6),
        ];
        let rows: Vec<FitRow> = shapes
            .iter()
            .map(|&(w, s)| row(w, s, w * a + s * b + c))
            .collect();
        let got = fit_coeffs(&rows).expect("fit");
        assert!((got.secs_per_word_op - a).abs() / a < 1e-6, "{got:?}");
        assert!((got.secs_per_byte - b).abs() / b < 1e-6, "{got:?}");
        assert!((got.dispatch_secs - c).abs() / c < 1e-6, "{got:?}");
        // no GCN rows: the sparse column is deactivated, fitted to 0
        assert_eq!(got.secs_per_sparse_block, 0.0);
        assert_eq!(got.gcn_samples, 0);
        assert!(got.rel_rmse < 1e-9, "{got:?}");
        assert_eq!(got.samples, rows.len());
    }

    #[test]
    fn recovers_sparse_block_coefficient_from_gcn_rows() {
        // secs = w*a + blk*d + s*b + c over a mixed dense/GCN grid —
        // exactly the row mix a sparse-backend calibration produces
        let (a, d, b, c) = (2e-10, 4e-10, 5e-11, 3e-6);
        let shapes = [
            (1.6e4, 0.0, 0.0),
            (2.6e5, 0.0, 0.0),
            (1.2e5, 0.0, 2.1e5),
            (2.0e5, 3.0e4, 4.0e4),
            (8.0e5, 2.4e5, 1.6e5),
            (3.2e6, 9.6e5, 6.4e5),
            (6.4e6, 3.8e6, 1.3e6),
        ];
        let rows: Vec<FitRow> = shapes
            .iter()
            .map(|&(w, blk, s)| gcn_row(w, blk, s, w * a + blk * d + s * b + c))
            .collect();
        let got = fit_coeffs(&rows).expect("fit");
        assert!((got.secs_per_word_op - a).abs() / a < 1e-6, "{got:?}");
        assert!((got.secs_per_sparse_block - d).abs() / d < 1e-6, "{got:?}");
        assert!((got.secs_per_byte - b).abs() / b < 1e-6, "{got:?}");
        assert!((got.dispatch_secs - c).abs() / c < 1e-6, "{got:?}");
        assert_eq!(got.gcn_samples, 4);
        assert!(got.rel_rmse < 1e-9, "{got:?}");
    }

    #[test]
    fn tolerates_measurement_noise() {
        let (a, c) = (1e-10, 2e-6);
        let mut rng = crate::util::Rng::new(11);
        let rows: Vec<FitRow> = (0..12)
            .map(|i| {
                let w = 1e4 * (1 << (i % 6)) as f64;
                let noise = 1.0 + 0.05 * (rng.next_f64() - 0.5);
                row(w, 0.0, (w * a + c) * noise)
            })
            .collect();
        let got = fit_coeffs(&rows).expect("fit");
        assert!((got.secs_per_word_op - a).abs() / a < 0.2, "{got:?}");
        assert!(got.rel_rmse < 0.1, "{got:?}");
    }

    #[test]
    fn clamps_to_non_negative() {
        // a grid engineered so an unconstrained fit would want a
        // negative byte rate: decreasing secs as bytes grow
        let rows = vec![
            row(1e5, 1e3, 3e-5),
            row(1e5, 5e5, 2.6e-5),
            row(2e5, 1e6, 5.2e-5),
            row(4e5, 4e6, 1.0e-4),
        ];
        let got = fit_coeffs(&rows).expect("fit");
        assert!(got.is_sane(), "{got:?}");
        assert!(got.secs_per_byte >= 0.0);
    }

    #[test]
    fn needs_three_rows() {
        assert!(fit_coeffs(&[row(1e5, 0.0, 1e-5), row(2e5, 0.0, 2e-5)]).is_none());
        // non-finite rows are filtered before the count
        assert!(fit_coeffs(&[
            row(1e5, 0.0, 1e-5),
            row(2e5, 0.0, f64::NAN),
            row(3e5, 0.0, 0.0),
        ])
        .is_none());
    }
}
