//! `CalibrationProfile`: the schema-versioned JSON artifact a tuner run
//! emits — fitted per-backend cost-model coefficients keyed by a host
//! fingerprint.
//!
//! The profile is persisted next to the `PlanCache`
//! (`PlanCache::profile_path`) and identified by a stable content
//! digest ([`CalibrationProfile::id`]).  Every plan embeds the id of
//! the cost source it was planned under, so cached plans from a
//! different profile (or from the analytic source) are invalidated the
//! moment the active profile changes.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::engine::json::Value;
use crate::layout::LayoutKind;
use crate::nn::cost::{host, ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};

use super::features::layer_features;
use super::fingerprint::HostFingerprint;

/// Version of the profile JSON document.  Bump whenever the layout (or
/// the meaning of a fitted coefficient) changes; `from_json` rejects
/// any other version, and because the profile id embeds the schema,
/// cached plans from an old profile schema are invalidated too.
///
/// v2: the layout co-design subsystem — profiles additionally carry
/// fitted repack-bandwidth coefficients per layout pair (`repacks`),
/// so calibrated planners price layout edges from measurement.
///
/// v3: the sparse subsystem — coefficient sets gain a fitted
/// per-stored-adjacency-block rate (`secs_per_sparse_block`) and the
/// GCN sample count that gates BinGcn predictions.
pub const PROFILE_SCHEMA: usize = 3;

/// Fitted cost-model coefficients of one backend: the analytic host
/// model's parameterization (`tuner::features`) with measured values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeCoeffs {
    /// seconds per u64 XOR+POPC+accumulate word op (1 / word-ops-per-sec).
    pub secs_per_word_op: f64,
    /// seconds per stored 64-bit adjacency block touched by a sparse
    /// aggregation (BinGcn layers; 0 for backends never measured on
    /// GCN shapes).
    pub secs_per_sparse_block: f64,
    /// seconds per streamed byte (1 / bytes-per-sec).
    pub secs_per_byte: f64,
    /// fixed fork/join + repack latency per layer dispatch.
    pub dispatch_secs: f64,
    /// seconds per f32 multiply-accumulate (first BWN layer).  Not fit
    /// by the microbench (the first layer is scheme-independent); the
    /// fitter seeds it from the analytic constant.
    pub secs_per_fp_op: f64,
    /// microbench measurements behind the fit.
    pub samples: usize,
    /// GCN-shape measurements behind the fit.  When 0 the sparse-block
    /// rate is unidentified, so [`CalibrationProfile::layer_secs`]
    /// refuses to price BinGcn layers and the caller falls back to the
    /// backend's analytic face.
    pub gcn_samples: usize,
    /// relative RMS error of the fit over its own measurements.
    pub rel_rmse: f64,
}

impl SchemeCoeffs {
    /// The analytic fastpath host constants expressed as coefficients —
    /// the prior a fit starts from, and a convenient test fixture.
    pub fn analytic() -> SchemeCoeffs {
        SchemeCoeffs {
            secs_per_word_op: 1.0 / host::WORD_OPS_PER_SEC,
            secs_per_sparse_block: 0.0,
            secs_per_byte: 1.0 / host::BYTES_PER_SEC,
            dispatch_secs: host::DISPATCH_SECS,
            secs_per_fp_op: 1.0 / host::FP_OPS_PER_SEC,
            samples: 0,
            gcn_samples: 0,
            rel_rmse: 0.0,
        }
    }

    /// Predicted seconds for a feature vector.
    pub fn predict(&self, f: super::features::Features) -> f64 {
        f.fp_ops * self.secs_per_fp_op
            + f.word_ops * self.secs_per_word_op
            + f.sparse_block_ops * self.secs_per_sparse_block
            + f.stream_bytes * self.secs_per_byte
            + self.dispatch_secs
    }

    /// All coefficients finite and non-negative, with a sane dispatch.
    pub fn is_sane(&self) -> bool {
        let nonneg = |x: f64| x.is_finite() && x >= 0.0;
        nonneg(self.secs_per_word_op)
            && nonneg(self.secs_per_sparse_block)
            && nonneg(self.secs_per_byte)
            && nonneg(self.dispatch_secs)
            && nonneg(self.secs_per_fp_op)
            && self.dispatch_secs < 1.0
    }
}

/// A fitted per-host calibration: fingerprint + one coefficient set per
/// calibrated scheme (backends without an entry fall back to their
/// analytic cost face under `CostSource::Calibrated`), plus fitted
/// repack bandwidth per layout pair (pairs without an entry fall back
/// to `layout::cost::analytic_repack_secs`).
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationProfile {
    pub fingerprint: HostFingerprint,
    /// `(scheme name, coefficients)` in registration order.
    pub schemes: Vec<(String, SchemeCoeffs)>,
    /// `("Src->Dst" layout pair, coefficients)` in `all_pairs` order —
    /// only `secs_per_byte` and `dispatch_secs` are meaningful for a
    /// repack (the word/fp terms are fitted to exactly 0).
    pub repacks: Vec<(String, SchemeCoeffs)>,
}

/// The profile key of one conversion direction (`"Row32->Blocked64"`).
pub fn repack_key(src: LayoutKind, dst: LayoutKind) -> String {
    crate::layout::repack::pair_name(src, dst)
}

impl CalibrationProfile {
    /// Coefficients for `scheme`, if it was calibrated.
    pub fn coeffs(&self, scheme: Scheme) -> Option<&SchemeCoeffs> {
        self.schemes
            .iter()
            .find(|(n, _)| n == scheme.name())
            .map(|(_, c)| c)
    }

    /// Fitted seconds of one layer under `scheme`; `None` when the
    /// scheme was not calibrated (caller falls back to analytic).
    /// BinGcn layers additionally require the fit to have seen GCN
    /// shapes (`gcn_samples > 0`) — otherwise the sparse-block rate is
    /// an unidentified 0 and the prediction would claim the
    /// aggregation is free.
    pub fn layer_secs(
        &self,
        scheme: Scheme,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> Option<f64> {
        let c = self.coeffs(scheme)?;
        if matches!(layer, LayerSpec::BinGcn { .. }) && c.gcn_samples == 0 {
            return None;
        }
        Some(c.predict(layer_features(layer, dims, batch, residual, model_has_residuals)))
    }

    /// Fitted repack coefficients for one layout pair, if calibrated.
    pub fn repack_coeffs(&self, src: LayoutKind, dst: LayoutKind) -> Option<&SchemeCoeffs> {
        let key = repack_key(src, dst);
        self.repacks.iter().find(|(n, _)| *n == key).map(|(_, c)| c)
    }

    /// Fitted seconds of converting `bytes` of total traffic from
    /// `src` to `dst` layout; `None` when the pair was not calibrated
    /// (caller falls back to the analytic repack model).
    pub fn repack_secs(&self, src: LayoutKind, dst: LayoutKind, bytes: usize) -> Option<f64> {
        if src == dst {
            return Some(0.0);
        }
        self.repack_coeffs(src, dst)
            .map(|c| bytes as f64 * c.secs_per_byte + c.dispatch_secs)
    }

    /// A copy with each named scheme's fitted rates scaled by its live
    /// EWMA measured/predicted ratio — how a cleanly shut down
    /// `EngineModel` persists what its `CostSource::Live` loop learned
    /// (see `EngineModel::converged_profile`).  Scaling every additive
    /// term by the ratio scales the predicted seconds by exactly that
    /// ratio, matching the EWMA's semantics; schemes without a ratio
    /// (or absent from the profile) are left untouched.  The content
    /// id changes with the coefficients, so cached plans priced under
    /// the old profile are invalidated on the next start.
    pub fn scaled_by(&self, ratios: &[(String, f64)]) -> CalibrationProfile {
        let mut out = self.clone();
        for (name, c) in out.schemes.iter_mut() {
            if let Some((_, r)) = ratios
                .iter()
                .find(|(n, r)| n == name && r.is_finite() && *r > 0.0)
            {
                c.secs_per_word_op *= r;
                c.secs_per_sparse_block *= r;
                c.secs_per_byte *= r;
                c.dispatch_secs *= r;
                c.secs_per_fp_op *= r;
            }
        }
        out
    }

    /// Stable content digest: `cal<schema>-<fnv64 of the JSON form>`.
    /// This is the id plans embed as their `cost_profile`, so any
    /// change to the fingerprint, the coefficient values, or the
    /// profile schema invalidates cached plans.
    pub fn id(&self) -> String {
        format!("cal{PROFILE_SCHEMA}-{:016x}", fnv1a64(self.to_json().as_bytes()))
    }

    pub fn to_json(&self) -> String {
        let coeff_obj = |key: &str, name: &str, c: &SchemeCoeffs| {
            Value::Obj(vec![
                (key.to_string(), Value::Str(name.to_string())),
                (
                    "secs_per_word_op".to_string(),
                    Value::Num(c.secs_per_word_op),
                ),
                (
                    "secs_per_sparse_block".to_string(),
                    Value::Num(c.secs_per_sparse_block),
                ),
                ("secs_per_byte".to_string(), Value::Num(c.secs_per_byte)),
                ("dispatch_secs".to_string(), Value::Num(c.dispatch_secs)),
                ("secs_per_fp_op".to_string(), Value::Num(c.secs_per_fp_op)),
                ("samples".to_string(), Value::Num(c.samples as f64)),
                ("gcn_samples".to_string(), Value::Num(c.gcn_samples as f64)),
                ("rel_rmse".to_string(), Value::Num(c.rel_rmse)),
            ])
        };
        let schemes: Vec<Value> = self
            .schemes
            .iter()
            .map(|(name, c)| coeff_obj("scheme", name, c))
            .collect();
        let repacks: Vec<Value> = self
            .repacks
            .iter()
            .map(|(pair, c)| coeff_obj("pair", pair, c))
            .collect();
        Value::Obj(vec![
            ("schema".to_string(), Value::Num(PROFILE_SCHEMA as f64)),
            ("fingerprint".to_string(), self.fingerprint.to_value()),
            ("schemes".to_string(), Value::Arr(schemes)),
            ("repacks".to_string(), Value::Arr(repacks)),
        ])
        .to_string()
    }

    pub fn from_json(text: &str) -> Result<CalibrationProfile> {
        let v = Value::parse(text).map_err(|e| anyhow::anyhow!("profile json: {e}"))?;
        let schema = v
            .get("schema")
            .and_then(Value::as_usize)
            .context("profile field \"schema\"")?;
        if schema != PROFILE_SCHEMA {
            bail!(
                "profile schema {schema} (this build reads {PROFILE_SCHEMA}); \
                 re-run the tuner"
            );
        }
        let fingerprint = HostFingerprint::from_value(
            v.get("fingerprint").context("profile field \"fingerprint\"")?,
        )
        .map_err(|e| anyhow::anyhow!("profile {e}"))?;
        let parse_coeffs = |section: &str, key: &str| -> Result<Vec<(String, SchemeCoeffs)>> {
            let mut out = Vec::new();
            for (i, sv) in v
                .get(section)
                .and_then(Value::as_arr)
                .with_context(|| format!("profile field {section:?}"))?
                .iter()
                .enumerate()
            {
                let name = sv
                    .get(key)
                    .and_then(Value::as_str)
                    .with_context(|| format!("profile {section}[{i}] {key}"))?
                    .to_string();
                let num = |k: &str| -> Result<f64> {
                    sv.get(k)
                        .and_then(Value::as_f64)
                        .with_context(|| format!("profile {section}[{i}] field {k:?}"))
                };
                let coeffs = SchemeCoeffs {
                    secs_per_word_op: num("secs_per_word_op")?,
                    secs_per_sparse_block: num("secs_per_sparse_block")?,
                    secs_per_byte: num("secs_per_byte")?,
                    dispatch_secs: num("dispatch_secs")?,
                    secs_per_fp_op: num("secs_per_fp_op")?,
                    samples: sv
                        .get("samples")
                        .and_then(Value::as_usize)
                        .with_context(|| format!("profile {section}[{i}] samples"))?,
                    gcn_samples: sv
                        .get("gcn_samples")
                        .and_then(Value::as_usize)
                        .with_context(|| format!("profile {section}[{i}] gcn_samples"))?,
                    rel_rmse: num("rel_rmse")?,
                };
                ensure_sane(&name, &coeffs)?;
                out.push((name, coeffs));
            }
            Ok(out)
        };
        Ok(CalibrationProfile {
            fingerprint,
            schemes: parse_coeffs("schemes", "scheme")?,
            repacks: parse_coeffs("repacks", "pair")?,
        })
    }

    /// Persist to `path` (creating parent directories).
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
    }

    /// Load a previously saved profile.
    pub fn load(path: impl AsRef<Path>) -> Result<CalibrationProfile> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!("read calibration profile {:?}", path.as_ref())
        })?;
        CalibrationProfile::from_json(&text)
    }
}

fn ensure_sane(name: &str, c: &SchemeCoeffs) -> Result<()> {
    if !c.is_sane() {
        bail!("profile scheme {name:?}: non-finite or negative coefficients");
    }
    Ok(())
}

/// FNV-1a 64-bit — stable, dependency-free content hash for profile ids.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::backend::BackendRegistry;

    fn sample() -> CalibrationProfile {
        CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![
                (
                    "FASTPATH".to_string(),
                    SchemeCoeffs {
                        secs_per_word_op: 8.5e-11,
                        secs_per_sparse_block: 0.0,
                        secs_per_byte: 6.0e-11,
                        dispatch_secs: 2.5e-6,
                        secs_per_fp_op: 1.25e-10,
                        samples: 9,
                        gcn_samples: 0,
                        rel_rmse: 0.07,
                    },
                ),
                (
                    "SPMM".to_string(),
                    SchemeCoeffs {
                        secs_per_word_op: 9.5e-11,
                        secs_per_sparse_block: 2.1e-10,
                        secs_per_byte: 7.0e-11,
                        dispatch_secs: 2.8e-6,
                        secs_per_fp_op: 1.25e-10,
                        samples: 12,
                        gcn_samples: 5,
                        rel_rmse: 0.09,
                    },
                ),
            ],
            repacks: vec![(
                "Row32->Blocked64".to_string(),
                SchemeCoeffs {
                    secs_per_word_op: 0.0,
                    secs_per_sparse_block: 0.0,
                    secs_per_byte: 9.0e-11,
                    dispatch_secs: 1.5e-6,
                    secs_per_fp_op: 0.0,
                    samples: 3,
                    gcn_samples: 0,
                    rel_rmse: 0.02,
                },
            )],
        }
    }

    #[test]
    fn json_roundtrip_preserves_id() {
        let p = sample();
        let back = CalibrationProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.id(), p.id());
        assert!(p.id().starts_with("cal3-"));
    }

    /// A calibrated dense backend (no GCN samples) must not price a
    /// BinGcn layer — its sparse-block rate is an unidentified 0 — while
    /// a sparse backend with GCN samples prices it through the fitted
    /// per-block coefficient.
    #[test]
    fn gcn_predictions_gated_on_gcn_samples() {
        use crate::nn::Scheme;
        use crate::sparse::{AdjKind, AdjSpec};
        let p = sample();
        let layer = LayerSpec::BinGcn {
            nodes: 128,
            d_in: 64,
            d_out: 64,
            adj: AdjSpec { kind: AdjKind::PowerLaw, degree: 4, seed: 7 },
            nnz_blocks: 400,
        };
        let dims = Dims { hw: 0, feat: 128 * 64 };
        assert!(p
            .layer_secs(Scheme::Fastpath, &layer, dims, 8, ResidualMode::None, false)
            .is_none());
        let got = p
            .layer_secs(Scheme::Spmm, &layer, dims, 8, ResidualMode::None, false)
            .expect("sparse scheme calibrated on GCN shapes");
        let c = p.coeffs(Scheme::Spmm).unwrap();
        let f = layer_features(&layer, dims, 8, ResidualMode::None, false);
        let want = c.predict(f);
        assert!((got - want).abs() / want < 1e-12);
        // the sparse-block term is load-bearing in the prediction
        assert!(f.sparse_block_ops * c.secs_per_sparse_block > 0.0);
        // dense layers still price normally under the dense backend
        let fc = LayerSpec::BinFc { d_in: 1024, d_out: 512 };
        assert!(p
            .layer_secs(Scheme::Fastpath, &fc, Dims { hw: 0, feat: 1024 }, 8, ResidualMode::None, false)
            .is_some());
    }

    #[test]
    fn repack_secs_uses_fitted_coefficients_or_falls_back() {
        let p = sample();
        let c = p
            .repack_coeffs(LayoutKind::Row32, LayoutKind::Blocked64)
            .expect("pair calibrated");
        let got = p
            .repack_secs(LayoutKind::Row32, LayoutKind::Blocked64, 4096)
            .unwrap();
        let want = 4096.0 * c.secs_per_byte + c.dispatch_secs;
        assert!((got - want).abs() / want < 1e-12);
        // identity is free, uncalibrated pair is None (analytic fallback)
        assert_eq!(
            p.repack_secs(LayoutKind::Fsb, LayoutKind::Fsb, 4096),
            Some(0.0)
        );
        assert!(p
            .repack_secs(LayoutKind::Blocked64, LayoutKind::Row32, 4096)
            .is_none());
    }

    #[test]
    fn scaled_by_scales_predictions_and_changes_the_id() {
        use crate::nn::Scheme;
        let p = sample();
        let q = p.scaled_by(&[("FASTPATH".to_string(), 3.0)]);
        assert_ne!(q.id(), p.id(), "converged profile must invalidate plans");
        let layer = LayerSpec::BinFc { d_in: 1024, d_out: 512 };
        let dims = Dims { hw: 0, feat: 1024 };
        let base = p
            .layer_secs(Scheme::Fastpath, &layer, dims, 8, ResidualMode::None, false)
            .unwrap();
        let scaled = q
            .layer_secs(Scheme::Fastpath, &layer, dims, 8, ResidualMode::None, false)
            .unwrap();
        assert!((scaled / base - 3.0).abs() < 1e-9, "{scaled} vs {base}");
        // unknown scheme names and degenerate ratios are ignored
        let same = p.scaled_by(&[
            ("BTC".to_string(), 5.0),
            ("FASTPATH".to_string(), f64::NAN),
        ]);
        assert_eq!(same, p);
    }

    #[test]
    fn id_changes_with_coefficients() {
        let p = sample();
        let mut q = p.clone();
        q.schemes[0].1.dispatch_secs *= 2.0;
        assert_ne!(p.id(), q.id());
        let mut r = p.clone();
        r.fingerprint.cores += 1;
        assert_ne!(p.id(), r.id());
    }

    #[test]
    fn predicts_with_analytic_constants_exactly() {
        use crate::nn::Scheme;
        let p = CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
            repacks: Vec::new(),
        };
        let layer = LayerSpec::BinFc { d_in: 1024, d_out: 512 };
        let dims = Dims { hw: 0, feat: 1024 };
        let got = p
            .layer_secs(Scheme::Fastpath, &layer, dims, 8, ResidualMode::None, false)
            .unwrap();
        let want = (8 * 512 * 16) as f64 / host::WORD_OPS_PER_SEC + host::DISPATCH_SECS;
        assert!((got - want).abs() / want < 1e-12);
        // uncalibrated scheme -> None (caller falls back to analytic)
        assert!(p
            .layer_secs(Scheme::Btc, &layer, dims, 8, ResidualMode::None, false)
            .is_none());
    }

    #[test]
    fn rejects_other_schemas_and_bad_coeffs() {
        let p = sample();
        let old = p.to_json().replace("\"schema\":3", "\"schema\":99");
        assert!(CalibrationProfile::from_json(&old).is_err());
        // a v2 (pre-sparse) document is stale too
        let v2 = p.to_json().replace("\"schema\":3", "\"schema\":2");
        assert!(CalibrationProfile::from_json(&v2).is_err());
        let neg = p.to_json().replace("8.5e-11", "-8.5e-11");
        assert!(CalibrationProfile::from_json(&neg).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_profile_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("calibration.profile.json");
        let p = sample();
        p.save(&path).unwrap();
        let back = CalibrationProfile::load(&path).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.id(), p.id());
    }
}
