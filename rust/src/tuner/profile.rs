//! `CalibrationProfile`: the schema-versioned JSON artifact a tuner run
//! emits — fitted per-backend cost-model coefficients keyed by a host
//! fingerprint.
//!
//! The profile is persisted next to the `PlanCache`
//! (`PlanCache::profile_path`) and identified by a stable content
//! digest ([`CalibrationProfile::id`]).  Every plan embeds the id of
//! the cost source it was planned under, so cached plans from a
//! different profile (or from the analytic source) are invalidated the
//! moment the active profile changes.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::engine::json::Value;
use crate::nn::cost::{host, ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};

use super::features::layer_features;
use super::fingerprint::HostFingerprint;

/// Version of the profile JSON document.  Bump whenever the layout (or
/// the meaning of a fitted coefficient) changes; `from_json` rejects
/// any other version, and because the profile id embeds the schema,
/// cached plans from an old profile schema are invalidated too.
pub const PROFILE_SCHEMA: usize = 1;

/// Fitted cost-model coefficients of one backend: the analytic host
/// model's parameterization (`tuner::features`) with measured values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeCoeffs {
    /// seconds per u64 XOR+POPC+accumulate word op (1 / word-ops-per-sec).
    pub secs_per_word_op: f64,
    /// seconds per streamed byte (1 / bytes-per-sec).
    pub secs_per_byte: f64,
    /// fixed fork/join + repack latency per layer dispatch.
    pub dispatch_secs: f64,
    /// seconds per f32 multiply-accumulate (first BWN layer).  Not fit
    /// by the microbench (the first layer is scheme-independent); the
    /// fitter seeds it from the analytic constant.
    pub secs_per_fp_op: f64,
    /// microbench measurements behind the fit.
    pub samples: usize,
    /// relative RMS error of the fit over its own measurements.
    pub rel_rmse: f64,
}

impl SchemeCoeffs {
    /// The analytic fastpath host constants expressed as coefficients —
    /// the prior a fit starts from, and a convenient test fixture.
    pub fn analytic() -> SchemeCoeffs {
        SchemeCoeffs {
            secs_per_word_op: 1.0 / host::WORD_OPS_PER_SEC,
            secs_per_byte: 1.0 / host::BYTES_PER_SEC,
            dispatch_secs: host::DISPATCH_SECS,
            secs_per_fp_op: 1.0 / host::FP_OPS_PER_SEC,
            samples: 0,
            rel_rmse: 0.0,
        }
    }

    /// Predicted seconds for a feature vector.
    pub fn predict(&self, f: super::features::Features) -> f64 {
        f.fp_ops * self.secs_per_fp_op
            + f.word_ops * self.secs_per_word_op
            + f.stream_bytes * self.secs_per_byte
            + self.dispatch_secs
    }

    /// All coefficients finite and non-negative, with a sane dispatch.
    pub fn is_sane(&self) -> bool {
        let nonneg = |x: f64| x.is_finite() && x >= 0.0;
        nonneg(self.secs_per_word_op)
            && nonneg(self.secs_per_byte)
            && nonneg(self.dispatch_secs)
            && nonneg(self.secs_per_fp_op)
            && self.dispatch_secs < 1.0
    }
}

/// A fitted per-host calibration: fingerprint + one coefficient set per
/// calibrated scheme (backends without an entry fall back to their
/// analytic cost face under `CostSource::Calibrated`).
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationProfile {
    pub fingerprint: HostFingerprint,
    /// `(scheme name, coefficients)` in registration order.
    pub schemes: Vec<(String, SchemeCoeffs)>,
}

impl CalibrationProfile {
    /// Coefficients for `scheme`, if it was calibrated.
    pub fn coeffs(&self, scheme: Scheme) -> Option<&SchemeCoeffs> {
        self.schemes
            .iter()
            .find(|(n, _)| n == scheme.name())
            .map(|(_, c)| c)
    }

    /// Fitted seconds of one layer under `scheme`; `None` when the
    /// scheme was not calibrated (caller falls back to analytic).
    pub fn layer_secs(
        &self,
        scheme: Scheme,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> Option<f64> {
        self.coeffs(scheme).map(|c| {
            c.predict(layer_features(layer, dims, batch, residual, model_has_residuals))
        })
    }

    /// Stable content digest: `cal<schema>-<fnv64 of the JSON form>`.
    /// This is the id plans embed as their `cost_profile`, so any
    /// change to the fingerprint, the coefficient values, or the
    /// profile schema invalidates cached plans.
    pub fn id(&self) -> String {
        format!("cal{PROFILE_SCHEMA}-{:016x}", fnv1a64(self.to_json().as_bytes()))
    }

    pub fn to_json(&self) -> String {
        let schemes: Vec<Value> = self
            .schemes
            .iter()
            .map(|(name, c)| {
                Value::Obj(vec![
                    ("scheme".to_string(), Value::Str(name.clone())),
                    (
                        "secs_per_word_op".to_string(),
                        Value::Num(c.secs_per_word_op),
                    ),
                    ("secs_per_byte".to_string(), Value::Num(c.secs_per_byte)),
                    ("dispatch_secs".to_string(), Value::Num(c.dispatch_secs)),
                    ("secs_per_fp_op".to_string(), Value::Num(c.secs_per_fp_op)),
                    ("samples".to_string(), Value::Num(c.samples as f64)),
                    ("rel_rmse".to_string(), Value::Num(c.rel_rmse)),
                ])
            })
            .collect();
        Value::Obj(vec![
            ("schema".to_string(), Value::Num(PROFILE_SCHEMA as f64)),
            ("fingerprint".to_string(), self.fingerprint.to_value()),
            ("schemes".to_string(), Value::Arr(schemes)),
        ])
        .to_string()
    }

    pub fn from_json(text: &str) -> Result<CalibrationProfile> {
        let v = Value::parse(text).map_err(|e| anyhow::anyhow!("profile json: {e}"))?;
        let schema = v
            .get("schema")
            .and_then(Value::as_usize)
            .context("profile field \"schema\"")?;
        if schema != PROFILE_SCHEMA {
            bail!(
                "profile schema {schema} (this build reads {PROFILE_SCHEMA}); \
                 re-run the tuner"
            );
        }
        let fingerprint = HostFingerprint::from_value(
            v.get("fingerprint").context("profile field \"fingerprint\"")?,
        )
        .map_err(|e| anyhow::anyhow!("profile {e}"))?;
        let mut schemes = Vec::new();
        for (i, sv) in v
            .get("schemes")
            .and_then(Value::as_arr)
            .context("profile field \"schemes\"")?
            .iter()
            .enumerate()
        {
            let name = sv
                .get("scheme")
                .and_then(Value::as_str)
                .with_context(|| format!("profile schemes[{i}] name"))?
                .to_string();
            let num = |key: &str| -> Result<f64> {
                sv.get(key)
                    .and_then(Value::as_f64)
                    .with_context(|| format!("profile schemes[{i}] field {key:?}"))
            };
            let coeffs = SchemeCoeffs {
                secs_per_word_op: num("secs_per_word_op")?,
                secs_per_byte: num("secs_per_byte")?,
                dispatch_secs: num("dispatch_secs")?,
                secs_per_fp_op: num("secs_per_fp_op")?,
                samples: sv
                    .get("samples")
                    .and_then(Value::as_usize)
                    .with_context(|| format!("profile schemes[{i}] samples"))?,
                rel_rmse: num("rel_rmse")?,
            };
            ensure_sane(&name, &coeffs)?;
            schemes.push((name, coeffs));
        }
        Ok(CalibrationProfile { fingerprint, schemes })
    }

    /// Persist to `path` (creating parent directories).
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
    }

    /// Load a previously saved profile.
    pub fn load(path: impl AsRef<Path>) -> Result<CalibrationProfile> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!("read calibration profile {:?}", path.as_ref())
        })?;
        CalibrationProfile::from_json(&text)
    }
}

fn ensure_sane(name: &str, c: &SchemeCoeffs) -> Result<()> {
    if !c.is_sane() {
        bail!("profile scheme {name:?}: non-finite or negative coefficients");
    }
    Ok(())
}

/// FNV-1a 64-bit — stable, dependency-free content hash for profile ids.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::backend::BackendRegistry;

    fn sample() -> CalibrationProfile {
        CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![(
                "FASTPATH".to_string(),
                SchemeCoeffs {
                    secs_per_word_op: 8.5e-11,
                    secs_per_byte: 6.0e-11,
                    dispatch_secs: 2.5e-6,
                    secs_per_fp_op: 1.25e-10,
                    samples: 9,
                    rel_rmse: 0.07,
                },
            )],
        }
    }

    #[test]
    fn json_roundtrip_preserves_id() {
        let p = sample();
        let back = CalibrationProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.id(), p.id());
        assert!(p.id().starts_with("cal1-"));
    }

    #[test]
    fn id_changes_with_coefficients() {
        let p = sample();
        let mut q = p.clone();
        q.schemes[0].1.dispatch_secs *= 2.0;
        assert_ne!(p.id(), q.id());
        let mut r = p.clone();
        r.fingerprint.cores += 1;
        assert_ne!(p.id(), r.id());
    }

    #[test]
    fn predicts_with_analytic_constants_exactly() {
        use crate::nn::Scheme;
        let p = CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![("FASTPATH".to_string(), SchemeCoeffs::analytic())],
        };
        let layer = LayerSpec::BinFc { d_in: 1024, d_out: 512 };
        let dims = Dims { hw: 0, feat: 1024 };
        let got = p
            .layer_secs(Scheme::Fastpath, &layer, dims, 8, ResidualMode::None, false)
            .unwrap();
        let want = (8 * 512 * 16) as f64 / host::WORD_OPS_PER_SEC + host::DISPATCH_SECS;
        assert!((got - want).abs() / want < 1e-12);
        // uncalibrated scheme -> None (caller falls back to analytic)
        assert!(p
            .layer_secs(Scheme::Btc, &layer, dims, 8, ResidualMode::None, false)
            .is_none());
    }

    #[test]
    fn rejects_other_schemas_and_bad_coeffs() {
        let p = sample();
        let old = p.to_json().replace("\"schema\":1", "\"schema\":99");
        assert!(CalibrationProfile::from_json(&old).is_err());
        let neg = p.to_json().replace("8.5e-11", "-8.5e-11");
        assert!(CalibrationProfile::from_json(&neg).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_profile_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("calibration.profile.json");
        let p = sample();
        p.save(&path).unwrap();
        let back = CalibrationProfile::load(&path).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.id(), p.id());
    }
}
