//! `CostSource`: where the planner's per-layer costs come from.
//!
//! Before the tuner, every planner query went straight to the
//! backends' `layer_secs` faces (analytic host constants for the
//! fastpath, simulated Turing traces for the GPU rows).  `CostSource`
//! makes that pluggable:
//!
//! * [`CostSource::Analytic`] — the backends' own cost faces,
//!   unchanged (the default; plans carry the id `"analytic"`).
//! * [`CostSource::Calibrated`] — fitted per-host coefficients from a
//!   [`CalibrationProfile`] for every scheme the profile covers;
//!   uncovered schemes fall back to their analytic face.  An analytic
//!   cost of infinity (a backend rejecting a shape, e.g. the fastpath
//!   tap limit) stays infinite: calibration must never rank a backend
//!   onto a shape it cannot execute.
//! * [`CostSource::Live`] — the calibrated prior scaled per scheme by
//!   the [`LiveCosts`] EWMA of measured-over-predicted ratios the
//!   executor records, so a serving process converges on true host
//!   costs and can re-plan on drift.
//!
//! Every source has a stable [`CostSource::profile_id`]; plans embed
//! it, and the plan cache treats an id mismatch as a miss.

use std::sync::Arc;

use crate::kernels::backend::KernelBackend;
use crate::layout::LayoutKind;
use crate::nn::cost::ResidualMode;
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::Engine;

use super::live::LiveCosts;
use super::profile::CalibrationProfile;

/// The id `CostSource::Analytic` plans carry (and the id
/// `PlanCache::get` validates against).
pub const ANALYTIC_PROFILE_ID: &str = "analytic";

/// Where planner cost queries are answered from.
#[derive(Clone, Debug)]
pub enum CostSource {
    /// The backends' own cost faces (analytic host models / simulated
    /// GPU traces) — the default.
    Analytic,
    /// Fitted per-host coefficients; schemes without a profile entry
    /// fall back to their analytic face.
    Calibrated(Arc<CalibrationProfile>),
    /// The calibrated `prior` scaled by the executor-fed `live` EWMA
    /// ratio per scheme.
    Live { prior: Arc<CalibrationProfile>, live: Arc<LiveCosts> },
}

impl CostSource {
    /// Seconds of one layer under `backend`, answered by this source.
    #[allow(clippy::too_many_arguments)]
    pub fn layer_secs(
        &self,
        backend: &dyn KernelBackend,
        engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        let analytic =
            backend.layer_secs(engine, layer, dims, batch, residual, model_has_residuals);
        match self {
            CostSource::Analytic => analytic,
            // an infinite analytic cost marks a shape the backend
            // cannot execute — calibration never overrides that
            _ if !analytic.is_finite() => analytic,
            CostSource::Calibrated(p) => p
                .layer_secs(backend.scheme(), layer, dims, batch, residual, model_has_residuals)
                .unwrap_or(analytic),
            CostSource::Live { prior, live } => {
                let base = prior
                    .layer_secs(
                        backend.scheme(),
                        layer,
                        dims,
                        batch,
                        residual,
                        model_has_residuals,
                    )
                    .unwrap_or(analytic);
                base * live.ratio(backend.scheme())
            }
        }
    }

    /// The *ratio-free* prediction of this source: identical to
    /// [`CostSource::layer_secs`] for `Analytic`/`Calibrated`, and the
    /// calibrated prior (without the live EWMA factor) for `Live`.
    ///
    /// Live feedback must be recorded against THIS value, never the
    /// blended one: recording `measured / (prior * ratio)` into the
    /// same EWMA that holds `ratio` has the fixed point
    /// `ratio = sqrt(true_drift)`, which under-corrects forever —
    /// recording against the constant prior converges the EWMA on the
    /// true measured/prior ratio.
    #[allow(clippy::too_many_arguments)]
    pub fn prior_layer_secs(
        &self,
        backend: &dyn KernelBackend,
        engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        match self {
            CostSource::Live { prior, .. } => {
                CostSource::Calibrated(Arc::clone(prior)).layer_secs(
                    backend,
                    engine,
                    layer,
                    dims,
                    batch,
                    residual,
                    model_has_residuals,
                )
            }
            _ => self.layer_secs(
                backend,
                engine,
                layer,
                dims,
                batch,
                residual,
                model_has_residuals,
            ),
        }
    }

    /// Seconds to convert `bytes` of total layout-edge traffic (source
    /// image + destination image) from `src` to `dst`, answered by this
    /// source: the analytic repack model for `Analytic`, the profile's
    /// fitted per-pair bandwidth for `Calibrated`/`Live` (falling back
    /// to analytic for uncalibrated pairs).  This is what the planner's
    /// (scheme, layout) DP charges on every edge whose layouts
    /// disagree — and the discount it grants for native-layout
    /// consumption.
    pub fn repack_secs(&self, src: LayoutKind, dst: LayoutKind, bytes: usize) -> f64 {
        if src == dst {
            return 0.0;
        }
        let analytic = crate::layout::cost::analytic_repack_secs(src, dst, bytes);
        match self {
            CostSource::Analytic => analytic,
            CostSource::Calibrated(p) => {
                p.repack_secs(src, dst, bytes).unwrap_or(analytic)
            }
            CostSource::Live { prior, .. } => {
                prior.repack_secs(src, dst, bytes).unwrap_or(analytic)
            }
        }
    }

    /// The stable identity plans embed as `cost_profile`.
    pub fn profile_id(&self) -> String {
        match self {
            CostSource::Analytic => ANALYTIC_PROFILE_ID.to_string(),
            CostSource::Calibrated(p) => p.id(),
            CostSource::Live { prior, .. } => format!("live:{}", prior.id()),
        }
    }

    /// The live feedback sink, when this source has one.
    pub fn live_handle(&self) -> Option<Arc<LiveCosts>> {
        match self {
            CostSource::Live { live, .. } => Some(Arc::clone(live)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::backend::BackendRegistry;
    use crate::nn::Scheme;
    use crate::sim::RTX2080TI;
    use crate::tuner::fingerprint::HostFingerprint;
    use crate::tuner::profile::SchemeCoeffs;

    fn profile_with(coeffs: SchemeCoeffs) -> Arc<CalibrationProfile> {
        Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: vec![("FASTPATH".to_string(), coeffs)],
            repacks: Vec::new(),
        })
    }

    fn query(src: &CostSource, scheme: Scheme, layer: &LayerSpec, dims: Dims) -> f64 {
        let engine = Engine::new(&RTX2080TI);
        let b = BackendRegistry::global().get(scheme).unwrap();
        src.layer_secs(b, &engine, layer, dims, 8, ResidualMode::None, false)
    }

    #[test]
    fn analytic_constants_make_calibrated_equal_analytic() {
        let layer = LayerSpec::BinFc { d_in: 1024, d_out: 512 };
        let dims = Dims { hw: 0, feat: 1024 };
        let cal = CostSource::Calibrated(profile_with(SchemeCoeffs::analytic()));
        let a = query(&CostSource::Analytic, Scheme::Fastpath, &layer, dims);
        let c = query(&cal, Scheme::Fastpath, &layer, dims);
        assert!((a - c).abs() / a < 1e-12, "analytic {a} vs calibrated {c}");
        // GPU schemes are not in the profile -> analytic fallback
        let a_btc = query(&CostSource::Analytic, Scheme::Btc, &layer, dims);
        let c_btc = query(&cal, Scheme::Btc, &layer, dims);
        assert_eq!(a_btc, c_btc);
    }

    #[test]
    fn calibration_never_overrides_unsupported_shapes() {
        // a 7x7 filter exceeds the fastpath tap limit: analytic cost is
        // infinite and must stay infinite under any profile
        let layer = LayerSpec::BinConv {
            c: 64,
            o: 64,
            k: 7,
            stride: 1,
            pad: 3,
            pool: false,
            residual: false,
        };
        let dims = Dims { hw: 14, feat: 64 };
        let mut cheap = SchemeCoeffs::analytic();
        cheap.secs_per_word_op = 1e-15;
        for src in [
            CostSource::Calibrated(profile_with(cheap)),
            CostSource::Live {
                prior: profile_with(cheap),
                live: Arc::new(LiveCosts::new()),
            },
        ] {
            assert!(query(&src, Scheme::Fastpath, &layer, dims).is_infinite());
        }
    }

    #[test]
    fn live_scales_the_prior_by_the_ewma_ratio() {
        let layer = LayerSpec::BinFc { d_in: 512, d_out: 512 };
        let dims = Dims { hw: 0, feat: 512 };
        let prior = profile_with(SchemeCoeffs::analytic());
        let live = Arc::new(LiveCosts::new());
        let src = CostSource::Live { prior: Arc::clone(&prior), live: Arc::clone(&live) };
        let base = query(&src, Scheme::Fastpath, &layer, dims);
        for _ in 0..50 {
            live.record(Scheme::Fastpath, 1e-4, 3e-4);
        }
        let scaled = query(&src, Scheme::Fastpath, &layer, dims);
        assert!((scaled / base - 3.0).abs() < 1e-6, "{scaled} vs {base}");
    }

    #[test]
    fn repack_secs_prefers_fitted_pairs_and_falls_back_to_analytic() {
        let pair = (LayoutKind::Row32, LayoutKind::Blocked64);
        let analytic = CostSource::Analytic.repack_secs(pair.0, pair.1, 4096);
        assert_eq!(
            analytic,
            crate::layout::cost::analytic_repack_secs(pair.0, pair.1, 4096)
        );
        // identity edges are free under every source
        assert_eq!(CostSource::Analytic.repack_secs(pair.0, pair.0, 4096), 0.0);
        // a profile with a fitted pair overrides; others fall back
        let mut fitted = SchemeCoeffs::analytic();
        fitted.secs_per_word_op = 0.0;
        fitted.secs_per_byte = 1e-12;
        fitted.dispatch_secs = 1e-7;
        fitted.secs_per_fp_op = 0.0;
        let p = Arc::new(CalibrationProfile {
            fingerprint: HostFingerprint::detect(BackendRegistry::global()),
            schemes: Vec::new(),
            repacks: vec![(crate::tuner::repack_key(pair.0, pair.1), fitted)],
        });
        let cal = CostSource::Calibrated(Arc::clone(&p));
        let got = cal.repack_secs(pair.0, pair.1, 4096);
        assert!((got - (4096.0 * 1e-12 + 1e-7)).abs() < 1e-15, "{got}");
        let fallback = cal.repack_secs(LayoutKind::Blocked64, LayoutKind::Row32, 4096);
        assert_eq!(
            fallback,
            crate::layout::cost::analytic_repack_secs(
                LayoutKind::Blocked64,
                LayoutKind::Row32,
                4096
            )
        );
        // Live prices edges from its prior
        let live = CostSource::Live { prior: p, live: Arc::new(LiveCosts::new()) };
        assert_eq!(live.repack_secs(pair.0, pair.1, 4096), got);
    }

    #[test]
    fn profile_ids_distinguish_sources() {
        let p = profile_with(SchemeCoeffs::analytic());
        let analytic = CostSource::Analytic.profile_id();
        let cal = CostSource::Calibrated(Arc::clone(&p)).profile_id();
        let live = CostSource::Live { prior: p, live: Arc::new(LiveCosts::new()) }
            .profile_id();
        assert_eq!(analytic, ANALYTIC_PROFILE_ID);
        assert_ne!(analytic, cal);
        assert_ne!(cal, live);
        assert!(live.starts_with("live:"));
    }
}
