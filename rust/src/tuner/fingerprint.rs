//! Host fingerprinting for calibration profiles.
//!
//! A fitted cost profile is only meaningful on hosts that look like the
//! one it was measured on: PhoneBit-style per-device tuning exists
//! precisely because analytic models mispredict across hosts.  The
//! fingerprint captures the coarse host shape (worker parallelism,
//! cache line) plus the backend set the profile was fitted over, so a
//! profile carried to a different machine — or loaded after a new
//! backend registered — is detectably stale instead of silently wrong.

use crate::engine::json::Value;
use crate::kernels::backend::BackendRegistry;
use crate::util::threadpool::default_threads;

/// The coarse host + registry shape a profile was calibrated on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostFingerprint {
    /// scoped-worker parallelism the microbenches ran with
    /// (`util::threadpool::default_threads`, not raw core count — it is
    /// the parallelism the executor will actually use).
    pub cores: usize,
    /// cache line size in bytes (sysfs when readable, 64 otherwise).
    pub cache_line: usize,
    /// registered scheme names at calibration time, in registration
    /// order (same staleness role as the plan cache's scheme set).
    pub schemes: Vec<String>,
}

impl HostFingerprint {
    /// Fingerprint of this host against `registry`, at the default
    /// scoped-worker parallelism (what a serving executor uses).
    pub fn detect(registry: &BackendRegistry) -> HostFingerprint {
        HostFingerprint::detect_with_cores(registry, default_threads())
    }

    /// Fingerprint with an explicit worker count — pass the
    /// `MicrobenchConfig::threads` the measurements actually ran with.
    /// A profile fitted at a non-default parallelism then (correctly)
    /// fails [`HostFingerprint::matches_host`] on a host that would
    /// serve with a different worker count: its coefficients describe
    /// a different machine shape.
    pub fn detect_with_cores(registry: &BackendRegistry, cores: usize) -> HostFingerprint {
        HostFingerprint {
            cores,
            cache_line: detect_cache_line(),
            schemes: registry.names().iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn to_value(&self) -> Value {
        Value::Obj(vec![
            ("cores".to_string(), Value::Num(self.cores as f64)),
            ("cache_line".to_string(), Value::Num(self.cache_line as f64)),
            (
                "schemes".to_string(),
                Value::Arr(self.schemes.iter().map(|s| Value::Str(s.clone())).collect()),
            ),
        ])
    }

    pub fn from_value(v: &Value) -> Result<HostFingerprint, String> {
        let cores = v
            .get("cores")
            .and_then(Value::as_usize)
            .ok_or("fingerprint field \"cores\"")?;
        let cache_line = v
            .get("cache_line")
            .and_then(Value::as_usize)
            .ok_or("fingerprint field \"cache_line\"")?;
        let mut schemes = Vec::new();
        for (i, s) in v
            .get("schemes")
            .and_then(Value::as_arr)
            .ok_or("fingerprint field \"schemes\"")?
            .iter()
            .enumerate()
        {
            schemes.push(
                s.as_str()
                    .ok_or_else(|| format!("fingerprint schemes[{i}]"))?
                    .to_string(),
            );
        }
        Ok(HostFingerprint { cores, cache_line, schemes })
    }

    /// Whether a profile with this fingerprint is usable on the current
    /// host serving `registry`.
    pub fn matches_host(&self, registry: &BackendRegistry) -> bool {
        *self == HostFingerprint::detect(registry)
    }
}

/// Cache line size: sysfs on Linux, 64 bytes otherwise (every x86-64
/// and almost every aarch64 serving host).
fn detect_cache_line() -> usize {
    std::fs::read_to_string(
        "/sys/devices/system/cpu/cpu0/cache/index0/coherency_line_size",
    )
    .ok()
    .and_then(|s| s.trim().parse::<usize>().ok())
    .filter(|&n| n > 0)
    .unwrap_or(64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_through_json_value() {
        let fp = HostFingerprint::detect(BackendRegistry::global());
        assert!(fp.cores >= 1);
        assert!(fp.cache_line >= 16);
        assert_eq!(fp.schemes.len(), BackendRegistry::global().len());
        let back = HostFingerprint::from_value(&fp.to_value()).unwrap();
        assert_eq!(back, fp);
    }

    #[test]
    fn matches_only_the_same_registry_shape() {
        let fp = HostFingerprint::detect(BackendRegistry::global());
        assert!(fp.matches_host(BackendRegistry::global()));
        let empty = BackendRegistry::empty();
        assert!(!fp.matches_host(&empty));
    }

    #[test]
    fn non_default_worker_count_does_not_match_the_serving_host() {
        // a profile measured at a different parallelism than the host
        // serves with must be detectably stale, not silently valid
        let reg = BackendRegistry::global();
        let odd = default_threads() + 1;
        let fp = HostFingerprint::detect_with_cores(reg, odd);
        assert_eq!(fp.cores, odd);
        assert!(!fp.matches_host(reg));
    }
}
