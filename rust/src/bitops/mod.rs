//! Bit-level substrate: binarization, packing, the FSB data format, and
//! packed bit matrices/tensors.
//!
//! Conventions (shared with python/compile/kernels/ref.py):
//! * binary value +1 <-> bit 1, -1 <-> bit 0 (Eq 1 of the paper);
//! * packing is along the innermost logical axis, LSB-first: bit `j` of
//!   word `w` holds element `w*32 + j`;
//! * the +/-1 dot product over packed operands is Eq 2:
//!   `v = n - 2*popc(a XOR b)`.

pub mod bitmatrix;
pub mod bittensor;
pub mod fsb;
pub mod pack;
pub mod pack64;
pub mod sparse;

pub use bitmatrix::{BitMatrix, Layout};
pub use bittensor::{BitTensor4, TensorLayout};
pub use fsb::FsbMatrix;
pub use pack64::BitMatrix64;
pub use sparse::SparseBitMatrix;
