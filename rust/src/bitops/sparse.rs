//! CSR-of-bit-lines sparse bit matrices — the BitGNN adjacency format.
//!
//! A [`SparseBitMatrix`] stores a `rows x cols` 0/1 matrix as CSR over
//! 64-bit *column blocks*: block `b` of a row covers logical columns
//! `[64*b, 64*b + 64)` and is materialized as one packed u64 bit-line
//! (LSB-first, same bit order as [`BitMatrix64`]).  Only nonzero blocks
//! are stored: `row_ptr[r]..row_ptr[r+1]` indexes parallel arrays
//! `block_cols` (strictly increasing block indices within a row) and
//! `bits` (the u64 line per stored block).  All-zero blocks are always
//! omitted, so equal logical matrices have equal representations and
//! `PartialEq` derives.
//!
//! Unlike the +/-1 dense formats, the sparse matrix is a *mask*: bit 1
//! means "edge present", bit 0 means absent — the binary-GNN
//! aggregation semantics (BitGNN, arXiv 2305.02522), where
//! `out[i][f] = sum over neighbours j of h[j][f]` reduces to
//! `2*popc(adj_row_i AND h_col_f) - degree(i)` for +/-1 features `h`.
//! The same storage doubles as a sparse +/-1 Eq-2 operand by treating
//! absent blocks as all -1 (bit 0) — see `sparse::spmm`.

use super::bitmatrix::{BitMatrix, Layout};
use super::pack64::{self, BitMatrix64};

/// Bits per stored column block (one u64 bit-line).
pub const BLOCK_BITS: usize = 64;

/// CSR-of-bit-lines sparse bit matrix.  See the module docs for the
/// representation invariants (sorted block columns, no zero blocks).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SparseBitMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `rows + 1` offsets into `block_cols`/`bits`.
    pub row_ptr: Vec<u32>,
    /// Column-block index of each stored block (block `b` covers
    /// columns `64*b..64*b+64`), strictly increasing within a row.
    pub block_cols: Vec<u32>,
    /// One packed u64 bit-line per stored block; never zero.
    pub bits: Vec<u64>,
}

impl SparseBitMatrix {
    /// An all-zero (edgeless) matrix.
    pub fn empty(rows: usize, cols: usize) -> SparseBitMatrix {
        SparseBitMatrix {
            rows,
            cols,
            row_ptr: vec![0; rows + 1],
            block_cols: Vec::new(),
            bits: Vec::new(),
        }
    }

    /// Column blocks per row in the equivalent dense representation.
    #[inline]
    pub fn blocks_per_row(&self) -> usize {
        self.cols.div_ceil(BLOCK_BITS)
    }

    /// Number of stored (nonzero) 64-bit blocks.
    #[inline]
    pub fn nnz_blocks(&self) -> usize {
        self.bits.len()
    }

    /// Number of set bits (edges).
    pub fn nnz_bits(&self) -> usize {
        self.bits.iter().map(|b| b.count_ones() as usize).sum()
    }

    /// Stored blocks / dense blocks — the density the planner's
    /// sparse-vs-dense crossover is parameterized on.
    pub fn block_density(&self) -> f64 {
        let dense = self.rows * self.blocks_per_row();
        if dense == 0 {
            return 0.0;
        }
        self.nnz_blocks() as f64 / dense as f64
    }

    /// The stored blocks of row `r` as parallel (block index, bit-line)
    /// slices.
    #[inline]
    pub fn row_blocks(&self, r: usize) -> (&[u32], &[u64]) {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        (&self.block_cols[lo..hi], &self.bits[lo..hi])
    }

    /// Set bits (out-degree) of row `r`.
    #[inline]
    pub fn row_degree(&self, r: usize) -> u32 {
        let (_, bits) = self.row_blocks(r);
        bits.iter().map(|b| b.count_ones()).sum()
    }

    /// Logical entry (r, c) — true iff the bit is stored and set.
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        let blk = (c / BLOCK_BITS) as u32;
        let (cols, bits) = self.row_blocks(r);
        match cols.binary_search(&blk) {
            Ok(i) => (bits[i] >> (c % BLOCK_BITS)) & 1 == 1,
            Err(_) => false,
        }
    }

    /// Build from explicit (row, col) edges (duplicates allowed).
    pub fn from_edges(
        rows: usize,
        cols: usize,
        edges: impl IntoIterator<Item = (usize, usize)>,
    ) -> SparseBitMatrix {
        let bpr = cols.div_ceil(BLOCK_BITS);
        // dense block grid, sparsified below — adjacency construction is
        // a one-time setup cost, not a serving hot path
        let mut grid = vec![0u64; rows * bpr];
        for (r, c) in edges {
            assert!(r < rows && c < cols, "edge ({r},{c}) out of {rows}x{cols}");
            grid[r * bpr + c / BLOCK_BITS] |= 1u64 << (c % BLOCK_BITS);
        }
        Self::from_block_grid(rows, cols, &grid)
    }

    /// Exact conversion from a row-major dense `BitMatrix`.
    pub fn from_bitmatrix(m: &BitMatrix) -> SparseBitMatrix {
        assert_eq!(m.layout, Layout::RowMajor, "sparse conversion is row-major");
        let bpr = m.cols.div_ceil(BLOCK_BITS);
        let mut grid = vec![0u64; m.rows * bpr];
        for r in 0..m.rows {
            pack64::repack64_into(m.line(r), &mut grid[r * bpr..(r + 1) * bpr]);
        }
        Self::from_block_grid(m.rows, m.cols, &grid)
    }

    /// Exact conversion from a row-major `BitMatrix64` (already u64
    /// lines: block `b` of row `r` IS word `b` of line `r`).
    pub fn from_bitmatrix64(m: &BitMatrix64) -> SparseBitMatrix {
        assert_eq!(m.layout, Layout::RowMajor, "sparse conversion is row-major");
        let bpr = m.cols.div_ceil(BLOCK_BITS);
        assert_eq!(m.words_per_line, bpr);
        Self::from_block_grid(m.rows, m.cols, &m.data)
    }

    fn from_block_grid(rows: usize, cols: usize, grid: &[u64]) -> SparseBitMatrix {
        let bpr = cols.div_ceil(BLOCK_BITS);
        debug_assert_eq!(grid.len(), rows * bpr);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut block_cols = Vec::new();
        let mut bits = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for b in 0..bpr {
                let line = grid[r * bpr + b];
                if line != 0 {
                    block_cols.push(b as u32);
                    bits.push(line);
                }
            }
            row_ptr.push(bits.len() as u32);
        }
        SparseBitMatrix { rows, cols, row_ptr, block_cols, bits }
    }

    /// Inverse of [`from_bitmatrix`] — exact round trip at any width.
    pub fn to_bitmatrix(&self) -> BitMatrix {
        let mut m = BitMatrix::zeros(self.rows, self.cols, Layout::RowMajor);
        let wpl = m.words_per_line;
        for r in 0..self.rows {
            let (cols, bits) = self.row_blocks(r);
            for (&b, &line) in cols.iter().zip(bits) {
                let w0 = 2 * b as usize;
                let dst = m.line_mut(r);
                dst[w0] = line as u32;
                if w0 + 1 < wpl {
                    dst[w0 + 1] = (line >> 32) as u32;
                } else {
                    debug_assert_eq!(line >> 32, 0, "pad half set in tail block");
                }
            }
        }
        m
    }

    /// Inverse of [`from_bitmatrix64`].
    pub fn to_bitmatrix64(&self) -> BitMatrix64 {
        BitMatrix64::from_bitmatrix(&self.to_bitmatrix())
    }

    /// Bytes of CSR storage (row pointers + block indices + bit-lines).
    pub fn storage_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.block_cols.len() * 4 + self.bits.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;
    use crate::util::Rng;

    #[test]
    fn empty_matrix_has_no_blocks_and_round_trips() {
        let s = SparseBitMatrix::empty(7, 130);
        assert_eq!(s.nnz_blocks(), 0);
        assert_eq!(s.nnz_bits(), 0);
        assert_eq!(s.block_density(), 0.0);
        assert_eq!(SparseBitMatrix::from_bitmatrix(&s.to_bitmatrix()), s);
    }

    #[test]
    fn dense_roundtrip_at_odd_widths() {
        run_cases(701, 80, |rng| {
            let rows = 1 + rng.gen_range(30);
            let cols = 1 + rng.gen_range(300);
            let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
            let s = SparseBitMatrix::from_bitmatrix(&m);
            assert_eq!(s.to_bitmatrix(), m, "{rows}x{cols}");
            // u64 route agrees with the u32 route
            let m64 = BitMatrix64::from_bitmatrix(&m);
            assert_eq!(SparseBitMatrix::from_bitmatrix64(&m64), s);
            assert_eq!(s.to_bitmatrix64(), m64);
        });
    }

    #[test]
    fn stored_blocks_are_sorted_nonzero_and_canonical() {
        run_cases(702, 40, |rng| {
            let rows = 1 + rng.gen_range(20);
            let cols = 1 + rng.gen_range(400);
            // sparse pattern: a few random edges
            let n_edges = rng.gen_range(3 * rows);
            let edges: Vec<(usize, usize)> = (0..n_edges)
                .map(|_| (rng.gen_range(rows), rng.gen_range(cols)))
                .collect();
            let s = SparseBitMatrix::from_edges(rows, cols, edges.iter().copied());
            assert!(s.bits.iter().all(|&b| b != 0), "zero block stored");
            for r in 0..rows {
                let (bc, _) = s.row_blocks(r);
                assert!(bc.windows(2).all(|w| w[0] < w[1]), "row {r} unsorted");
            }
            for &(r, c) in &edges {
                assert!(s.get(r, c), "edge ({r},{c}) lost");
            }
            // canonical: dense round-trip reproduces the same CSR
            assert_eq!(SparseBitMatrix::from_bitmatrix(&s.to_bitmatrix()), s);
        });
    }

    #[test]
    fn degrees_and_density_match_dense_counts() {
        run_cases(703, 30, |rng| {
            let rows = 1 + rng.gen_range(20);
            let cols = 1 + rng.gen_range(200);
            let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
            let s = SparseBitMatrix::from_bitmatrix(&m);
            let mut total = 0usize;
            for r in 0..rows {
                let dense: u32 =
                    m.line(r).iter().map(|w| w.count_ones()).sum();
                assert_eq!(s.row_degree(r), dense, "row {r}");
                total += dense as usize;
            }
            assert_eq!(s.nnz_bits(), total);
            assert!(s.block_density() <= 1.0);
            // random dense data: essentially every block present
            assert_eq!(
                s.nnz_blocks() <= rows * s.blocks_per_row(),
                true
            );
        });
    }

    #[test]
    fn get_matches_dense_get() {
        run_cases(704, 30, |rng| {
            let rows = 1 + rng.gen_range(15);
            let cols = 1 + rng.gen_range(250);
            let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
            let s = SparseBitMatrix::from_bitmatrix(&m);
            for _ in 0..40 {
                let r = rng.gen_range(rows);
                let c = rng.gen_range(cols);
                assert_eq!(s.get(r, c), m.get(r, c), "({r},{c})");
            }
        });
    }

    #[test]
    fn full_rows_store_every_block() {
        let mut rng = Rng::new(705);
        let mut m = BitMatrix::random(4, 130, Layout::RowMajor, &mut rng);
        // force row 2 all-ones
        for c in 0..130 {
            m.set(2, c, true);
        }
        let s = SparseBitMatrix::from_bitmatrix(&m);
        let (bc, bits) = s.row_blocks(2);
        assert_eq!(bc, &[0, 1, 2]);
        assert_eq!(bits[0], u64::MAX);
        assert_eq!(bits[1], u64::MAX);
        assert_eq!(bits[2], (1u64 << 2) - 1, "tail block masks to 130 bits");
        assert_eq!(s.to_bitmatrix(), m);
    }
}
