//! Packed bit matrices in the "general" (sequential) format the paper
//! contrasts with FSB: row-major matrices pack each row into u32 words,
//! column-major matrices pack each column (this is what the Turing BMMA
//! expects for operand B).

use super::pack;
use crate::util::Rng;

/// Storage order of the packed dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// rows packed along columns (operand A)
    RowMajor,
    /// columns packed along rows (operand B)
    ColMajor,
}

/// A 2D +/-1 matrix stored as packed bits.
///
/// `rows x cols` logical +/-1 entries; the packed ("minor") dimension is
/// `cols` for RowMajor and `rows` for ColMajor.  The minor dimension is
/// padded up to a whole number of words; pad bits are 0 (-1) and are
/// excluded from all dot products by construction (callers always pass
/// the logical length `n` to Eq 2).
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    pub layout: Layout,
    /// words per packed line (row for RowMajor, column for ColMajor)
    pub words_per_line: usize,
    pub data: Vec<u32>,
}

impl BitMatrix {
    /// All -1 matrix.
    pub fn zeros(rows: usize, cols: usize, layout: Layout) -> BitMatrix {
        let minor = match layout {
            Layout::RowMajor => cols,
            Layout::ColMajor => rows,
        };
        let major = match layout {
            Layout::RowMajor => rows,
            Layout::ColMajor => cols,
        };
        let wpl = minor.div_ceil(32);
        BitMatrix { rows, cols, layout, words_per_line: wpl, data: vec![0; wpl * major] }
    }

    /// Binarize a row-major f32 buffer (Eq 1) into the requested layout.
    pub fn from_f32(rows: usize, cols: usize, xs: &[f32], layout: Layout) -> BitMatrix {
        assert_eq!(xs.len(), rows * cols);
        let mut m = BitMatrix::zeros(rows, cols, layout);
        for r in 0..rows {
            for c in 0..cols {
                if xs[r * cols + c] >= 0.0 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    /// Random +/-1 matrix.
    pub fn random(rows: usize, cols: usize, layout: Layout, rng: &mut Rng) -> BitMatrix {
        let mut m = BitMatrix::zeros(rows, cols, layout);
        // fill whole words then mask the pad bits back to zero
        for w in m.data.iter_mut() {
            *w = rng.next_u32();
        }
        m.mask_padding();
        m
    }

    /// Number of lines (major dimension extent).
    pub fn lines(&self) -> usize {
        match self.layout {
            Layout::RowMajor => self.rows,
            Layout::ColMajor => self.cols,
        }
    }

    /// Logical length of one packed line in bits.
    pub fn line_bits(&self) -> usize {
        match self.layout {
            Layout::RowMajor => self.cols,
            Layout::ColMajor => self.rows,
        }
    }

    /// Packed words of line `i`.
    #[inline]
    pub fn line(&self, i: usize) -> &[u32] {
        let w = self.words_per_line;
        &self.data[i * w..(i + 1) * w]
    }

    #[inline]
    pub fn line_mut(&mut self, i: usize) -> &mut [u32] {
        let w = self.words_per_line;
        &mut self.data[i * w..(i + 1) * w]
    }

    #[inline]
    fn pos(&self, r: usize, c: usize) -> (usize, usize) {
        match self.layout {
            Layout::RowMajor => (r, c),
            Layout::ColMajor => (c, r),
        }
    }

    /// Logical +/-1 entry as bool (true == +1).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        let (line, off) = self.pos(r, c);
        pack::get_bit(self.line(line), off)
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let (line, off) = self.pos(r, c);
        pack::set_bit(self.line_mut(line), off, v)
    }

    /// Force pad bits (beyond the logical minor extent) to 0.
    pub fn mask_padding(&mut self) {
        let bits = self.line_bits();
        let rem = bits % 32;
        if rem == 0 {
            return;
        }
        let mask = (1u32 << rem) - 1;
        let wpl = self.words_per_line;
        let lines = self.lines();
        for l in 0..lines {
            self.data[l * wpl + wpl - 1] &= mask;
        }
    }

    /// Expand to a row-major +/-1 float buffer.
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] = if self.get(r, c) { 1.0 } else { -1.0 };
            }
        }
        out
    }

    /// Transposed copy with flipped layout — a free reinterpretation for
    /// packed data (rows of A^T == columns of A).
    pub fn transpose_reinterpret(&self) -> BitMatrix {
        BitMatrix {
            rows: self.cols,
            cols: self.rows,
            layout: match self.layout {
                Layout::RowMajor => Layout::ColMajor,
                Layout::ColMajor => Layout::RowMajor,
            },
            words_per_line: self.words_per_line,
            data: self.data.clone(),
        }
    }

    /// Convert to the other layout (an actual bit transpose of storage).
    pub fn to_layout(&self, layout: Layout) -> BitMatrix {
        if layout == self.layout {
            return self.clone();
        }
        let mut out = BitMatrix::zeros(self.rows, self.cols, layout);
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.get(r, c) {
                    out.set(r, c, true);
                }
            }
        }
        out
    }

    /// Bytes of packed storage.
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    #[test]
    fn get_set_roundtrip() {
        run_cases(21, 60, |rng| {
            let rows = 1 + rng.gen_range(40);
            let cols = 1 + rng.gen_range(90);
            let layout = if rng.next_bool() { Layout::RowMajor } else { Layout::ColMajor };
            let mut m = BitMatrix::zeros(rows, cols, layout);
            let r = rng.gen_range(rows);
            let c = rng.gen_range(cols);
            m.set(r, c, true);
            assert!(m.get(r, c));
            assert_eq!(m.to_f32()[r * cols + c], 1.0);
        });
    }

    #[test]
    fn from_to_f32_roundtrip() {
        run_cases(22, 40, |rng| {
            let rows = 1 + rng.gen_range(20);
            let cols = 1 + rng.gen_range(70);
            let xs = rng.pm1_vec(rows * cols);
            for layout in [Layout::RowMajor, Layout::ColMajor] {
                let m = BitMatrix::from_f32(rows, cols, &xs, layout);
                assert_eq!(m.to_f32(), xs);
            }
        });
    }

    #[test]
    fn layout_conversion_preserves_entries() {
        run_cases(23, 40, |rng| {
            let m = BitMatrix::random(
                1 + rng.gen_range(30),
                1 + rng.gen_range(30),
                Layout::RowMajor,
                rng,
            );
            let c = m.to_layout(Layout::ColMajor);
            assert_eq!(m.to_f32(), c.to_f32());
            assert_eq!(c.to_layout(Layout::RowMajor), m);
        });
    }

    #[test]
    fn transpose_reinterpret_is_transpose() {
        run_cases(24, 40, |rng| {
            let m = BitMatrix::random(
                1 + rng.gen_range(20),
                1 + rng.gen_range(20),
                Layout::RowMajor,
                rng,
            );
            let t = m.transpose_reinterpret();
            for r in 0..m.rows {
                for c in 0..m.cols {
                    assert_eq!(m.get(r, c), t.get(c, r));
                }
            }
        });
    }

    #[test]
    fn padding_masked() {
        let mut rng = Rng::new(4);
        let m = BitMatrix::random(8, 33, Layout::RowMajor, &mut rng);
        // bits 33..64 of each row must be zero
        for r in 0..8 {
            assert_eq!(m.line(r)[1] >> 1, 0, "row {r} pad bits set");
        }
    }
}
