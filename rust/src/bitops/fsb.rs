//! FSB — the paper's Fixed-Stride-Bit format (§5.1, Fig 14).
//!
//! Instead of storing bits sequentially with the matrix width as the WMMA
//! stride `ldm`, bits are stored tile-by-tile in (BH x BW) = (8 x 128)-bit
//! units so that every `load_matrix_sync` uses the fixed, fastest stride
//! `ldm = 128`.  The format only changes how bits are *ordered*; if the
//! logical width does not divide BW the row is padded to a BW multiple
//! (the same padding `load_matrix_sync` would require anyway).
//!
//! Tile-wise order and in-tile order follow the source layout: row-major
//! matrices use row-major tiles of row-major bits; column-major likewise.

use super::bitmatrix::{BitMatrix, Layout};

/// BMMA operand tile extents, in bits.
pub const BH: usize = 8;
pub const BW: usize = 128;
/// u32 words per tile row.
pub const TILE_ROW_WORDS: usize = BW / 32; // 4
/// u32 words per full (8 x 128)-bit tile.
pub const TILE_WORDS: usize = BH * TILE_ROW_WORDS; // 32

/// A bit matrix stored in FSB tile order.
///
/// Logical `rows x cols` (+/-1 entries), stored as a `tiles_y x tiles_x`
/// grid of (BH x BW)-bit tiles; each tile is BH consecutive 128-bit rows
/// (4 words each).  `rows` is padded up to BH and `cols` up to BW; pad
/// bits are 0.
#[derive(Clone, Debug, PartialEq)]
pub struct FsbMatrix {
    pub rows: usize,
    pub cols: usize,
    /// source layout this FSB image was converted from
    pub layout: Layout,
    pub tiles_y: usize,
    pub tiles_x: usize,
    pub data: Vec<u32>,
}

impl FsbMatrix {
    /// Convert a general-format matrix into FSB order.
    ///
    /// For RowMajor input, tile (ty, tx) covers logical rows
    /// `ty*BH..` and columns `tx*BW..`.  For ColMajor input the roles of
    /// rows/cols swap (tiles tile the packed *columns*).
    pub fn from_bitmatrix(m: &BitMatrix) -> FsbMatrix {
        let (major, minor) = match m.layout {
            Layout::RowMajor => (m.rows, m.cols),
            Layout::ColMajor => (m.cols, m.rows),
        };
        let tiles_y = major.div_ceil(BH);
        let tiles_x = minor.div_ceil(BW);
        let mut data = vec![0u32; tiles_y * tiles_x * TILE_WORDS];
        for line in 0..major {
            let src = m.line(line);
            let ty = line / BH;
            let ry = line % BH;
            for w in 0..m.words_per_line {
                let tx = w / TILE_ROW_WORDS;
                let wx = w % TILE_ROW_WORDS;
                let idx = ((ty * tiles_x + tx) * TILE_WORDS)
                    + ry * TILE_ROW_WORDS
                    + wx;
                data[idx] = src[w];
            }
        }
        FsbMatrix { rows: m.rows, cols: m.cols, layout: m.layout, tiles_y, tiles_x, data }
    }

    /// Convert back to the general format (inverse of `from_bitmatrix`).
    pub fn to_bitmatrix(&self) -> BitMatrix {
        let mut m = BitMatrix::zeros(self.rows, self.cols, self.layout);
        let major = m.lines();
        for line in 0..major {
            let ty = line / BH;
            let ry = line % BH;
            let wpl = m.words_per_line;
            for w in 0..wpl {
                let tx = w / TILE_ROW_WORDS;
                let wx = w % TILE_ROW_WORDS;
                let idx = ((ty * self.tiles_x + tx) * TILE_WORDS)
                    + ry * TILE_ROW_WORDS
                    + wx;
                m.line_mut(line)[w] = self.data[idx];
            }
        }
        m.mask_padding();
        m
    }

    /// The packed words of one (BH x BW) tile, contiguous in memory —
    /// this contiguity is exactly what fixes the WMMA stride at 128.
    #[inline]
    pub fn tile(&self, ty: usize, tx: usize) -> &[u32] {
        let base = (ty * self.tiles_x + tx) * TILE_WORDS;
        &self.data[base..base + TILE_WORDS]
    }

    /// One 128-bit row (4 words) within a tile.
    #[inline]
    pub fn tile_row(&self, ty: usize, tx: usize, ry: usize) -> &[u32] {
        let base =
            (ty * self.tiles_x + tx) * TILE_WORDS + ry * TILE_ROW_WORDS;
        &self.data[base..base + TILE_ROW_WORDS]
    }

    /// Storage bytes (== padded logical bits / 8; FSB adds no overhead
    /// beyond the BW padding that WMMA loads require anyway).
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// The Fig 14 toy example: an 8x4-bit matrix (H=4, W=8) converted with a
/// 4x2 tile (BH=2, BW=4).  Exposed as a generic function so the unit test
/// can reproduce the figure exactly with non-default tile sizes.
pub fn fsb_order_generic(
    h: usize,
    w: usize,
    bh: usize,
    bw: usize,
) -> Vec<usize> {
    // returns, for each storage slot, the index of the logical bit
    // (row-major) placed there
    let tx_n = w.div_ceil(bw);
    let ty_n = h.div_ceil(bh);
    let mut order = Vec::with_capacity(ty_n * tx_n * bh * bw);
    for ty in 0..ty_n {
        for tx in 0..tx_n {
            for r in 0..bh {
                for c in 0..bw {
                    let row = ty * bh + r;
                    let col = tx * bw + c;
                    if row < h && col < w {
                        order.push(row * w + col);
                    }
                }
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;
    use crate::util::Rng;

    #[test]
    fn roundtrip_row_major() {
        run_cases(31, 50, |rng| {
            let rows = 1 + rng.gen_range(40);
            let cols = 1 + rng.gen_range(300);
            let m = BitMatrix::random(rows, cols, Layout::RowMajor, rng);
            let f = FsbMatrix::from_bitmatrix(&m);
            assert_eq!(f.to_bitmatrix(), m);
        });
    }

    #[test]
    fn roundtrip_col_major() {
        run_cases(32, 50, |rng| {
            let rows = 1 + rng.gen_range(300);
            let cols = 1 + rng.gen_range(40);
            let m = BitMatrix::random(rows, cols, Layout::ColMajor, rng);
            let f = FsbMatrix::from_bitmatrix(&m);
            assert_eq!(f.to_bitmatrix(), m);
        });
    }

    #[test]
    fn tile_rows_are_contiguous_lines() {
        let mut rng = Rng::new(33);
        let m = BitMatrix::random(16, 256, Layout::RowMajor, &mut rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        // tile (1, 1), row 3 == logical row 11, words 4..8
        let got = f.tile_row(1, 1, 3);
        assert_eq!(got, &m.line(11)[4..8]);
    }

    #[test]
    fn fig14_example() {
        // Paper Fig 14: 1D general format H=4 x W=8, tile BH=2 x BW=4.
        // First tile must contain bits {0,1,2,3, 8,9,10,11}, second tile
        // {4,5,6,7, 12,13,14,15}, then the bottom half likewise.
        let order = fsb_order_generic(4, 8, 2, 4);
        assert_eq!(
            order,
            vec![
                0, 1, 2, 3, 8, 9, 10, 11, //
                4, 5, 6, 7, 12, 13, 14, 15, //
                16, 17, 18, 19, 24, 25, 26, 27, //
                20, 21, 22, 23, 28, 29, 30, 31
            ]
        );
    }

    #[test]
    fn no_extra_space_when_aligned() {
        let mut rng = Rng::new(34);
        let m = BitMatrix::random(64, 1024, Layout::RowMajor, &mut rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        assert_eq!(f.storage_bytes(), m.storage_bytes());
    }

    #[test]
    fn padded_when_unaligned() {
        let mut rng = Rng::new(35);
        let m = BitMatrix::random(10, 200, Layout::RowMajor, &mut rng);
        let f = FsbMatrix::from_bitmatrix(&m);
        // rows pad 10->16, cols pad 200->256
        assert_eq!(f.tiles_y, 2);
        assert_eq!(f.tiles_x, 2);
        assert_eq!(f.storage_bytes(), 2 * 2 * TILE_WORDS * 4);
    }
}
