//! 4D packed bit tensors for the convolution path (§5.3).
//!
//! The paper's key layout move is HWNC for activations (so the (N, C)
//! plane at each image point is a BMM operand) and KKCO for filters
//! (each filter tap is a (C, O) operand).  The innermost axis is packed
//! into u32 words, LSB-first, padded to whole words.

use super::pack;
use crate::util::Rng;

/// Semantic layout tag for a 4D bit tensor.  The storage order is always
/// dims[0] (outermost) .. dims[3] (innermost, packed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorLayout {
    /// activations: height, width, batch, channels (packed C)
    Hwnc,
    /// filters: kh, kw, out-channels, in-channels (packed C; O-major so a
    /// tap is a column-major BMM operand)
    Kkoc,
    /// activations in framework order (TensorFlow): batch, h, w, channels
    Nhwc,
}

/// A 4D +/-1 tensor with the innermost axis packed into u32 words.
#[derive(Clone, Debug, PartialEq)]
pub struct BitTensor4 {
    pub dims: [usize; 4],
    pub layout: TensorLayout,
    /// words along the packed innermost axis
    pub words_inner: usize,
    pub data: Vec<u32>,
}

impl BitTensor4 {
    pub fn zeros(dims: [usize; 4], layout: TensorLayout) -> BitTensor4 {
        let words_inner = dims[3].div_ceil(32);
        let n = dims[0] * dims[1] * dims[2] * words_inner;
        BitTensor4 { dims, layout, words_inner, data: vec![0; n] }
    }

    pub fn random(dims: [usize; 4], layout: TensorLayout, rng: &mut Rng) -> BitTensor4 {
        let mut t = BitTensor4::zeros(dims, layout);
        for w in t.data.iter_mut() {
            *w = rng.next_u32();
        }
        t.mask_padding();
        t
    }

    /// Binarize (Eq 1) a dense f32 buffer in the same dim order.
    pub fn from_f32(dims: [usize; 4], layout: TensorLayout, xs: &[f32]) -> BitTensor4 {
        assert_eq!(xs.len(), dims.iter().product::<usize>());
        let mut t = BitTensor4::zeros(dims, layout);
        let inner = dims[3];
        for outer in 0..dims[0] * dims[1] * dims[2] {
            let src = &xs[outer * inner..(outer + 1) * inner];
            let dst = t.inner_words_at_mut(outer);
            for (i, &x) in src.iter().enumerate() {
                if x >= 0.0 {
                    dst[i / 32] |= 1 << (i % 32);
                }
            }
        }
        t
    }

    #[inline]
    fn flat_outer(&self, a: usize, b: usize, c: usize) -> usize {
        debug_assert!(a < self.dims[0] && b < self.dims[1] && c < self.dims[2]);
        (a * self.dims[1] + b) * self.dims[2] + c
    }

    /// Packed words of the innermost vector at (a, b, c).
    #[inline]
    pub fn inner(&self, a: usize, b: usize, c: usize) -> &[u32] {
        let o = self.flat_outer(a, b, c) * self.words_inner;
        &self.data[o..o + self.words_inner]
    }

    #[inline]
    pub fn inner_mut(&mut self, a: usize, b: usize, c: usize) -> &mut [u32] {
        let o = self.flat_outer(a, b, c) * self.words_inner;
        &mut self.data[o..o + self.words_inner]
    }

    #[inline]
    fn inner_words_at_mut(&mut self, outer: usize) -> &mut [u32] {
        let o = outer * self.words_inner;
        &mut self.data[o..o + self.words_inner]
    }

    /// Logical +/-1 bit at (a, b, c, d).
    #[inline]
    pub fn get(&self, a: usize, b: usize, c: usize, d: usize) -> bool {
        pack::get_bit(self.inner(a, b, c), d)
    }

    #[inline]
    pub fn set(&mut self, a: usize, b: usize, c: usize, d: usize, v: bool) {
        pack::set_bit(self.inner_mut(a, b, c), d, v)
    }

    /// Zero the pad bits of every packed inner vector.
    pub fn mask_padding(&mut self) {
        let rem = self.dims[3] % 32;
        if rem == 0 {
            return;
        }
        let mask = (1u32 << rem) - 1;
        let wi = self.words_inner;
        for outer in 0..self.dims[0] * self.dims[1] * self.dims[2] {
            self.data[outer * wi + wi - 1] &= mask;
        }
    }

    /// Dense +/-1 expansion (dim order preserved).
    pub fn to_f32(&self) -> Vec<f32> {
        let inner = self.dims[3];
        let mut out = Vec::with_capacity(self.dims.iter().product());
        for outer in 0..self.dims[0] * self.dims[1] * self.dims[2] {
            let words = &self.data
                [outer * self.words_inner..(outer + 1) * self.words_inner];
            out.extend(pack::unpack_row(words, inner));
        }
        out
    }

    /// NHWC -> HWNC relayout (the paper's pre-conv transformation).
    pub fn nhwc_to_hwnc(&self) -> BitTensor4 {
        assert_eq!(self.layout, TensorLayout::Nhwc);
        let [n, h, w, c] = self.dims;
        let mut out = BitTensor4::zeros([h, w, n, c], TensorLayout::Hwnc);
        for ni in 0..n {
            for hi in 0..h {
                for wi in 0..w {
                    let src = self.inner(ni, hi, wi).to_vec();
                    out.inner_mut(hi, wi, ni).copy_from_slice(&src);
                }
            }
        }
        out
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    #[test]
    fn get_set_roundtrip() {
        run_cases(41, 50, |rng| {
            let dims = [
                1 + rng.gen_range(5),
                1 + rng.gen_range(5),
                1 + rng.gen_range(6),
                1 + rng.gen_range(80),
            ];
            let mut t = BitTensor4::zeros(dims, TensorLayout::Hwnc);
            let idx = [
                rng.gen_range(dims[0]),
                rng.gen_range(dims[1]),
                rng.gen_range(dims[2]),
                rng.gen_range(dims[3]),
            ];
            t.set(idx[0], idx[1], idx[2], idx[3], true);
            assert!(t.get(idx[0], idx[1], idx[2], idx[3]));
        });
    }

    #[test]
    fn f32_roundtrip() {
        run_cases(42, 30, |rng| {
            let dims = [2, 3, 1 + rng.gen_range(4), 1 + rng.gen_range(70)];
            let xs = rng.pm1_vec(dims.iter().product());
            let t = BitTensor4::from_f32(dims, TensorLayout::Nhwc, &xs);
            assert_eq!(t.to_f32(), xs);
        });
    }

    #[test]
    fn nhwc_to_hwnc_permutes() {
        run_cases(43, 20, |rng| {
            let (n, h, w, c) = (2, 3, 4, 40);
            let t = BitTensor4::random([n, h, w, c], TensorLayout::Nhwc, rng);
            let p = t.nhwc_to_hwnc();
            for ni in 0..n {
                for hi in 0..h {
                    for wi in 0..w {
                        for ci in 0..c {
                            assert_eq!(t.get(ni, hi, wi, ci), p.get(hi, wi, ni, ci));
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn padding_is_masked() {
        let mut rng = Rng::new(44);
        let t = BitTensor4::random([2, 2, 2, 40], TensorLayout::Hwnc, &mut rng);
        for a in 0..2 {
            for b in 0..2 {
                for c in 0..2 {
                    assert_eq!(t.inner(a, b, c)[1] >> 8, 0);
                }
            }
        }
    }
}
