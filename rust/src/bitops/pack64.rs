//! u64-word repacking — the fastpath backend's native word size.
//!
//! `BitMatrix`/`FsbMatrix` pack along u32 words (the CUDA-facing unit:
//! BMMA consumes 32-bit fragments).  A host CPU popcounts fastest on
//! 64-bit words, so `kernels::fastpath` repacks lines into u64 before
//! compute.  Repacking is a pure pairing: u64 word `w` of a line holds
//! u32 words `2w` (low half) and `2w + 1` (high half), preserving the
//! LSB-first element order — element `e` lives at u64 word `e/64`, bit
//! `e%64`.  An odd u32 line width leaves the final high half zero,
//! which Eq 2 ignores by construction (pad bits are 0 in both
//! operands, so they XOR to 0 disagreements).

use super::bitmatrix::BitMatrix;
use super::fsb::FsbMatrix;

/// u64 words needed to hold a line of `w32` u32 words.
#[inline]
pub fn words64(w32: usize) -> usize {
    w32.div_ceil(2)
}

/// Repack one packed u32 line into u64 words.
/// `dst.len()` must equal `words64(src.len())`.
pub fn repack64_into(src: &[u32], dst: &mut [u64]) {
    debug_assert_eq!(dst.len(), words64(src.len()));
    let pairs = src.chunks_exact(2);
    let rem = pairs.remainder();
    for (d, pair) in dst.iter_mut().zip(pairs) {
        *d = pair[0] as u64 | ((pair[1] as u64) << 32);
    }
    if let Some(&last) = rem.first() {
        dst[src.len() / 2] = last as u64;
    }
}

/// Inverse of [`repack64_into`]: split u64 words back into u32 words.
/// `src.len()` must equal `words64(dst.len())`.
pub fn unpack64_into(src: &[u64], dst: &mut [u32]) {
    debug_assert_eq!(src.len(), words64(dst.len()));
    for (w, d) in dst.iter_mut().enumerate() {
        let v = src[w / 2];
        *d = if w % 2 == 0 { v as u32 } else { (v >> 32) as u32 };
    }
}

/// popc(a XOR b) over two u64-packed lines of equal word length, with a
/// 4-way `chunks_exact` unroll the compiler autovectorizes.
#[inline]
pub fn xor_popc64(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = 0u32;
    for (x, y) in ca.zip(cb) {
        acc += (x[0] ^ y[0]).count_ones()
            + (x[1] ^ y[1]).count_ones()
            + (x[2] ^ y[2]).count_ones()
            + (x[3] ^ y[3]).count_ones();
    }
    for (x, y) in ra.iter().zip(rb) {
        acc += (x ^ y).count_ones();
    }
    acc
}

/// Eq 2 over u64-packed lines of logical length `n` bits.
#[inline]
pub fn pm1_dot64(a: &[u64], b: &[u64], n: usize) -> i32 {
    n as i32 - 2 * xor_popc64(a, b) as i32
}

/// Split two equal-length packed lines into `L`-word lane pairs plus
/// their scalar remainders — the access shape every SIMD popcount
/// kernel consumes (L=4 for 256-bit unrolls, 8 for AVX-512, 16 for the
/// NEON 8-vector block).  Returning fixed-size array refs lets the
/// vector kernels index lanes without bounds checks.
#[inline]
pub fn lane_pairs<'a, const L: usize>(
    a: &'a [u64],
    b: &'a [u64],
) -> (
    impl Iterator<Item = (&'a [u64; L], &'a [u64; L])>,
    &'a [u64],
    &'a [u64],
) {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(L);
    let cb = b.chunks_exact(L);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let it = ca.zip(cb).map(|(x, y)| -> (&'a [u64; L], &'a [u64; L]) {
        (x.try_into().expect("exact chunk"), y.try_into().expect("exact chunk"))
    });
    (it, ra, rb)
}

/// A bit matrix with lines repacked into u64 words — the fastpath
/// operand form.  `rows`/`cols`/`layout` carry the same meaning as in
/// [`BitMatrix`]; only the word size of a packed line changes.
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix64 {
    pub rows: usize,
    pub cols: usize,
    pub layout: super::bitmatrix::Layout,
    /// u64 words per packed line
    pub words_per_line: usize,
    pub data: Vec<u64>,
}

impl BitMatrix64 {
    /// Repack a u32 bit matrix line-by-line into u64 words.
    pub fn from_bitmatrix(m: &BitMatrix) -> BitMatrix64 {
        let wpl = words64(m.words_per_line);
        let lines = m.lines();
        let mut data = vec![0u64; wpl * lines];
        for l in 0..lines {
            repack64_into(m.line(l), &mut data[l * wpl..(l + 1) * wpl]);
        }
        BitMatrix64 {
            rows: m.rows,
            cols: m.cols,
            layout: m.layout,
            words_per_line: wpl,
            data,
        }
    }

    /// Repack an FSB image.  The FSB tile order exists to fix the WMMA
    /// stride at 128 on a Turing GPU — on the host it buys nothing, so
    /// the image is first normalized back to plain packed lines.
    pub fn from_fsb(f: &FsbMatrix) -> BitMatrix64 {
        BitMatrix64::from_bitmatrix(&f.to_bitmatrix())
    }

    /// Inverse of `from_bitmatrix` (round-trip tested property).
    pub fn to_bitmatrix(&self) -> BitMatrix {
        let mut m = BitMatrix::zeros(self.rows, self.cols, self.layout);
        let lines = m.lines();
        for l in 0..lines {
            unpack64_into(self.line(l), m.line_mut(l));
        }
        m
    }

    /// Number of packed lines (major dimension extent).
    pub fn lines(&self) -> usize {
        self.data.len() / self.words_per_line.max(1)
    }

    /// Packed u64 words of line `i`.
    #[inline]
    pub fn line(&self, i: usize) -> &[u64] {
        let w = self.words_per_line;
        &self.data[i * w..(i + 1) * w]
    }

    /// Bytes of packed storage.
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::bitmatrix::Layout;
    use crate::bitops::pack;
    use crate::util::proptest::run_cases;

    #[test]
    fn repack_preserves_every_bit() {
        run_cases(61, 80, |rng| {
            let n = 1 + rng.gen_range(300);
            let xs = rng.pm1_vec(n);
            let w32 = pack::pack_row(&xs);
            let mut w64 = vec![0u64; words64(w32.len())];
            repack64_into(&w32, &mut w64);
            for (i, &x) in xs.iter().enumerate() {
                let bit = (w64[i / 64] >> (i % 64)) & 1 == 1;
                assert_eq!(bit, x >= 0.0, "bit {i} of {n}");
            }
            let mut back = vec![0u32; w32.len()];
            unpack64_into(&w64, &mut back);
            assert_eq!(back, w32);
        });
    }

    #[test]
    fn dot64_matches_dot32() {
        run_cases(62, 80, |rng| {
            let n = 1 + rng.gen_range(500);
            let a = rng.pm1_vec(n);
            let b = rng.pm1_vec(n);
            let (pa, pb) = (pack::pack_row(&a), pack::pack_row(&b));
            let mut a64 = vec![0u64; words64(pa.len())];
            let mut b64 = vec![0u64; words64(pb.len())];
            repack64_into(&pa, &mut a64);
            repack64_into(&pb, &mut b64);
            assert_eq!(pm1_dot64(&a64, &b64, n), pack::pm1_dot(&pa, &pb, n));
        });
    }

    #[test]
    fn lane_pairs_tile_the_lines_exactly() {
        run_cases(66, 60, |rng| {
            let n = 1 + rng.gen_range(100);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            // popcount composed from L-word lanes + remainder must match
            // the flat kernel for every lane width the SIMD paths use
            fn via_lanes<const L: usize>(a: &[u64], b: &[u64]) -> u32 {
                let (lanes, ra, rb) = lane_pairs::<L>(a, b);
                let mut acc = 0u32;
                for (x, y) in lanes {
                    for l in 0..L {
                        acc += (x[l] ^ y[l]).count_ones();
                    }
                }
                for (x, y) in ra.iter().zip(rb) {
                    acc += (x ^ y).count_ones();
                }
                acc
            }
            let want = xor_popc64(&a, &b);
            assert_eq!(via_lanes::<4>(&a, &b), want);
            assert_eq!(via_lanes::<8>(&a, &b), want);
            assert_eq!(via_lanes::<16>(&a, &b), want);
        });
    }

    #[test]
    fn lane_pairs_remainder_covers_short_lines() {
        let a = [1u64, 2, 3];
        let b = [3u64, 2, 1];
        let (mut lanes, ra, rb) = lane_pairs::<4>(&a, &b);
        assert!(lanes.next().is_none());
        assert_eq!(ra, &a);
        assert_eq!(rb, &b);
    }

    #[test]
    fn bitmatrix_roundtrip_both_layouts() {
        run_cases(63, 60, |rng| {
            let rows = 1 + rng.gen_range(40);
            let cols = 1 + rng.gen_range(200);
            for layout in [Layout::RowMajor, Layout::ColMajor] {
                let m = BitMatrix::random(rows, cols, layout, rng);
                let m64 = BitMatrix64::from_bitmatrix(&m);
                assert_eq!(m64.to_bitmatrix(), m);
            }
        });
    }

    #[test]
    fn fsb_repack_matches_direct_repack() {
        run_cases(64, 30, |rng| {
            let m = BitMatrix::random(
                1 + rng.gen_range(30),
                1 + rng.gen_range(300),
                Layout::RowMajor,
                rng,
            );
            let via_fsb = BitMatrix64::from_fsb(&FsbMatrix::from_bitmatrix(&m));
            assert_eq!(via_fsb, BitMatrix64::from_bitmatrix(&m));
        });
    }

    #[test]
    fn odd_word_width_leaves_high_half_zero() {
        let mut rng = crate::util::Rng::new(65);
        // 3 u32 words per line -> 2 u64 words, high half of the last zero
        let m = BitMatrix::random(4, 96, Layout::RowMajor, &mut rng);
        let m64 = BitMatrix64::from_bitmatrix(&m);
        assert_eq!(m64.words_per_line, 2);
        for l in 0..4 {
            assert_eq!(m64.line(l)[1] >> 32, 0, "line {l} high half set");
        }
    }
}
