//! Binarization + packing primitives (Eq 1 / Eq 2 of the paper).

/// Eq 1: sign binarization, `x >= 0 -> +1 else -1`.
#[inline]
pub fn sign_pm1(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Pack a row of floats into u32 words, LSB-first; bit 1 encodes x >= 0.
/// `row.len()` need not be a multiple of 32: the tail bits of the last
/// word are 0 (they encode -1 and must be compensated by the caller —
/// `BitMatrix` pads columns explicitly instead).
pub fn pack_row(row: &[f32]) -> Vec<u32> {
    let words = row.len().div_ceil(32);
    let mut out = vec![0u32; words];
    // branchless word building (§Perf opt-3): full 32-element chunks
    // fold sign bits without per-bit branches
    let chunks = row.chunks_exact(32);
    let rem = chunks.remainder();
    for (w, chunk) in chunks.enumerate() {
        let mut word = 0u32;
        for (j, &x) in chunk.iter().enumerate() {
            word |= ((x >= 0.0) as u32) << j;
        }
        out[w] = word;
    }
    let base = row.len() - rem.len();
    for (j, &x) in rem.iter().enumerate() {
        let i = base + j;
        out[i / 32] |= ((x >= 0.0) as u32) << (i % 32);
    }
    out
}

/// Pack with a per-element threshold: bit = (x >= thresh).
pub fn pack_row_thresh(row: &[f32], thresh: &[f32]) -> Vec<u32> {
    debug_assert_eq!(row.len(), thresh.len());
    let words = row.len().div_ceil(32);
    let mut out = vec![0u32; words];
    for (i, (&x, &t)) in row.iter().zip(thresh).enumerate() {
        if x >= t {
            out[i / 32] |= 1 << (i % 32);
        }
    }
    out
}

/// Unpack `n` bits from packed words into +/-1 floats.
pub fn unpack_row(words: &[u32], n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            if words[i / 32] >> (i % 32) & 1 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect()
}

/// Bit value at position `i` of a packed row (true == +1).
#[inline]
pub fn get_bit(words: &[u32], i: usize) -> bool {
    words[i / 32] >> (i % 32) & 1 == 1
}

/// Set bit `i` in a packed row.
#[inline]
pub fn set_bit(words: &mut [u32], i: usize, v: bool) {
    if v {
        words[i / 32] |= 1 << (i % 32);
    } else {
        words[i / 32] &= !(1 << (i % 32));
    }
}

/// popc(a XOR b) over two packed rows of equal word length.
#[inline]
pub fn xor_popc(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (&x, &y) in a.iter().zip(b) {
        acc += (x ^ y).count_ones();
    }
    acc
}

/// Eq 2: the +/-1 dot product of two packed bit vectors of logical
/// length `n` bits: `v = n - 2*popc(a XOR b)`.
#[inline]
pub fn pm1_dot(a: &[u32], b: &[u32], n: usize) -> i32 {
    n as i32 - 2 * xor_popc(a, b) as i32
}

/// Eq 2, xnor form: `v = 2*popc(a XNOR b) - n` (used by the FPGA/ASIC
/// lineage; mathematically identical for whole words — kept for tests).
#[inline]
pub fn pm1_dot_xnor(a: &[u32], b: &[u32], n_words_bits: usize) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (&x, &y) in a.iter().zip(b) {
        acc += (!(x ^ y)).count_ones();
    }
    2 * acc as i32 - n_words_bits as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;
    use crate::util::Rng;

    #[test]
    fn pack_unpack_roundtrip() {
        run_cases(11, 100, |rng| {
            let n = 1 + rng.gen_range(300);
            let xs = rng.pm1_vec(n);
            let packed = pack_row(&xs);
            assert_eq!(unpack_row(&packed, n), xs);
        });
    }

    #[test]
    fn eq2_matches_float_dot() {
        run_cases(12, 100, |rng| {
            let n = 32 * (1 + rng.gen_range(16));
            let a = rng.pm1_vec(n);
            let b = rng.pm1_vec(n);
            let fdot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let pa = pack_row(&a);
            let pb = pack_row(&b);
            assert_eq!(pm1_dot(&pa, &pb, n), fdot as i32);
            assert_eq!(pm1_dot_xnor(&pa, &pb, n), fdot as i32);
        });
    }

    #[test]
    fn threshold_packing() {
        let row = [0.1, 0.9, -0.5, 0.5];
        let th = [0.5, 0.5, -1.0, 0.6];
        let p = pack_row_thresh(&row, &th);
        assert_eq!(p[0] & 0xF, 0b0110);
    }

    #[test]
    fn bit_accessors() {
        let mut w = vec![0u32; 2];
        set_bit(&mut w, 33, true);
        assert!(get_bit(&w, 33));
        assert!(!get_bit(&w, 32));
        set_bit(&mut w, 33, false);
        assert_eq!(w, vec![0, 0]);
    }

    #[test]
    fn xor_popc_counts_disagreements() {
        let mut rng = Rng::new(5);
        let n = 256;
        let a = rng.pm1_vec(n);
        let b = rng.pm1_vec(n);
        let disagree = a.iter().zip(&b).filter(|(x, y)| x != y).count() as u32;
        assert_eq!(xor_popc(&pack_row(&a), &pack_row(&b)), disagree);
    }
}
