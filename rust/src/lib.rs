//! # TCBNN-X
//!
//! A reproduction of *"Accelerating Binarized Neural Networks via
//! Bit-Tensor-Cores in Turing GPUs"* (Li & Su, 2020) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build-time python): Pallas bit kernels (XOR+POPC BMM and
//!   BConv) — `python/compile/kernels/`.
//! * **Layer 2** (build-time python): JAX BNN model graphs AOT-lowered to
//!   HLO text — `python/compile/model.py` + `aot.py`.
//! * **Layer 3** (this crate): the inference coordinator — dynamic
//!   batcher, router, PJRT runtime — plus the complete Turing BTC
//!   substrate the paper's evaluation depends on: packed bit formats
//!   (including the FSB format of §5.1), functional implementations of
//!   every BMM/BConv scheme in the evaluation, a calibrated Turing
//!   timing model reproducing the §4 characterization, the six network
//!   models of Table 5, and the BENN multi-GPU ensemble of §7.6.
//!
//! ## Engine
//!
//! The `engine` module is the serving layer that connects the kernel
//! study to the coordinator: a **planner** queries the calibrated
//! Turing cost model for every Tables-6/7 scheme per layer shape and
//! emits an executable `ModelPlan` (persisted in a JSON plan cache
//! keyed by model x batch x gpu); an **arena executor** pre-allocates
//! every buffer from the plan and runs the packed-bit forward pass with
//! zero per-request heap allocation, parallelized across rows; and
//! `EngineModel` plugs the executor into `coordinator::server` so any
//! Table-5 model is servable end to end.  See `docs/ENGINE.md`.
//!
//! The seventh scheme, `nn::cost::Scheme::Fastpath`, is the blocked
//! u64 XNOR-popcount **host** backend (`kernels::fastpath`, operands
//! repacked via `bitops::pack64`): bit-identical to the naive
//! references, >= 2x the scalar schemes on ResNet-18 shapes, and
//! regression-gated in CI by `cargo bench --bench bench_kernels`
//! against `benches/baseline.json` (see `docs/BENCH.md`).
//!
//! See DESIGN.md for the system inventory and the per-table/figure
//! experiment index, and EXPERIMENTS.md for paper-vs-measured results.

pub mod bitops;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod kernels;
pub mod nn;
pub mod runtime;
pub mod sim;
pub mod util;

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: $TCBNN_ARTIFACTS, ./artifacts, or
/// ../artifacts (so tests and examples work from any working dir).
pub fn artifact_dir() -> String {
    if let Ok(d) = std::env::var("TCBNN_ARTIFACTS") {
        return d;
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        if std::path::Path::new(&format!("{cand}/manifest.txt")).exists() {
            return cand.to_string();
        }
    }
    ARTIFACT_DIR.to_string()
}
