//! # TCBNN-X
//!
//! A reproduction of *"Accelerating Binarized Neural Networks via
//! Bit-Tensor-Cores in Turing GPUs"* (Li & Su, 2020) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build-time python): Pallas bit kernels (XOR+POPC BMM and
//!   BConv) — `python/compile/kernels/`.
//! * **Layer 2** (build-time python): JAX BNN model graphs AOT-lowered to
//!   HLO text — `python/compile/model.py` + `aot.py`.
//! * **Layer 3** (this crate): the inference coordinator — dynamic
//!   batcher, router, PJRT runtime — plus the complete Turing BTC
//!   substrate the paper's evaluation depends on: packed bit formats
//!   (including the FSB format of §5.1), functional implementations of
//!   every BMM/BConv scheme in the evaluation, a calibrated Turing
//!   timing model reproducing the §4 characterization, the six network
//!   models of Table 5, and the BENN multi-GPU ensemble of §7.6.
//!
//! ## Backends + engine
//!
//! Every scheme is provided through one abstraction:
//! `kernels::backend::KernelBackend` — weight *preparation* (opaque
//! prepared-layer handles owning scheme-specific packed weights),
//! bit-exact *execution* over an `ExecCtx` (arena scratch +
//! threadpool), and the *cost face* (`layer_secs`/`layer_traces`) the
//! planner ranks.  A `BackendRegistry` keyed by `nn::cost::Scheme` is
//! the single dispatch point: `nn::forward`, `nn::cost`, and the
//! engine consult a registry instead of matching on `Scheme`, so new
//! host backends (SIMD, NUMA-sharded, test doubles) drop in by
//! registering — proven by the toy backend in
//! `tests/backend_equivalence.rs`.
//!
//! The `engine` module is the serving layer on top: a **planner**
//! asks every registered backend for its per-layer cost and emits an
//! executable `ModelPlan` (persisted in a schema-versioned JSON plan
//! cache keyed by model x batch x gpu, invalidated when the backend
//! set changes); an **arena executor** holds one prepared-layer
//! handle per plan layer and runs the packed-bit forward pass with
//! zero per-request heap allocation, parallelized across rows; and
//! `EngineModel::builder` (+ `PlanPolicy`) plugs the executor into
//! `coordinator::server` so any Table-5 model is servable end to end.
//! See `docs/ENGINE.md`.
//!
//! Two schemes run on the serving **host** rather than the modeled
//! GPU: `nn::cost::Scheme::Fastpath`, the blocked u64 XNOR-popcount
//! backend (`kernels::fastpath`, operands repacked via
//! `bitops::pack64`), and `nn::cost::Scheme::Simd`, the same blocking
//! with the inner popcount dispatched through a runtime-detected
//! `PopcountEngine` (AVX-512 `vpopcntdq` / x86 `popcnt` / NEON `cnt` /
//! portable; `kernels::simd`, forcible via `TCBNN_SIMD`), with
//! NUMA-sharded row bands from `util::threadpool`.  Both are
//! bit-identical to the naive references, >= 2x the scalar schemes on
//! ResNet-18 shapes, and regression-gated in CI by `cargo bench
//! --bench bench_kernels` against `benches/baseline.json` (see
//! `docs/BENCH.md`).
//!
//! The `layout` module makes the paper's data-format co-design a
//! planned quantity: `LayoutKind` (`Row32` | `Blocked64` | `Fsb` |
//! `Im2rowStaged`) + exact repack converters between every pair
//! (`layout::repack`), a layout face on `KernelBackend`, and a planner
//! dynamic program over (scheme, layout) pairs that prices explicit
//! repack edges (plan schema v4) which the arena executor then
//! materializes through pre-sized scratch — so conversions that used
//! to happen implicitly inside kernels are chosen, costed, and counted
//! (`Metrics` repack ops/bytes).
//!
//! The `tuner` module closes the loop between those cost models and
//! reality: a microbench runner measures each registered host
//! backend's kernels over a shape grid and least-squares-fits its
//! cost-model coefficients into a schema-versioned, host-fingerprinted
//! `CalibrationProfile` (persisted next to the plan cache, which
//! invalidates entries when the active profile changes).  Planner cost
//! queries go through a `tuner::CostSource` — `Analytic`,
//! `Calibrated(profile)`, or `Live` (the calibrated prior blended with
//! the executor's lock-free per-scheme latency EWMA, letting a served
//! `EngineModel` re-plan when measured costs drift >2x from
//! prediction).  Run `cargo run --release --bin tuner -- --quick`; the
//! CI `tuner-smoke` job gates on it.
//!
//! The `serve` module scales the coordinator out to a multi-model
//! **fleet**: each named model runs N replica shards (sharing one plan
//! cache/calibration profile) with work stealing between siblings,
//! behind token-bucket + queue-depth admission control that sheds load
//! with an explicit `Overloaded` error instead of unbounded queues,
//! and — when a p99 deadline is configured — SLO-aware batch sizing
//! that restricts the bucket list to sizes whose planner-predicted
//! service time meets the deadline.  See `docs/SERVING.md`.
//!
//! The `sparse` module extends the bit substrate to *sparse* binary
//! tensors and a graph workload: `bitops::SparseBitMatrix` (CSR of
//! 64-bit column blocks) with exact dense converters, two sparse host
//! backends (`Scheme::Spmm`, `Scheme::GcnFused`) whose cost faces are
//! parameterized on stored-block counts, a binary GCN layer
//! (`LayerSpec::BinGcn`) with deterministic synthetic adjacencies, and
//! two GCN models in `nn::all_models()` — so the planner's
//! scheme/layout DP sees a density-dependent sparse-vs-dense crossover
//! and plans carry a sparsity fingerprint that invalidates the cache
//! when adjacency density changes.  See `docs/ENGINE.md`.
//!
//! The `obs` module is the telemetry layer the stack reports into:
//! a bounded log-scale latency histogram (replacing unbounded
//! per-request latency storage in `coordinator::Metrics`), per-batch
//! span traces (queue wait → batch assembly → per-layer execution
//! with explicit repack ops) in a fixed-capacity ring, and a
//! `Snapshot` exporter that renders the same struct as the human
//! report line, a round-trippable `engine::json` document, and
//! Prometheus text — with per-*layer* drift and per-*edge* repack
//! attribution from the executor.  See `docs/OBSERVABILITY.md`.
//!
//! See DESIGN.md for the system inventory and the per-table/figure
//! experiment index, and EXPERIMENTS.md for paper-vs-measured results.

pub mod bitops;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod kernels;
pub mod layout;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sparse;
pub mod tuner;
pub mod util;

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: $TCBNN_ARTIFACTS, ./artifacts, or
/// ../artifacts (so tests and examples work from any working dir).
pub fn artifact_dir() -> String {
    if let Ok(d) = std::env::var("TCBNN_ARTIFACTS") {
        return d;
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        if std::path::Path::new(&format!("{cand}/manifest.txt")).exists() {
            return cand.to_string();
        }
    }
    ARTIFACT_DIR.to_string()
}
