//! Multi-model serving fleet, layered above `coordinator`.
//!
//! The coordinator serves one model with one worker.  This module
//! scales that out along three axes the single-model server cannot:
//!
//! * **Sharding + work stealing** ([`fleet`]): each named model gets N
//!   replica shards; an idle replica steals queued batches from a
//!   loaded sibling, so one hot shard cannot strand latency while
//!   others sit idle.  Replicas built from one factory share a
//!   `PlanCache`/calibration profile.
//! * **Admission control** ([`admission`]): a token bucket (sustained
//!   rate + burst) and a queue-depth cap shed load *synchronously* on
//!   the submit path — a rejected request gets an explicit
//!   [`Overload`] and is never enqueued, so no waiter leaks.
//! * **SLO-aware batch sizing** ([`slo`]): given a p99 deadline, batch
//!   formation is restricted to the largest buckets whose predicted
//!   service time (the planner's Live/Calibrated/Analytic cost source)
//!   still meets the deadline, replacing the fixed bucket list.
//!
//! Telemetry flows through the same `obs::Snapshot` as the rest of the
//! stack, extended with per-model sheds/steals/SLO counters and
//! per-shard attribution ([`crate::obs::ShardAttr`]).  See
//! `docs/SERVING.md`.

pub mod admission;
pub mod fleet;
pub(crate) mod queue;
pub mod slo;

pub use admission::{Admission, AdmissionConfig, Overload};
pub use fleet::{Fleet, FleetError, FleetModelConfig};
pub use slo::{plan_predictor, BatchSecsPredictor, BatchSizer, SloConfig};
