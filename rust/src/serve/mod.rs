//! Multi-model serving fleet, layered above `coordinator`.
//!
//! The coordinator serves one model with one worker.  This module
//! scales that out along three axes the single-model server cannot:
//!
//! * **Sharding + work stealing** ([`fleet`]): each named model gets N
//!   replica shards; an idle replica steals queued batches from a
//!   loaded sibling, so one hot shard cannot strand latency while
//!   others sit idle.  Replicas built from one factory share a
//!   `PlanCache`/calibration profile.
//! * **Admission control** ([`admission`]): a token bucket (sustained
//!   rate + burst) and a queue-depth cap shed load *synchronously* on
//!   the submit path — a rejected request gets an explicit
//!   [`Overload`] and is never enqueued, so no waiter leaks.
//!   Models carry a `priority` (0 = highest): under shared-host
//!   pressure (summed higher-priority queue depth past
//!   [`Fleet::set_priority_pressure`]) lower-priority submits shed
//!   with [`Overload::LowPriority`] before they can starve a
//!   latency-critical tenant.
//! * **SLO-aware batch sizing** ([`slo`]): given a p99 deadline, batch
//!   formation is restricted to the largest buckets whose predicted
//!   service time (the planner's Live/Calibrated/Analytic cost source)
//!   still meets the deadline, replacing the fixed bucket list.  The
//!   admissible set is re-derived whenever the engine re-plans.
//! * **Shard health watchdog** ([`health`]): a monitor thread
//!   classifies every shard Healthy / Degraded / Stalled from worker
//!   heartbeats, queue age, and the windowed SLO miss-rate; the board
//!   feeds `/healthz` and the snapshot's `health` block.
//!
//! Telemetry flows through the same `obs::Snapshot` as the rest of the
//! stack, extended with per-model sheds/steals/SLO counters, per-shard
//! attribution ([`crate::obs::ShardAttr`]), rolling-window stats, and
//! shard health ([`crate::obs::ShardHealthAttr`]).  The fleet is an
//! [`crate::obs::ScrapeSource`], so `obs::ScrapeServer` exposes it
//! live over HTTP.  See `docs/SERVING.md`.

pub mod admission;
pub mod fleet;
pub mod health;
pub(crate) mod queue;
pub mod slo;

pub use admission::{Admission, AdmissionConfig, Overload};
pub use fleet::{Fleet, FleetError, FleetModelConfig};
pub use health::{
    HealthReport, ModelHealth, ShardHealth, ShardState, Watchdog, WatchdogConfig,
};
pub use slo::{plan_predictor, BatchSecsPredictor, BatchSizer, SloConfig};
