//! Latency-SLO-aware batch sizing.
//!
//! The fixed-bucket batcher always prefers the largest fully-filled
//! bucket — throughput-optimal, but under a latency SLO the largest
//! bucket may be the wrong choice: a request that joins a 128-row
//! batch pays that batch's full service time.  Given a p99 deadline
//! `D` and a predictor `t(b)` for the service time of a `b`-row batch,
//! the admissible buckets are
//!
//! ```text
//!   A = { b in buckets : t(b) <= D }
//! ```
//!
//! and the sizer hands the batch-formation rule `A` instead of the full
//! bucket list — so the chosen size is still "largest fully-filled
//! admissible bucket", i.e. *maximal subject to predicted time meeting
//! the deadline*.  Two degradations keep the fleet serving:
//!
//! * no bucket meets the deadline -> serve the smallest bucket anyway
//!   (an impossible SLO must not halt traffic; misses are counted in
//!   the SLO hit-rate instead);
//! * no predictor / predictor abstains -> the full fixed bucket list
//!   (exactly the pre-SLO behavior).
//!
//! The predictor is typically [`plan_predictor`]: `Planner::predict_secs`
//! under the planner's cost source, so Live/Calibrated profiles feed
//! batch sizing automatically and Analytic is the fallback.

use std::sync::Arc;
use std::time::Duration;

use crate::engine::Planner;
use crate::nn::ModelDef;

/// Latency objective for one fleet model.
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// target p99 end-to-end deadline for accepted requests
    pub p99_deadline: Duration,
}

/// Predicted service seconds for a batch of the given row count.
/// `None` means "no data for this bucket" and degrades the sizer to
/// fixed buckets.
pub type BatchSecsPredictor = Arc<dyn Fn(usize) -> Option<f64> + Send + Sync>;

/// Predictor backed by a planner: predicted whole-model seconds at
/// each bucket, inheriting the planner's cost source (Live /
/// Calibrated / Analytic).
pub fn plan_predictor(planner: &Planner, model: &ModelDef) -> BatchSecsPredictor {
    let planner = planner.clone();
    let model = model.clone();
    Arc::new(move |batch| Some(planner.predict_secs(&model, batch)))
}

/// The per-shard batch-sizing decision, computed once at worker start
/// (buckets and cost profiles are fixed per model instance).
#[derive(Clone, Debug)]
pub struct BatchSizer {
    admissible: Vec<usize>,
    restricted: bool,
}

impl BatchSizer {
    /// No SLO: the full fixed bucket list.
    pub fn fixed(buckets: Vec<usize>) -> BatchSizer {
        assert!(!buckets.is_empty(), "need at least one bucket");
        BatchSizer { admissible: buckets, restricted: false }
    }

    /// SLO-restricted sizing over `buckets` (ascending).  `predicted`
    /// holds the per-bucket service-time predictions, parallel to
    /// `buckets`; any `None` degrades to the fixed list.
    pub fn with_slo(
        buckets: Vec<usize>,
        predicted: &[Option<f64>],
        deadline: Duration,
    ) -> BatchSizer {
        assert_eq!(buckets.len(), predicted.len());
        let Some(preds) = predicted.iter().copied().collect::<Option<Vec<f64>>>()
        else {
            // no cost profile for some bucket: fixed-bucket behavior
            return BatchSizer::fixed(buckets);
        };
        let d = deadline.as_secs_f64();
        let admissible: Vec<usize> = buckets
            .iter()
            .zip(&preds)
            .filter(|(_, &t)| t <= d)
            .map(|(&b, _)| b)
            .collect();
        if admissible.is_empty() {
            // impossible deadline: keep serving at the smallest bucket
            return BatchSizer { admissible: vec![buckets[0]], restricted: true };
        }
        let restricted = admissible.len() != buckets.len();
        BatchSizer { admissible, restricted }
    }

    /// Build the sizer a fleet worker uses: SLO + predictor when both
    /// are configured, fixed buckets otherwise.
    pub fn for_model(
        buckets: Vec<usize>,
        slo: Option<SloConfig>,
        predictor: Option<&BatchSecsPredictor>,
    ) -> BatchSizer {
        match (slo, predictor) {
            (Some(slo), Some(pred)) => {
                let preds: Vec<Option<f64>> =
                    buckets.iter().map(|&b| pred(b)).collect();
                BatchSizer::with_slo(buckets, &preds, slo.p99_deadline)
            }
            _ => BatchSizer::fixed(buckets),
        }
    }

    /// The bucket list batch formation may use (ascending, non-empty).
    pub fn buckets(&self) -> &[usize] {
        &self.admissible
    }

    /// Largest admissible bucket (steal size cap).
    pub fn max_bucket(&self) -> usize {
        *self.admissible.last().unwrap()
    }

    /// Smallest admissible bucket (minimum worthwhile steal).
    pub fn min_bucket(&self) -> usize {
        self.admissible[0]
    }

    /// Whether the SLO actually cut buckets off the fixed list.
    pub fn restricted(&self) -> bool {
        self.restricted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    const BUCKETS: [usize; 3] = [8, 32, 128];

    /// synthetic monotone cost: 1ms per 8 rows
    fn pred(b: usize) -> Option<f64> {
        Some(b as f64 / 8.0 * 1e-3)
    }

    #[test]
    fn deadline_cuts_the_largest_buckets() {
        let preds: Vec<_> = BUCKETS.iter().map(|&b| pred(b)).collect();
        // 5ms deadline: t(8)=1ms, t(32)=4ms admissible; t(128)=16ms not
        let s = BatchSizer::with_slo(
            BUCKETS.to_vec(),
            &preds,
            Duration::from_millis(5),
        );
        assert_eq!(s.buckets(), &[8, 32]);
        assert!(s.restricted());
        assert_eq!(s.max_bucket(), 32);
    }

    #[test]
    fn generous_deadline_keeps_all_buckets() {
        let preds: Vec<_> = BUCKETS.iter().map(|&b| pred(b)).collect();
        let s = BatchSizer::with_slo(
            BUCKETS.to_vec(),
            &preds,
            Duration::from_secs(1),
        );
        assert_eq!(s.buckets(), &BUCKETS);
        assert!(!s.restricted(), "nothing was cut");
    }

    #[test]
    fn impossible_deadline_degrades_to_smallest_bucket() {
        let preds: Vec<_> = BUCKETS.iter().map(|&b| pred(b)).collect();
        let s = BatchSizer::with_slo(
            BUCKETS.to_vec(),
            &preds,
            Duration::from_micros(10),
        );
        assert_eq!(s.buckets(), &[8], "still serves, counts misses");
        assert!(s.restricted());
    }

    #[test]
    fn missing_predictions_degrade_to_fixed_buckets() {
        let preds = vec![Some(1e-3), None, Some(16e-3)];
        let s = BatchSizer::with_slo(
            BUCKETS.to_vec(),
            &preds,
            Duration::from_millis(5),
        );
        assert_eq!(s.buckets(), &BUCKETS);
        assert!(!s.restricted());
        // ...and so does an absent predictor entirely
        let s = BatchSizer::for_model(BUCKETS.to_vec(), Some(SloConfig {
            p99_deadline: Duration::from_millis(5),
        }), None);
        assert_eq!(s.buckets(), &BUCKETS);
    }

    #[test]
    fn chosen_size_is_maximal_subject_to_deadline_property() {
        // grid of random deadlines over a random monotone cost curve:
        // the sizer's max bucket must be the largest bucket whose
        // predicted time fits, whenever any bucket fits at all
        run_cases(1789, 200, |rng| {
            let base = 1e-4 * (1.0 + rng.gen_range(50) as f64 / 10.0);
            let costs: Vec<f64> =
                BUCKETS.iter().map(|&b| base * b as f64).collect();
            let preds: Vec<Option<f64>> = costs.iter().map(|&c| Some(c)).collect();
            let deadline_s = 1e-4 * (1 + rng.gen_range(20_000)) as f64;
            let s = BatchSizer::with_slo(
                BUCKETS.to_vec(),
                &preds,
                Duration::from_secs_f64(deadline_s),
            );
            let fits: Vec<usize> = BUCKETS
                .iter()
                .zip(&costs)
                .filter(|(_, &c)| c <= deadline_s)
                .map(|(&b, _)| b)
                .collect();
            match fits.last() {
                // maximality: exactly the largest bucket that fits
                Some(&best) => {
                    assert_eq!(s.max_bucket(), best);
                    assert_eq!(s.buckets(), &fits[..], "admissible set is the fit set");
                }
                // nothing fits: smallest bucket, flagged restricted
                None => {
                    assert_eq!(s.buckets(), &[BUCKETS[0]]);
                    assert!(s.restricted());
                }
            }
        });
    }
}
