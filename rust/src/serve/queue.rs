//! Per-shard work queues for the fleet: a mutex-guarded FIFO whose
//! depth is mirrored in an atomic, so the submit path (shed checks,
//! shard choice) and the steal path (victim selection) can probe load
//! without taking any queue lock.
//!
//! Unlike `coordinator::Batcher`, each queued request carries its own
//! response sender: stealing moves the *waiter* together with the
//! work, so a request answered by a sibling shard still reaches its
//! client.  Batch formation reuses the coordinator's single
//! bucket-selection rule (`coordinator::batcher::bucket_for`), so the
//! fleet pads exactly like the single-model server.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::bucket_for;
use crate::coordinator::server::Response;

/// One queued fleet request: input plus its response channel (the
/// waiter travels with the work across steals).
pub(crate) struct FleetReq {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// shard-to-shard migrations so far (incremented by the thief;
    /// surfaced per request in the sampled trace log)
    pub steals: u64,
    pub tx: Sender<Response>,
}

/// A formed batch: requests popped in FIFO order, inputs concatenated
/// and tail-padded to `padded` rows with copies of the last real row.
pub(crate) struct Formed {
    pub reqs: Vec<FleetReq>,
    pub data: Vec<f32>,
    pub padded: usize,
    pub oldest_wait: Duration,
}

/// A shard's FIFO with a lock-free depth mirror.
pub(crate) struct ShardQueue {
    q: Mutex<VecDeque<FleetReq>>,
    depth: AtomicUsize,
}

impl ShardQueue {
    pub fn new() -> ShardQueue {
        ShardQueue { q: Mutex::new(VecDeque::new()), depth: AtomicUsize::new(0) }
    }

    /// Queued requests (approximate under concurrency; exact when the
    /// queue is quiescent).  Never counts in-flight batches.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    pub fn push(&self, req: FleetReq) {
        let mut q = self.q.lock().unwrap();
        q.push_back(req);
        self.depth.store(q.len(), Ordering::Release);
    }

    /// Pop up to `take` requests from the FRONT (the oldest — stealing
    /// these preserves latency order rather than scrambling it).
    pub fn pop_front_n(&self, take: usize) -> Vec<FleetReq> {
        let mut q = self.q.lock().unwrap();
        let n = take.min(q.len());
        let out: Vec<FleetReq> = q.drain(..n).collect();
        self.depth.store(q.len(), Ordering::Release);
        out
    }

    /// Age of the oldest queued request (`None` when empty) — the
    /// watchdog's queue-age probe.
    pub fn oldest_age(&self, now: Instant) -> Option<Duration> {
        let q = self.q.lock().unwrap();
        q.front().map(|f| now.saturating_duration_since(f.enqueued))
    }

    /// Time until the oldest waiter's partial-flush deadline (zero when
    /// already due; `None` when empty) — mirrors
    /// `Batcher::time_until_flush`.
    pub fn time_until_flush(&self, max_wait: Duration, now: Instant) -> Option<Duration> {
        let q = self.q.lock().unwrap();
        let front = q.front()?;
        Some((front.enqueued + max_wait).saturating_duration_since(now))
    }

    /// Form the next batch under the admissible `buckets` if policy
    /// allows: a fully-filled bucket forms immediately; stragglers form
    /// once the oldest has waited `max_wait` (or `force_flush`, used on
    /// shutdown drain).
    pub fn try_form(
        &self,
        buckets: &[usize],
        row_elems: usize,
        max_wait: Duration,
        now: Instant,
        force_flush: bool,
    ) -> Option<Formed> {
        let mut q = self.q.lock().unwrap();
        let n = q.len();
        if n == 0 {
            return None;
        }
        let oldest_wait = now.duration_since(q.front().unwrap().enqueued);
        let flush = force_flush || oldest_wait >= max_wait;
        let bucket = bucket_for(buckets, n, flush)?;
        let take = bucket.min(n);
        let mut reqs = Vec::with_capacity(take);
        let mut data = Vec::with_capacity(bucket * row_elems);
        for _ in 0..take {
            let r = q.pop_front().unwrap();
            debug_assert_eq!(r.input.len(), row_elems, "input width mismatch");
            data.extend_from_slice(&r.input);
            reqs.push(r);
        }
        self.depth.store(q.len(), Ordering::Release);
        drop(q);
        // pad the tail with copies of the last real row (same rule as
        // Batcher::next_batch; padded results are discarded)
        let last = (take - 1) * row_elems;
        for _ in take..bucket {
            let row: Vec<f32> = data[last..last + row_elems].to_vec();
            data.extend_from_slice(&row);
        }
        Some(Formed { reqs, data, padded: bucket, oldest_wait })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, t: Instant) -> (FleetReq, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (FleetReq { id, input: vec![id as f32; 4], enqueued: t, steals: 0, tx }, rx)
    }

    #[test]
    fn depth_mirrors_queue_length() {
        let q = ShardQueue::new();
        let t0 = Instant::now();
        assert_eq!(q.depth(), 0);
        for i in 0..5 {
            q.push(req(i, t0).0);
        }
        assert_eq!(q.depth(), 5);
        let stolen = q.pop_front_n(3);
        assert_eq!(stolen.len(), 3);
        assert_eq!(stolen[0].id, 0, "steals take the oldest first");
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop_front_n(10).len(), 2, "over-ask drains what exists");
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn forms_like_the_coordinator_batcher() {
        let q = ShardQueue::new();
        let t0 = Instant::now();
        let wait = Duration::from_millis(1);
        for i in 0..3 {
            q.push(req(i, t0).0);
        }
        // 3 stragglers, not yet due: no batch
        assert!(q.try_form(&[8, 32], 4, wait, t0, false).is_none());
        assert_eq!(q.time_until_flush(wait, t0), Some(wait));
        assert_eq!(q.oldest_age(t0 + wait), Some(wait));
        // due: flush into the smallest bucket, tail padded from row 2
        let later = t0 + Duration::from_millis(2);
        let f = q.try_form(&[8, 32], 4, wait, later, false).expect("flush");
        assert_eq!(f.reqs.len(), 3);
        assert_eq!(f.padded, 8);
        assert_eq!(f.oldest_wait, Duration::from_millis(2));
        assert_eq!(f.data.len(), 8 * 4);
        assert_eq!(&f.data[2 * 4..3 * 4], &f.data[7 * 4..8 * 4]);
        assert_eq!(q.depth(), 0);
        assert_eq!(q.time_until_flush(wait, later), None);
        assert_eq!(q.oldest_age(later), None);
    }

    #[test]
    fn full_bucket_forms_without_waiting_and_prefers_largest() {
        let q = ShardQueue::new();
        let t0 = Instant::now();
        for i in 0..40 {
            q.push(req(i, t0).0);
        }
        let f = q
            .try_form(&[8, 32], 4, Duration::from_secs(1), t0, false)
            .expect("full bucket forms immediately");
        assert_eq!(f.padded, 32);
        assert_eq!(f.reqs.len(), 32);
        assert_eq!(q.depth(), 8);
    }

    #[test]
    fn force_flush_drains_stragglers_immediately() {
        let q = ShardQueue::new();
        let t0 = Instant::now();
        q.push(req(0, t0).0);
        let f = q
            .try_form(&[8, 32], 4, Duration::from_secs(1), t0, true)
            .expect("shutdown drain ignores the wait");
        assert_eq!(f.reqs.len(), 1);
        assert_eq!(f.padded, 8);
    }
}
