//! Admission control for the fleet: a token bucket (sustained-rate
//! limit with burst allowance) plus queue-depth load shedding.
//!
//! Both checks happen synchronously on the submit path, *before* the
//! request is enqueued — a rejected request is never queued, so there
//! is no waiter to leak: the caller gets an explicit
//! [`Overload`] back instead of a channel that never fires (or a queue
//! that grows without bound).  Depth is checked first so a full fleet
//! does not also burn rate tokens on requests it cannot take.
//!
//! Time is injected (`Instant` parameter) rather than read internally,
//! so tests drive the bucket deterministically.

use std::sync::Mutex;
use std::time::Instant;

/// Why a request was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overload {
    /// the token bucket is empty: sustained arrival rate exceeds the
    /// configured requests/sec
    RateLimited,
    /// total queued depth across the model's shards is at the limit
    QueueFull,
    /// the request targeted a low-priority model while higher-priority
    /// models sharing the host were backed up; background work yields
    /// first (see `serve::Fleet` priority shedding)
    LowPriority,
}

impl std::fmt::Display for Overload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Overload::RateLimited => write!(f, "rate limited (token bucket empty)"),
            Overload::QueueFull => write!(f, "queue depth limit reached"),
            Overload::LowPriority => {
                write!(f, "shed as low priority under shared-host pressure")
            }
        }
    }
}

impl std::error::Error for Overload {}

/// Admission policy for one fleet model.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// sustained admitted requests/sec; `None` disables rate limiting
    pub rate: Option<f64>,
    /// bucket capacity: how large an instantaneous burst is admitted
    /// beyond the sustained rate (clamped to >= 1 token when rate set)
    pub burst: f64,
    /// max total queued requests across the model's shards
    pub max_queue_depth: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { rate: None, burst: 64.0, max_queue_depth: 8192 }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// One model's admission state (shared by every submit).
pub struct Admission {
    cfg: AdmissionConfig,
    bucket: Mutex<Option<Bucket>>,
}

impl Admission {
    /// The bucket starts full: the first burst up to `burst` is always
    /// admitted.
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission { cfg, bucket: Mutex::new(None) }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Admit one request or say why not.  `queue_depth` is the caller's
    /// current total queued count; `now` is injectable for tests.
    pub fn try_admit(&self, queue_depth: usize, now: Instant) -> Result<(), Overload> {
        if queue_depth >= self.cfg.max_queue_depth {
            return Err(Overload::QueueFull);
        }
        let Some(rate) = self.cfg.rate else {
            return Ok(());
        };
        let cap = self.cfg.burst.max(1.0);
        let mut guard = self.bucket.lock().unwrap();
        let b = guard.get_or_insert_with(|| Bucket { tokens: cap, last: now });
        // refill since the last admit attempt, capped at the burst size
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * rate).min(cap);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err(Overload::RateLimited)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn unlimited_when_no_rate_and_room_in_queue() {
        let a = Admission::new(AdmissionConfig::default());
        let t0 = Instant::now();
        for _ in 0..10_000 {
            assert_eq!(a.try_admit(0, t0), Ok(()));
        }
    }

    #[test]
    fn queue_depth_sheds_before_spending_tokens() {
        let a = Admission::new(AdmissionConfig {
            rate: Some(100.0),
            burst: 2.0,
            max_queue_depth: 4,
        });
        let t0 = Instant::now();
        assert_eq!(a.try_admit(4, t0), Err(Overload::QueueFull));
        assert_eq!(a.try_admit(5, t0), Err(Overload::QueueFull));
        // the full-queue rejections above must not have consumed
        // tokens: the whole burst allowance is still there
        assert_eq!(a.try_admit(0, t0), Ok(()));
        assert_eq!(a.try_admit(0, t0), Ok(()));
        assert_eq!(a.try_admit(0, t0), Err(Overload::RateLimited));
    }

    #[test]
    fn token_bucket_admits_burst_then_refills_at_rate() {
        let a = Admission::new(AdmissionConfig {
            rate: Some(10.0), // one token per 100ms
            burst: 3.0,
            max_queue_depth: usize::MAX,
        });
        let t0 = Instant::now();
        // initial burst: exactly `burst` tokens
        for _ in 0..3 {
            assert_eq!(a.try_admit(0, t0), Ok(()));
        }
        assert_eq!(a.try_admit(0, t0), Err(Overload::RateLimited));
        // 250ms later: 2.5 tokens refilled -> 2 admits
        let t1 = t0 + Duration::from_millis(250);
        assert_eq!(a.try_admit(0, t1), Ok(()));
        assert_eq!(a.try_admit(0, t1), Ok(()));
        assert_eq!(a.try_admit(0, t1), Err(Overload::RateLimited));
        // a long quiet period refills to the cap, not beyond
        let t2 = t1 + Duration::from_secs(3600);
        for _ in 0..3 {
            assert_eq!(a.try_admit(0, t2), Ok(()));
        }
        assert_eq!(a.try_admit(0, t2), Err(Overload::RateLimited));
    }

    #[test]
    fn burst_below_one_still_admits_at_rate() {
        let a = Admission::new(AdmissionConfig {
            rate: Some(10.0),
            burst: 0.0, // clamped to 1 token
            max_queue_depth: usize::MAX,
        });
        let t0 = Instant::now();
        assert_eq!(a.try_admit(0, t0), Ok(()));
        assert_eq!(a.try_admit(0, t0), Err(Overload::RateLimited));
        assert_eq!(a.try_admit(0, t0 + Duration::from_millis(150)), Ok(()));
    }
}
